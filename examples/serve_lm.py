"""Batched serving example: admit a wave of prompts, prefill once, decode
step-synchronously (the decode_* dry-run shapes use this exact step fn).

    PYTHONPATH=src python examples/serve_lm.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax

from repro import configs
from repro.models import transformer
from repro.serve.engine import ServeEngine

cfg = configs.get_smoke("qwen3-moe-235b-a22b")      # MoE decode path
params, _ = transformer.make_params(cfg, jax.random.key(0))
eng = ServeEngine(cfg, params, max_batch=4, max_seq=64)

prompts = [[1, 5, 9], [2, 6], [3, 7, 11, 13], [4]]
t0 = time.time()
outs = eng.generate(prompts, max_new=16, temperature=0.8, seed=0)
dt = time.time() - t0
toks = sum(len(o.tokens) - o.prompt_len for o in outs)
print(f"generated {toks} tokens for {len(prompts)} requests "
      f"in {dt:.2f}s ({toks/dt:.1f} tok/s batched)")
for i, o in enumerate(outs):
    print(f"  req{i}: {o.tokens}")

"""Carbon-aware deferral case study: shifting batch work in TIME.

The thermal/carbon subsystem (PR 3) priced every joule at the diurnal
grid intensity; the control plane (PR 5) lets the scheduler *act* on it.
A diurnal ``wiki_like_trace`` workload — arrival peak phase-aligned with
the carbon-intensity peak, the worst case for a carbon-blind scheduler —
runs on a farm with a PkgC6 sleep timer, 60% of jobs flagged deferrable
(batch work with a deadline), twice:

  baseline      LOAD_BALANCE: every job admitted on arrival, so the bulk
                of the energy is drawn at peak intensity
  carbon-aware  SchedPolicy.CARBON_AWARE: deferrable arrivals in the
                high-intensity half are parked and released at the solved
                down-crossing of the intensity sinusoid (deadline as
                backstop); urgent jobs are untouched

Reported per scenario: grams CO2 (exact closed-form integral), the new
deferral telemetry (released-after-deferral count, deferred seconds,
first-order grams-avoided estimate), p95 latency overall AND for the
urgent (non-deferrable) slice — the honest cost axis, since a deferred
batch job's latency includes its park time by definition.

Acceptance: >= 20% carbon reduction at bounded urgent-p95 degradation.

    PYTHONPATH=src python examples/carbon_deferral_case.py
"""
import sys

sys.path.insert(0, "src")

import dataclasses

import numpy as np

from repro.core import farm, workload
from repro.core.jobs import dag_single
from repro.core.types import (SchedPolicy, SimConfig, SleepPolicy,
                              SrvState, TelemetryConfig, ThermalConfig)

N_JOBS = 1200
PERIOD = 240.0          # compressed "day"
CARBON_BASE = 350.0
CARBON_SWING = 0.6

thermal = ThermalConfig(
    enabled=True, r_th=0.35, tau_th=3.0, t_inlet=22.0,
    recirc=0.3, rack_size=4,
    carbon_base=CARBON_BASE, carbon_swing=CARBON_SWING,
    carbon_period=PERIOD,
    price_base=0.12, price_swing=0.6, price_period=PERIOD,
    # defer while intensity sits above 0.7x its mean: releases land well
    # into the trough instead of right at the mean-crossing (a sweep of
    # {1.0, 0.9, 0.8, 0.7}x gave 20.3/21.2/22.5/23.6% reduction at
    # comparable urgent p95)
    defer_threshold=0.7 * CARBON_BASE)

cfg_base = SimConfig(
    n_servers=12, n_cores=2, max_jobs=2048, tasks_per_job=1,
    sched_policy=SchedPolicy.LOAD_BALANCE,
    sleep_policy=SleepPolicy.SINGLE_TIMER, sleep_state=SrvState.PKG_C6,
    max_events=200_000,
    telemetry=TelemetryConfig(n_windows=128, window_dt=4.0),
    thermal=thermal)
cfg_carbon = dataclasses.replace(cfg_base,
                                 sched_policy=SchedPolicy.CARBON_AWARE)

rng = np.random.default_rng(0)
# arrivals peak in phase with the carbon peak (sin > 0 half)
arr = workload.wiki_like_trace(N_JOBS, mean_rate=6.0, period=PERIOD,
                               swing=0.6, seed=1)
deferrable = rng.random(N_JOBS) < 0.6
specs = [dag_single(rng.exponential(0.3), deferrable=bool(deferrable[j]),
                    defer_slack=0.8 * PERIOD)      # deadline backstop
         for j in range(N_JOBS)]

results = {}
for name, cfg in (("baseline", cfg_base), ("carbon-aware", cfg_carbon)):
    res = farm.simulate(cfg, arr, specs, tau=0.5)
    assert res.n_finished == N_JOBS, (name, res.n_finished)
    results[name] = res

base, ca = results["baseline"], results["carbon-aware"]
urgent = ~deferrable


def _p95(res, mask):
    return float(np.percentile(res.latencies[mask], 95))


reduction = 1.0 - ca.carbon_g / base.carbon_g
print(f"{'scenario':>14} {'gCO2':>9} {'deferred':>9} {'defer(s)':>10} "
      f"{'g-avoided':>10} {'p95 all':>9} {'p95 urgent':>11}")
for name, res in results.items():
    print(f"{name:>14} {res.carbon_g:9.2f} {res.deferred_jobs:9d} "
          f"{res.deferred_seconds:10.0f} {res.carbon_g_avoided_est:10.3f} "
          f"{_p95(res, slice(None)):9.3f} {_p95(res, urgent):11.3f}")

print(f"\ncarbon reduction: {reduction:.1%} "
      f"(deferred {ca.deferred_jobs}/{N_JOBS} jobs, "
      f"mean park {ca.deferred_seconds / max(ca.deferred_jobs, 1):.0f} s)")

ts = ca.telemetry
occ = ts.occupancy > 0
print(f"[windows] carbon intensity "
      f"{np.nanmin(ts.carbon_intensity[occ]):.0f}-"
      f"{np.nanmax(ts.carbon_intensity[occ]):.0f} gCO2/kWh, "
      f"per-window grams peak {np.nanmax(ts.carbon_per_window):.2f} "
      f"(baseline {np.nanmax(base.telemetry.carbon_per_window):.2f})")

# acceptance: >= 20% carbon cut, urgent traffic effectively unharmed
assert reduction >= 0.20, f"carbon reduction {reduction:.1%} < 20%"
assert _p95(ca, urgent) <= 1.5 * _p95(base, urgent), \
    "urgent p95 degraded beyond bound"
assert ca.carbon_g_avoided_est > 0.0

"""End-to-end training driver example: a ~100M-param llama-family model
trained for a few hundred steps with checkpoint/restart.

On this CPU container we default to a width-reduced sibling so the run
finishes in minutes; pass --full to use the real smollm-360m config (same
code path — on a TPU slice add --data/--model for the mesh).

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import pathlib
import sys

sys.path.insert(0, "src")

from repro.launch import train


def main():
    args = sys.argv[1:]
    if "--full" in args:
        args.remove("--full")
        arch = ["--arch", "smollm-360m"]
    else:
        arch = ["--arch", "smollm-360m", "--smoke"]
    ckpt = pathlib.Path("results/ckpt_example")
    rc = train.main(arch + ["--steps", "300", "--batch", "8",
                            "--seq", "128", "--ckpt-dir", str(ckpt),
                            "--ckpt-every", "100", "--resume"] + args)
    sys.exit(rc)


if __name__ == "__main__":
    main()

"""Quickstart: the two halves of the framework in one minute.

  1. HolDCSim — simulate a 16-server farm under a bursty MMPP workload
     with a delay-timer power policy, and read off energy/latency.
  2. LM substrate — train a tiny llama-family model for 20 steps and
     greedy-decode from it.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

# ---------------------------------------------------------------- 1. DES
from repro.core import farm, workload
from repro.core.jobs import dag_single
from repro.core.types import SimConfig, SleepPolicy, SrvState, \
    TelemetryConfig

cfg = SimConfig(n_servers=16, n_cores=4, max_jobs=2048, tasks_per_job=1,
                sleep_policy=SleepPolicy.SINGLE_TIMER,
                sleep_state=SrvState.PKG_C6, max_events=60_000,
                telemetry=TelemetryConfig(window_dt=0.05,
                                          tail_thresh=0.05))
rng = np.random.default_rng(0)
arr = workload.mmpp2_arrivals(lam_h=2000.0, lam_l=200.0, r_hl=2.0, r_lh=1.0,
                              n_jobs=1500, seed=1)
# jobs carry a 100ms SLA tracked on device (telemetry.py QoS counters)
specs = [dag_single(rng.exponential(0.005), sla=0.1) for _ in range(1500)]
res = farm.simulate(cfg, arr, specs, tau=0.05)
print(f"[dcsim] {res.n_finished}/{res.n_jobs} jobs, "
      f"mean latency {res.mean_latency*1e3:.2f} ms, "
      f"p95 {res.p95_latency*1e3:.2f} ms, "
      f"mean power {res.mean_power:.0f} W "
      f"({res.events} events in {res.sim_time:.2f}s simulated)")

# device-side telemetry: histogram percentiles, QoS, energy-delay product,
# and windowed time series — all accumulated inside the jitted event loop
ts = res.telemetry
print(f"[dcsim] telemetry: p50/p95/p99 = {ts.job_p50*1e3:.2f}/"
      f"{ts.job_p95*1e3:.2f}/{ts.job_p99*1e3:.2f} ms (from device hist), "
      f"SLA miss {ts.sla_miss}/{ts.sla_total}, "
      f"tail>{cfg.telemetry.tail_thresh*1e3:.0f}ms: {ts.tail_violations}, "
      f"E.D = {ts.energy_delay_product:.2f} J.s")
occ = ts.occupancy > 0
print(f"[dcsim] {ts.n_windows_used} windows: awake servers "
      f"min {ts.awake_servers[occ].min():.1f} / "
      f"max {ts.awake_servers[occ].max():.1f}, "
      f"peak power {np.nanmax(ts.server_power):.0f} W")

# ---------------------------------------------------------------- 2. LM
from repro import configs
from repro.data.pipeline import DataConfig, get_batch
from repro.serve.engine import ServeEngine
from repro.train import step as step_lib

mcfg = configs.get_smoke("llama3.2-1b")
state = step_lib.init_state(mcfg, jax.random.key(0))
ts = jax.jit(step_lib.make_train_step(mcfg))
dc = DataConfig(vocab=mcfg.vocab, seq_len=64, global_batch=8)
for step in range(20):
    state, m = ts(state, get_batch(dc, step))
print(f"[lm] 20 steps, loss {float(m['loss']):.3f}")

eng = ServeEngine(mcfg, state["params"], max_batch=2, max_seq=48)
outs = eng.generate([[1, 2, 3], [4, 5]], max_new=8)
print(f"[lm] generated: {[o.tokens for o in outs]}")

"""Thermal/carbon case study: what the electrical boundary hides.

Three placements of the same diurnal (wiki-like) workload on a 12-server
farm with a PkgC6 delay timer (sleeping servers actually cool down, so
temperatures have real dynamic range), all simulated with the
thermal/cooling/carbon subsystem on:

  baseline   LOAD_BALANCE, no throttle guard — its argmin tie-break
             consolidates work onto low server indices, and their racks
             run past the 60°C limit
  throttled  LOAD_BALANCE + thermal throttling (engage 60°C / release
             54°C hysteresis): caps the silicon but stretches in-flight
             work (~2x p95) and burns extra energy/carbon
  thermal    SchedPolicy.THERMAL_AWARE + the same guard: places on the
             coolest eligible server, so the cap holds with ~40% less
             throttle time and near-baseline carbon

Reported per scenario: peak/mean temperature, throttle time, p95 latency,
energy (IT + CRAC cooling), E·D product, grams CO2 and electricity cost
under the diurnal grid-intensity/tariff curves.

    PYTHONPATH=src python examples/thermal_case.py
"""
import sys

sys.path.insert(0, "src")

import dataclasses

import numpy as np

from repro.core import farm, traceio, workload
from repro.core.jobs import dag_single
from repro.core.types import (SchedPolicy, SimConfig, SleepPolicy,
                              SrvState, TelemetryConfig, ThermalConfig,
                              TraceConfig)

N_JOBS = 2000
PERIOD = 120.0          # compressed "day" so the diurnal curves matter

thermal_base = ThermalConfig(
    enabled=True, r_th=0.35, tau_th=3.0, t_inlet=22.0,
    recirc=0.3, rack_size=4,                       # 3 racks of 4
    throttle_freq=0.5, throttle_power_scale=0.6,
    carbon_base=350.0, carbon_swing=0.5, carbon_period=PERIOD,
    price_base=0.12, price_swing=0.6, price_period=PERIOD)
thermal_guard = dataclasses.replace(thermal_base, t_throttle=60.0,
                                    t_release=54.0)

cfg0 = SimConfig(
    n_servers=12, n_cores=2, max_jobs=2048, tasks_per_job=1,
    sleep_policy=SleepPolicy.SINGLE_TIMER, sleep_state=SrvState.PKG_C6,
    max_events=200_000,
    telemetry=TelemetryConfig(n_windows=128, window_dt=1.0),
    thermal=thermal_base)

rng = np.random.default_rng(0)
arr = workload.wiki_like_trace(N_JOBS, mean_rate=20.0, period=PERIOD,
                               swing=0.6, seed=1)
specs = [dag_single(rng.exponential(0.35)) for _ in range(N_JOBS)]

scenarios = {
    "baseline": cfg0,
    "throttled": dataclasses.replace(cfg0, thermal=thermal_guard),
    # flight recorder on for the winning scenario: the exported Perfetto
    # timeline shows placements avoiding the hot racks
    "thermal-aware": dataclasses.replace(
        cfg0, sched_policy=SchedPolicy.THERMAL_AWARE,
        thermal=thermal_guard, trace=TraceConfig(enabled=True)),
}

print(f"{'scenario':>14} {'peakT':>7} {'meanT':>7} {'thr(s)':>8} "
      f"{'p95(s)':>8} {'E(kJ)':>8} {'E.D':>9} {'gCO2':>8} {'cost($)':>8}")
results = {}
for name, cfg in scenarios.items():
    res = farm.simulate(cfg, arr, specs, tau=0.5)
    results[name] = res
    assert res.n_finished == N_JOBS, (name, res.n_finished)
    ed = res.total_energy * res.mean_latency
    print(f"{name:>14} {res.peak_temp:7.1f} {res.mean_temp:7.1f} "
          f"{res.throttle_seconds:8.1f} {res.p95_latency:8.3f} "
          f"{res.total_energy/1e3:8.1f} {ed:9.1f} "
          f"{res.carbon_g:8.2f} {res.energy_cost:8.4f}")

assert results["throttled"].peak_temp < results["baseline"].peak_temp
assert results["thermal-aware"].throttle_seconds \
    < results["throttled"].throttle_seconds

res_ta = results["thermal-aware"]
traceio.save_chrome_trace("thermal_case_trace.json", res_ta.trace_events,
                          scenarios["thermal-aware"],
                          n_dropped=res_ta.trace_dropped)
print(f"\n[trace] {len(res_ta.trace_events)} events "
      f"({res_ta.trace_dropped} dropped) -> thermal_case_trace.json "
      f"(load in ui.perfetto.dev)")

ts = results["thermal-aware"].telemetry
occ = ts.occupancy > 0
print(f"\n[windows] max-temp series peak {np.nanmax(ts.max_temp):.1f} °C, "
      f"carbon intensity {np.nanmin(ts.carbon_intensity[occ]):.0f}-"
      f"{np.nanmax(ts.carbon_intensity[occ]):.0f} gCO2/kWh, "
      f"cooling {np.nanmax(ts.cooling_power):.0f} W peak "
      f"({ts.n_windows_used} windows)")

"""The paper's thesis transplanted to ML clusters: use HolDCSim to plan a
fleet SERVING the dry-run-profiled models.

The roofline step-time estimate of a compiled (arch × shape × mesh) cell
becomes the task service-time distribution for the simulator; the paper's
delay-timer / provisioning policies then answer capacity questions before
renting a single pod:

  * how many inference pods must stay active at a given request rate to
    hold P95 TTFT inside QoS;
  * what a delay-timer power policy saves on the idle pods;
  * what checkpoint cadence a training fleet of the same size needs
    (Young/Daly from a node MTBF).

    PYTHONPATH=src python examples/fleet_planning.py [--arch llama3.2-1b]
"""
import argparse
import json
import pathlib
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import farm, workload
from repro.core.jobs import dag_single
from repro.core.montecarlo import young_daly_interval
from repro.core.types import SchedPolicy, SimConfig, SleepPolicy, SrvState


def load_cell(arch, shape="prefill_32k", mesh="pod",
              dir_="results/dryrun"):
    f = pathlib.Path(dir_) / (f"{arch.replace('.', '_').replace('|','_')}"
                              f"_{shape}_{mesh}.json")
    cand = list(pathlib.Path(dir_).glob(
        f"{arch.replace('.', '_').replace('-', '*')}*{shape}_{mesh}.json"))
    path = f if f.exists() else (cand[0] if cand else None)
    if path is None:
        raise FileNotFoundError(f"run the dry-run first ({arch} {shape})")
    return json.loads(path.read_text())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--rate", type=float, default=4.0,
                    help="requests/s across the fleet")
    ap.add_argument("--pods", type=int, default=12)
    args = ap.parse_args()

    cell = load_cell(args.arch)
    svc = cell["step_time_est"]                   # sec per prefill request
    print(f"[bridge] {args.arch} prefill_32k on a 256-chip pod: "
          f"service time ~{svc*1e3:.0f} ms "
          f"(dominant: {cell['dominant'][2:]}, "
          f"roofline frac {cell['roofline_fraction']:.3f})")

    # each "server" = one inference pod serving one request at a time per
    # "core" (model replicas per pod = n_cores)
    n_jobs = 1200
    cfg = SimConfig(n_servers=args.pods, n_cores=2, max_jobs=2048,
                    tasks_per_job=1, local_q=64,
                    sched_policy=SchedPolicy.LOAD_BALANCE,
                    sleep_policy=SleepPolicy.SINGLE_TIMER,
                    sleep_state=SrvState.S3, max_events=80_000)
    rng = np.random.default_rng(0)
    arr = workload.wiki_like_trace(n_jobs, args.rate, period=120.0,
                                   swing=0.6, seed=1)
    specs = [dag_single(max(rng.normal(svc, 0.1 * svc), 0.2 * svc))
             for _ in range(n_jobs)]

    qos = 2.5 * svc
    print(f"[fleet] {args.pods} pods x 2 replicas, {args.rate} req/s, "
          f"QoS P95 <= {qos*1e3:.0f} ms")
    for tau in (0.0, 2.0, 10.0):
        res = farm.simulate(cfg, arr, specs, tau=tau if tau else None)
        ok = "MEETS" if res.p95_latency <= qos else "VIOLATES"
        print(f"  tau={tau:5.1f}s: p95={res.p95_latency*1e3:7.0f} ms "
              f"({ok} QoS)  mean power={res.mean_power:7.0f} W  "
              f"wakes={int(res.wake_count.sum())}")

    # training-fleet checkpoint cadence for the same hardware scale
    mtbf_node = 3.0e6                             # ~35 days/node
    n_nodes = args.pods * 64                      # hosts per pod
    fleet_mtbf = mtbf_node / n_nodes
    delta = 45.0                                  # checkpoint write cost (s)
    print(f"[ckpt] fleet of {n_nodes} hosts: MTBF {fleet_mtbf/60:.1f} min "
          f"-> Young/Daly interval "
          f"{young_daly_interval(fleet_mtbf, delta):.0f}s")


if __name__ == "__main__":
    main()

"""Case study B (paper §IV-B, Figs 5-6): delay-timer exploration.

Reproduced claims:
  C6a (Fig 5) — for each workload (web search 5ms, web serving 120ms) and
  each utilization (10/30/60%), energy vs τ is U-shaped with an interior
  optimum τ*, and τ* is CONSISTENT ACROSS UTILIZATIONS for one workload.
  C6b (Fig 6) — dual delay timers (a small high-τ pool prioritized for
  dispatch + a low-τ pool that sleeps aggressively) beat both Active-Idle
  and the best single τ; savings are stable from 20 to 100 servers.

Replica parallelism: each (τ, ρ) cell is an independent simulation — on a
mesh these vmap/shard_map across all chips (core/montecarlo.py).
"""
from __future__ import annotations

import numpy as np

from .common import (WEB_SEARCH_SVC, WEB_SERVING_SVC, make_jobs,
                     poisson_arrivals_for, row, timed)
from repro.core import farm as farm_mod
from repro.core.types import SchedPolicy, SimConfig, SleepPolicy, SrvState


def _cfg(n_servers, policy=SleepPolicy.SINGLE_TIMER):
    return SimConfig(n_servers=n_servers, n_cores=4, max_jobs=4096,
                     tasks_per_job=1, local_q=128,
                     sched_policy=SchedPolicy.LOAD_BALANCE,
                     sleep_policy=policy, sleep_state=SrvState.S3,
                     max_events=120_000)


def sweep_single_timer(svc, taus, rhos, n_jobs=2500, n_servers=20, seed=0):
    """Energy vs τ for each utilization; returns (taus, {rho: energies})."""
    out = {}
    for rho in rhos:
        cfg = _cfg(n_servers)
        rng = np.random.default_rng(seed)
        arr = poisson_arrivals_for(n_jobs, rho, cfg, svc, seed=seed + 1)
        specs = make_jobs(rng, n_jobs, svc)
        energies = []
        for tau in taus:
            res = farm_mod.simulate(cfg, arr, specs, tau=tau)
            energies.append(res.server_energy)
        out[rho] = np.asarray(energies)
    return out


def dual_timer(svc, tau_hi, tau_lo, hi_frac, n_jobs=2500, n_servers=20,
               rho=0.3, seed=0):
    cfg = _cfg(n_servers, SleepPolicy.DUAL_TIMER)
    rng = np.random.default_rng(seed)
    arr = poisson_arrivals_for(n_jobs, rho, cfg, svc, seed=seed + 1)
    specs = make_jobs(rng, n_jobs, svc)
    n_hi = max(1, int(hi_frac * n_servers))
    tau = np.where(np.arange(n_servers) < n_hi, tau_hi, tau_lo)
    pools = (np.arange(n_servers) >= n_hi).astype(np.int32)
    return farm_mod.simulate(cfg, arr, specs, tau=tau, pools=pools)


def active_idle(svc, n_jobs=2500, n_servers=20, rho=0.3, seed=0):
    cfg = _cfg(n_servers, SleepPolicy.ALWAYS_ON)
    rng = np.random.default_rng(seed)
    arr = poisson_arrivals_for(n_jobs, rho, cfg, svc, seed=seed + 1)
    specs = make_jobs(rng, n_jobs, svc)
    return farm_mod.simulate(cfg, arr, specs)


def run(verbose=True, n_jobs=2000):
    taus = np.asarray([0.05, 0.2, 0.8, 3.2, 12.8])
    rhos = [0.1, 0.3, 0.6]
    results = {}
    for name, svc in [("web_search", WEB_SEARCH_SVC),
                      ("web_serving", WEB_SERVING_SVC)]:
        sweep, dt = timed(sweep_single_timer, svc, taus, rhos, n_jobs)
        # τ* per utilization; paper claim: consistent across ρ
        tau_stars = {rho: float(taus[int(np.argmin(e))])
                     for rho, e in sweep.items()}
        star_vals = list(tau_stars.values())
        consistent = max(star_vals) / max(min(star_vals), 1e-9) <= 4.0
        results[name] = {"tau_star": tau_stars, "consistent": consistent,
                         "energies": {r: e.tolist()
                                      for r, e in sweep.items()}}
        if verbose:
            row(f"case_b_single_{name}", dt / (len(taus) * len(rhos)) * 1e6,
                f"tau*={tau_stars} consistent={consistent}")

    # dual timer vs baselines (web serving shows the bigger win)
    for n_servers in (20, 100):
        base = active_idle(WEB_SERVING_SVC, n_jobs, n_servers)
        best_single = min(
            (farm_mod.simulate(
                _cfg(n_servers), poisson_arrivals_for(
                    n_jobs, 0.3, _cfg(n_servers), WEB_SERVING_SVC, seed=1),
                make_jobs(np.random.default_rng(0), n_jobs,
                          WEB_SERVING_SVC), tau=t)
             for t in (0.8, 3.2, 12.8)),
            key=lambda r: r.server_energy)
        dual = dual_timer(WEB_SERVING_SVC, tau_hi=12.8, tau_lo=0.2,
                          hi_frac=0.3, n_jobs=n_jobs, n_servers=n_servers)
        sav_ai = 1 - dual.server_energy / base.server_energy
        sav_single = 1 - dual.server_energy / best_single.server_energy
        # energy-delay trade-off from device telemetry: sleeping deeper must
        # not blow up E·D vs Active-Idle
        ed_ratio = dual.telemetry.energy_delay_product \
            / max(base.telemetry.energy_delay_product, 1e-12)
        results[f"dual_{n_servers}"] = {
            "saving_vs_active_idle": sav_ai,
            "saving_vs_single": sav_single,
            "p95_ratio": dual.p95_latency / max(base.p95_latency, 1e-9),
            "ed_ratio_vs_active_idle": ed_ratio,
            "hist_p99_ms": dual.telemetry.job_p99 * 1e3,
        }
        if verbose:
            row(f"case_b_dual_n{n_servers}", 0.0,
                f"save_vs_AI={sav_ai:.1%} save_vs_single={sav_single:.1%} "
                f"ED_ratio={ed_ratio:.2f}")
    return results


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))

"""Case study C (paper §IV-C, Figs 8-9): hierarchical processor/system
sleep states with workload-adaptive two-pool management (WASP).

Reproduced claims:
  * active-state residency ≈ system utilization (the framework coordinates
    a minimal set of active servers);
  * non-active servers spend most time in the deepest state (S3) up to
    ~60% utilization;
  * energy beats the delay-timer baseline (paper: ~39%);
  * work concentrates on a small subset of servers (Fig 9's skew).
"""
from __future__ import annotations

import numpy as np

from .common import WEB_SEARCH_SVC, make_jobs, poisson_arrivals_for, row, \
    timed
from repro.core import farm as farm_mod
from repro.core.types import SchedPolicy, SimConfig, SleepPolicy, SrvState


def _cfg(policy, sched=None):
    return SimConfig(n_servers=10, n_cores=10, max_jobs=8192,
                     tasks_per_job=1, local_q=256,
                     sched_policy=sched if sched is not None
                     else SchedPolicy.LOAD_BALANCE,
                     sleep_policy=policy, sleep_state=SrvState.S3,
                     wasp_t_wakeup=2.0, wasp_t_sleep=0.3,
                     max_events=150_000)


def run(n_jobs=4000, verbose=True):
    results = {}
    rng = np.random.default_rng(0)
    for rho in (0.1, 0.3, 0.6):
        cfg_w = _cfg(SleepPolicy.WASP, SchedPolicy.WASP_POOLS)
        arr = poisson_arrivals_for(n_jobs, rho, cfg_w, WEB_SEARCH_SVC,
                                   seed=2)
        specs = make_jobs(np.random.default_rng(1), n_jobs, WEB_SEARCH_SVC)
        # start with 2 active-pool servers, the rest in the sleep pool
        pools = (np.arange(10) >= 2).astype(np.int32)
        wasp, dt = timed(farm_mod.simulate, cfg_w, arr, specs,
                         tau=3.0, pools=pools)

        cfg_t = _cfg(SleepPolicy.SINGLE_TIMER)
        timer = farm_mod.simulate(cfg_t, arr, specs, tau=0.2)

        T = wasp.sim_time
        res = wasp.residency
        active_frac = res[:, SrvState.ACTIVE].sum() / res.sum()
        s3_frac = res[:, SrvState.S3].sum() / res.sum()
        pkg_frac = res[:, SrvState.PKG_C6].sum() / res.sum()
        saving = 1 - wasp.server_energy / timer.server_energy
        # Fig 9 skew: top-3 servers take most of the energy spread
        e = np.sort(wasp.energy_per_server)[::-1]
        skew = e[:3].sum() / e.sum()
        results[rho] = {
            "active_frac": active_frac, "s3_frac": s3_frac,
            "pkgc6_frac": pkg_frac, "util": wasp.utilization,
            "saving_vs_timer": saving, "top3_energy_share": skew,
            "p95_ms": wasp.p95_latency * 1e3,
            "hist_p99_ms": wasp.telemetry.job_p99 * 1e3,
            "ed_product_Js": wasp.telemetry.energy_delay_product,
            "tail_violations": wasp.telemetry.tail_violations,
            "finished": wasp.n_finished,
        }
        if verbose:
            row(f"case_c_wasp_rho{int(rho*100)}",
                dt / max(wasp.events, 1) * 1e6,
                f"active={active_frac:.2f} (util {wasp.utilization:.2f}) "
                f"s3={s3_frac:.2f} save_vs_timer={saving:.1%} "
                f"top3={skew:.2f} ED={wasp.telemetry.energy_delay_product:.1f}")
        assert wasp.n_finished == n_jobs
    return results


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))

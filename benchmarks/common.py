"""Shared helpers for the paper-table benchmarks."""
from __future__ import annotations

import sys
import time

import numpy as np

sys.path.insert(0, "src")  # allow `python -m benchmarks.run` from repo root

from repro.core import farm as farm_mod          # noqa: E402
from repro.core import workload                  # noqa: E402
from repro.core.jobs import dag_single           # noqa: E402
from repro.core.types import SimConfig           # noqa: E402

# paper workload models (§IV-B): web search ~5ms, web serving ~120ms
WEB_SEARCH_SVC = 0.005
WEB_SERVING_SVC = 0.120


def make_jobs(rng, n_jobs, mean_svc):
    return [dag_single(rng.exponential(mean_svc)) for _ in range(n_jobs)]


def wiki_arrivals(n_jobs, rho, cfg, mean_svc, seed=0):
    lam = workload.utilization_to_rate(rho, mean_svc, cfg.n_servers,
                                       cfg.n_cores)
    return workload.wiki_like_trace(n_jobs, lam, period=60.0, swing=0.5,
                                    seed=seed)


def poisson_arrivals_for(n_jobs, rho, cfg, mean_svc, seed=0):
    lam = workload.utilization_to_rate(rho, mean_svc, cfg.n_servers,
                                       cfg.n_cores)
    return workload.poisson_arrivals(lam, n_jobs, seed=seed)


def timed(fn, *a, **kw):
    t0 = time.time()
    out = fn(*a, **kw)
    return out, time.time() - t0


def row(name, us_per_call, derived=""):
    print(f"{name},{us_per_call:.1f},{derived}")

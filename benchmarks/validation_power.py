"""Validation benchmarks (paper §V analogue).

The paper validates simulated power against a physical Xeon E5-2680 and a
Cisco WS-C2960-24-S.  Without lab hardware we validate the same property
against independent references:

  * server power trace vs the sequential heapq oracle (exact DES) — the
    error metric mirrors the paper's (mean |ΔP|, std);
  * switch power vs the closed-form expectation for the measured profile
    (base 14.7 W + 0.23 W/active port) under a known port-activity trace;
  * mean server latency vs Erlang-C (M/M/c).
"""
from __future__ import annotations

import math
import sys

import numpy as np

from .common import row, timed
from repro.core import farm as farm_mod
from repro.core import topology, workload
from repro.core.jobs import dag_chain, dag_single
from repro.core.types import SchedPolicy, SimConfig, SleepPolicy, SrvState

sys.path.insert(0, "tests")


def server_power_vs_oracle(n_jobs=1500):
    from oracle import OracleSim
    cfg = SimConfig(n_servers=1, n_cores=10, local_q=512, max_jobs=2048,
                    tasks_per_job=1, sleep_policy=SleepPolicy.SINGLE_TIMER,
                    sleep_state=SrvState.PKG_C6, max_events=60_000)
    rng = np.random.default_rng(0)
    arr = workload.wiki_like_trace(n_jobs, 120.0, period=30.0, swing=0.6,
                                   seed=1)
    specs = [dag_single(rng.exponential(0.01)) for _ in range(n_jobs)]
    res, dt = timed(farm_mod.simulate, cfg, arr, specs, tau=0.05)
    orc = OracleSim(cfg, arr, specs, tau=0.05).run()
    # mean-power error over the run (paper: 0.22 W / 1.3%)
    p_sim = res.server_energy / res.sim_time
    p_orc = orc.total_energy() / orc.t
    return {"mean_power_sim_W": p_sim, "mean_power_oracle_W": p_orc,
            "abs_err_W": abs(p_sim - p_orc),
            "rel_err": abs(p_sim - p_orc) / p_orc, "wall_s": dt}


def switch_power_closed_form(n_jobs=400):
    """24 servers on one switch (paper's §V-B setup): simulated switch
    energy vs base+per-port closed form given the simulated port activity."""
    topo = topology.star(24, link_cap=1.25e9)
    cfg = SimConfig(n_servers=24, n_cores=2, max_jobs=512, tasks_per_job=2,
                    max_children=2, has_network=True, max_flows=128,
                    sched_policy=SchedPolicy.ROUND_ROBIN,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=60_000)
    rng = np.random.default_rng(2)
    specs = [dag_chain(rng.uniform(0.005, 0.02, size=2), edge_bytes=5e6)
             for _ in range(n_jobs)]
    arr = workload.poisson_arrivals(40.0, n_jobs, seed=3)
    res, dt = timed(farm_mod.simulate, cfg, arr, specs, topo=topo)
    swp = cfg.switch_power
    # closed form from port residencies: E = base·T + Σ_port Σ_state P_s·t_s
    # port_residency comes from the same run; the check is that the energy
    # integrator agrees with the residency bookkeeping (independent paths)
    # plus the base/per-port profile measured by the paper.
    import jax.numpy as jnp  # noqa
    return {"switch_energy_J": res.switch_energy,
            "sim_time_s": res.sim_time,
            "mean_switch_power_W": res.switch_energy / res.sim_time,
            "base_power_W": swp.p_chassis,
            "full_active_W": swp.p_chassis + 24 * swp.p_port_active,
            "wall_s": dt}


def latency_vs_erlang_c(n_jobs=4000, rho=0.5, c=8, svc=0.01):
    cfg = SimConfig(n_servers=1, n_cores=c, local_q=1024, max_jobs=4096,
                    tasks_per_job=1, sleep_policy=SleepPolicy.ALWAYS_ON,
                    max_events=100_000)
    mu = 1 / svc
    lam = rho * mu * c
    rng = np.random.default_rng(4)
    arr = workload.poisson_arrivals(lam, n_jobs, seed=5)
    specs = [dag_single(rng.exponential(svc)) for _ in range(n_jobs)]
    res, dt = timed(farm_mod.simulate, cfg, arr, specs)
    a = lam / mu
    p0 = 1.0 / (sum(a ** k / math.factorial(k) for k in range(c))
                + a ** c / (math.factorial(c) * (1 - rho)))
    erl = a ** c / (math.factorial(c) * (1 - rho)) * p0
    w = erl / (c * mu - lam) + 1 / mu
    return {"sim_W_ms": res.mean_latency * 1e3, "theory_W_ms": w * 1e3,
            "rel_err": abs(res.mean_latency - w) / w, "wall_s": dt}


def run(verbose=True):
    out = {}
    out["server_vs_oracle"] = server_power_vs_oracle()
    out["switch_power"] = switch_power_closed_form()
    out["latency_vs_erlang_c"] = latency_vs_erlang_c()
    if verbose:
        so = out["server_vs_oracle"]
        row("validation_server_power", 0.0,
            f"|dP|={so['abs_err_W']:.3f}W rel={so['rel_err']:.2%}")
        sw = out["switch_power"]
        row("validation_switch_power", 0.0,
            f"mean={sw['mean_switch_power_W']:.2f}W "
            f"(base {sw['base_power_W']}W)")
        lt = out["latency_vs_erlang_c"]
        row("validation_erlang_c", 0.0,
            f"sim={lt['sim_W_ms']:.2f}ms theory={lt['theory_W_ms']:.2f}ms "
            f"rel={lt['rel_err']:.2%}")
    assert out["server_vs_oracle"]["rel_err"] < 0.02
    assert out["latency_vs_erlang_c"]["rel_err"] < 0.08
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))

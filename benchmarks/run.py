"""Benchmark aggregator: one harness per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Prints ``name,us_per_call,derived`` CSV rows per benchmark plus a JSON
summary at the end.  Set --fast for reduced job counts (CI-sized).
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from . import (bench_engine, case_a_provisioning, case_b_delay_timer,
                   case_c_wasp, case_d_network, validation_power)

    fast = args.fast
    suites = {
        "case_a": lambda: case_a_provisioning.run(
            n_jobs=800 if fast else 3000),
        "case_b": lambda: case_b_delay_timer.run(
            n_jobs=600 if fast else 2000),
        "case_c": lambda: case_c_wasp.run(n_jobs=1000 if fast else 4000),
        "case_d": lambda: case_d_network.run(n_jobs=120 if fast else 300),
        "validation": lambda: validation_power.run(),
        "engine": lambda: bench_engine.run(
            sizes=(64, 512) if fast else (64, 512, 4096, 20480)),
    }
    if args.only:
        suites = {args.only: suites[args.only]}

    summary = {}
    failed = []
    for name, fn in suites.items():
        print(f"== {name} ==")
        try:
            summary[name] = fn()
        except Exception as e:
            traceback.print_exc()
            failed.append(name)
            summary[name] = {"error": str(e)}
        sys.stdout.flush()

    print("\n== summary ==")
    print(json.dumps(summary, indent=1, default=str))
    if failed:
        print(f"FAILED: {failed}")
        sys.exit(1)


if __name__ == "__main__":
    main()

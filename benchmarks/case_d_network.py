"""Case study D (paper §IV-D, Figs 10-11): server-network cooperative
energy optimization on a fat-tree.

Reproduced claim: the Server-Network Aware policy (wake the server with the
least network wake cost) saves server AND network power vs strict
Server-Balanced placement, with negligible job-latency increase.

Jobs are task DAGs whose edges carry 100 MB flows (paper's setting),
routed over a k=4 fat-tree with full bisection bandwidth; switches doze
when traffic-idle and ports use 802.3az LPI.
"""
from __future__ import annotations

import numpy as np

from .common import row, timed
from repro.core import farm as farm_mod
from repro.core import topology, workload
from repro.core.jobs import dag_chain
from repro.core.types import SchedPolicy, SimConfig, SleepPolicy, SrvState


def _cfg(sched):
    return SimConfig(n_servers=16, n_cores=4, max_jobs=512, tasks_per_job=2,
                     max_children=2, max_flows=256, local_q=64,
                     sched_policy=sched,
                     sleep_policy=SleepPolicy.SINGLE_TIMER,
                     sleep_state=SrvState.S3,
                     has_network=True, comm_model=0,
                     max_events=60_000)


def run(n_jobs=300, verbose=True):
    topo = topology.fat_tree(4, link_cap=1.25e9)       # 16 servers, 20 sw
    rng = np.random.default_rng(0)
    # two-task chains with 100MB transfer between them (paper's flow size)
    specs = [dag_chain(rng.uniform(0.01, 0.05, size=2), edge_bytes=100e6)
             for _ in range(n_jobs)]
    arr = workload.poisson_arrivals(30.0, n_jobs, seed=4)

    out = {}
    for name, sched in [("server_balanced", SchedPolicy.LOAD_BALANCE),
                        ("net_aware", SchedPolicy.NETWORK_AWARE)]:
        cfg = _cfg(sched)
        res, dt = timed(farm_mod.simulate, cfg, arr, specs, tau=0.2,
                        topo=topo)
        out[name] = {"server_energy": res.server_energy,
                     "switch_energy": res.switch_energy,
                     "p95_ms": res.p95_latency * 1e3,
                     "mean_ms": res.mean_latency * 1e3,
                     "hist_p99_ms": res.telemetry.job_p99 * 1e3,
                     "ed_product_Js": res.telemetry.energy_delay_product,
                     "finished": res.n_finished,
                     "events": res.events, "wall_s": dt}
        if verbose:
            row(f"case_d_{name}", dt / max(res.events, 1) * 1e6,
                f"srv={res.server_energy:.0f}J "
                f"net={res.switch_energy:.0f}J "
                f"p95={res.p95_latency*1e3:.1f}ms "
                f"ED={res.telemetry.energy_delay_product:.0f}J.s "
                f"fin={res.n_finished}")

    sb, na = out["server_balanced"], out["net_aware"]
    out["saving_server"] = 1 - na["server_energy"] / sb["server_energy"]
    out["saving_switch"] = 1 - na["switch_energy"] / sb["switch_energy"]
    out["latency_ratio"] = na["p95_ms"] / max(sb["p95_ms"], 1e-9)
    if verbose:
        row("case_d_savings", 0.0,
            f"server={out['saving_server']:.1%} "
            f"switch={out['saving_switch']:.1%} "
            f"p95_ratio={out['latency_ratio']:.2f}")
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))

"""Engine scalability (paper Table I: >20K servers).

Measures events/second of the jitted engine as the farm grows, and the
replica-parallel throughput (vmapped Monte-Carlo batch — the axis that
shard_maps across a TPU mesh).  The per-event cost of the dense engine is
O(state) but it executes at VPU width; the paper's Java heap engine is
O(log n) pointer chasing — crossover favors the dense engine once replicas
or farm width amortize the streaming.

Perf trajectory: two fixed acceptance configs (a 512-server no-network farm
and the 16-server case-D fat-tree) are measured on every run and written to
``BENCH_engine.json`` together with the recorded pre-PR-2 baseline, so
regressions are visible per-PR (CI runs ``--smoke`` and uploads the JSON).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from .common import row
from repro.core import engine, farm as farm_mod, montecarlo, topology, \
    workload
from repro.core.jobs import dag_chain, dag_single
from repro.core.types import (SchedPolicy, SimConfig, SleepPolicy, SrvState,
                              TelemetryConfig)

# events/s of the acceptance configs at the seed engine (PR 1), measured
# on the same container class that runs CI — the denominator of "speedup".
# network_flows_rr (round-robin placement, so chained tasks split across
# servers and every job routes a 100MB flow) exercises the flow-spawn /
# rate-recompute path that case-D's colocating score policy never hits.
BASELINE_PRE_PR2 = {"no_network": 657.3, "network_case_d": 2756.0,
                    "network_flows_rr": 1596.2}


def one_farm(n_servers, n_jobs=1000, seed=0, telemetry=True, repeats=0):
    cfg = SimConfig(n_servers=n_servers, n_cores=4, local_q=64,
                    max_jobs=max(n_jobs, 16), tasks_per_job=1,
                    sleep_policy=SleepPolicy.ALWAYS_ON,
                    max_events=20_000,
                    telemetry=TelemetryConfig(enabled=telemetry))
    rng = np.random.default_rng(seed)
    lam = workload.utilization_to_rate(0.5, 0.01, n_servers, 4)
    arr = workload.poisson_arrivals(lam, n_jobs, seed=seed)
    specs = [dag_single(rng.exponential(0.01)) for _ in range(n_jobs)]
    best = 0.0
    for _ in range(repeats + 1):
        t0 = time.time()
        res = farm_mod.simulate(cfg, arr, specs)
        best = max(best, res.events / (time.time() - t0))
    return best, res


def network_farm(n_jobs=300, seed=0, repeats=0,
                 sched=SchedPolicy.NETWORK_AWARE, max_flows=256):
    """2-task chains with 100MB edges over a k=4 fat-tree.  With the
    default NETWORK_AWARE policy this is the case-study-D shape
    (benchmarks/case_d_network.py): the shared-snapshot argmin colocates
    each chain, so edges resolve locally and no flow spawns.  Pass
    sched=ROUND_ROBIN (+ max_flows=1024 headroom) to split every chain
    across servers and drive the flow-spawn / rate-recompute path."""
    cfg = SimConfig(n_servers=16, n_cores=4, max_jobs=512, tasks_per_job=2,
                    max_children=2, max_flows=max_flows, local_q=64,
                    sched_policy=sched,
                    sleep_policy=SleepPolicy.SINGLE_TIMER,
                    sleep_state=SrvState.S3, has_network=True,
                    comm_model=0, max_events=60_000)
    topo = topology.fat_tree(4, link_cap=1.25e9)
    rng = np.random.default_rng(seed)
    specs = [dag_chain(rng.uniform(0.01, 0.05, size=2), edge_bytes=100e6)
             for _ in range(n_jobs)]
    arr = workload.poisson_arrivals(30.0, n_jobs, seed=4)
    best = 0.0
    for _ in range(repeats + 1):
        t0 = time.time()
        res = farm_mod.simulate(cfg, arr, specs, tau=0.2, topo=topo)
        best = max(best, res.events / (time.time() - t0))
    return best, res


def perf_cases(repeats=2, verbose=True):
    """The fixed acceptance configs, compared to the recorded pre-PR-2
    baseline.  Post-jit best-of-(repeats) events/s."""
    out = {}
    for name, fn in [("no_network",
                      lambda: one_farm(512, n_jobs=600, repeats=repeats)),
                     ("network_case_d",
                      lambda: network_farm(n_jobs=300, repeats=repeats)),
                     ("network_flows_rr",
                      lambda: network_farm(n_jobs=300, repeats=repeats,
                                           sched=SchedPolicy.ROUND_ROBIN,
                                           max_flows=1024))]:
        eps, res = fn()
        base = BASELINE_PRE_PR2[name]
        out[name] = {"events_per_s": eps, "finished": res.n_finished,
                     "events": res.events,
                     "baseline_events_per_s": base,
                     "speedup_vs_baseline": eps / base}
        if verbose:
            row(f"bench_engine_{name}", 1e6 / eps,
                f"events/s={eps:.0f} ({eps / base:.2f}x baseline "
                f"{base:.0f}) finished={res.n_finished}")
    return out


def telemetry_overhead(n_servers=512, n_jobs=600, repeats=2):
    """Wall-clock cost of the instrumented step: events/s with telemetry
    off vs on (best of ``repeats``, post-jit).  Tracked in the perf
    trajectory.  Note: the fraction grew after PR 2 because the base step
    got ~5x faster, not because telemetry got slower — re-fusing the
    histogram binning is an open item (ROADMAP)."""
    eps = {}
    for mode in (False, True):
        # same seed every rep: repeats re-time the identical jitted
        # computation rather than different workload instances
        e, _ = one_farm(n_servers, n_jobs=n_jobs, seed=0,
                        telemetry=mode, repeats=repeats)
        eps[mode] = e
    return {"events_per_s_off": eps[False], "events_per_s_on": eps[True],
            "overhead_frac": eps[False] / max(eps[True], 1e-9) - 1.0}


def replica_throughput(n_replicas=8, n_servers=64, n_jobs=400):
    cfg = SimConfig(n_servers=n_servers, n_cores=4, local_q=64,
                    max_jobs=512, tasks_per_job=1,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=10_000)
    rng = np.random.default_rng(1)
    lam = workload.utilization_to_rate(0.5, 0.01, n_servers, 4)
    arrs = np.stack([workload.poisson_arrivals(lam, n_jobs, seed=s)
                     for s in range(n_replicas)])
    specs = [dag_single(rng.exponential(0.01)) for _ in range(n_jobs)]
    state_b, tc = montecarlo.batched_state(cfg, arrs, specs)
    t0 = time.time()
    out = montecarlo.run_replicas(cfg, state_b, tc)
    jax.block_until_ready(out.t)
    dt = time.time() - t0
    ev = int(np.asarray(out.events).sum())
    return ev / dt, out


def run(verbose=True, sizes=(64, 512, 4096, 20480), smoke=False):
    out = {"smoke": smoke}
    if smoke:
        sizes = (64,)
    for n in sizes:
        eps, res = one_farm(n, n_jobs=600)
        out[f"n{n}"] = {"events_per_s": eps, "finished": res.n_finished}
        if verbose:
            row(f"bench_engine_n{n}", 1e6 / eps,
                f"events/s={eps:.0f} finished={res.n_finished}")
    out["perf"] = perf_cases(repeats=1 if smoke else 2, verbose=verbose)
    if not smoke:
        eps, _ = replica_throughput()
        out["replicas8"] = {"events_per_s": eps}
        if verbose:
            row("bench_engine_replicas8", 1e6 / eps,
                f"agg_events/s={eps:.0f}")
        tel = telemetry_overhead()
        out["telemetry"] = tel
        if verbose:
            row("bench_engine_telemetry",
                1e6 / max(tel["events_per_s_on"], 1e-9),
                f"off={tel['events_per_s_off']:.0f}ev/s "
                f"on={tel['events_per_s_on']:.0f}ev/s "
                f"overhead={tel['overhead_frac']:.1%}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: perf acceptance configs + the 64-server "
                         "point only (skips the 20K-server sweep)")
    ap.add_argument("--out", default="BENCH_engine.json",
                    help="where to write the JSON record")
    args = ap.parse_args(argv)
    out = run(smoke=args.smoke)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")
    print(json.dumps(out, indent=1))
    return out


if __name__ == "__main__":
    main()

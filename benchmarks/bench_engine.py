"""Engine scalability (paper Table I: >20K servers).

Measures events/second of the jitted engine as the farm grows, and the
replica-parallel throughput (vmapped Monte-Carlo batch — the axis that
shard_maps across a TPU mesh).  The per-event cost of the dense engine is
O(state) but it executes at VPU width; the paper's Java heap engine is
O(log n) pointer chasing — crossover favors the dense engine once replicas
or farm width amortize the streaming.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from .common import row
from repro.core import engine, farm as farm_mod, montecarlo, workload
from repro.core.jobs import dag_single
from repro.core.types import SimConfig, SleepPolicy


def one_farm(n_servers, n_jobs=1000, seed=0):
    cfg = SimConfig(n_servers=n_servers, n_cores=4, local_q=64,
                    max_jobs=max(n_jobs, 16), tasks_per_job=1,
                    sleep_policy=SleepPolicy.ALWAYS_ON,
                    max_events=20_000)
    rng = np.random.default_rng(seed)
    lam = workload.utilization_to_rate(0.5, 0.01, n_servers, 4)
    arr = workload.poisson_arrivals(lam, n_jobs, seed=seed)
    specs = [dag_single(rng.exponential(0.01)) for _ in range(n_jobs)]
    t0 = time.time()
    res = farm_mod.simulate(cfg, arr, specs)
    dt = time.time() - t0
    return res.events / dt, res


def replica_throughput(n_replicas=8, n_servers=64, n_jobs=400):
    cfg = SimConfig(n_servers=n_servers, n_cores=4, local_q=64,
                    max_jobs=512, tasks_per_job=1,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=10_000)
    rng = np.random.default_rng(1)
    lam = workload.utilization_to_rate(0.5, 0.01, n_servers, 4)
    arrs = np.stack([workload.poisson_arrivals(lam, n_jobs, seed=s)
                     for s in range(n_replicas)])
    specs = [dag_single(rng.exponential(0.01)) for _ in range(n_jobs)]
    state_b, tc = montecarlo.batched_state(cfg, arrs, specs)
    t0 = time.time()
    out = montecarlo.run_replicas(cfg, state_b, tc)
    jax.block_until_ready(out.t)
    dt = time.time() - t0
    ev = int(np.asarray(out.events).sum())
    return ev / dt, out


def run(verbose=True, sizes=(64, 512, 4096, 20480)):
    out = {}
    for n in sizes:
        eps, res = one_farm(n, n_jobs=600)
        out[f"n{n}"] = {"events_per_s": eps, "finished": res.n_finished}
        if verbose:
            row(f"bench_engine_n{n}", 1e6 / eps,
                f"events/s={eps:.0f} finished={res.n_finished}")
    eps, _ = replica_throughput()
    out["replicas8"] = {"events_per_s": eps}
    if verbose:
        row("bench_engine_replicas8", 1e6 / eps, f"agg_events/s={eps:.0f}")
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))

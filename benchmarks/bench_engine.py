"""Engine scalability (paper Table I: >20K servers).

Measures events/second of the jitted engine as the farm grows, and the
replica-parallel throughput (vmapped Monte-Carlo batch — the axis that
shard_maps across a TPU mesh).  The per-event cost of the dense engine is
O(state) but it executes at VPU width; the paper's Java heap engine is
O(log n) pointer chasing — crossover favors the dense engine once replicas
or farm width amortize the streaming.

Perf trajectory: two fixed acceptance configs (a 512-server no-network farm
and the 16-server case-D fat-tree) are measured on every run and written to
``BENCH_engine.json`` together with the recorded pre-PR-2 baseline, so
regressions are visible per-PR (CI runs ``--smoke`` and uploads the JSON).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from .common import row
from repro.core import engine, farm as farm_mod, montecarlo, topology, \
    workload
from repro.core.jobs import dag_chain, dag_single
from repro.core.types import (SchedPolicy, SimConfig, SleepPolicy,
                              SrvState, TelemetryConfig, ThermalConfig,
                              TraceConfig)

# events/s of the acceptance configs at the seed engine (PR 1), measured
# on the same container class that runs CI — the denominator of "speedup".
# network_flows_rr (round-robin placement, so chained tasks split across
# servers and every job routes a 100MB flow) exercises the flow-spawn /
# rate-recompute path that case-D's colocating score policy never hits.
BASELINE_PRE_PR2 = {"no_network": 657.3, "network_case_d": 2756.0,
                    "network_flows_rr": 1596.2}


def one_farm(n_servers, n_jobs=1000, seed=0, telemetry=True, repeats=0,
             thermal=None):
    cfg = SimConfig(n_servers=n_servers, n_cores=4, local_q=64,
                    max_jobs=max(n_jobs, 16), tasks_per_job=1,
                    sleep_policy=SleepPolicy.ALWAYS_ON,
                    max_events=20_000,
                    telemetry=TelemetryConfig(enabled=telemetry),
                    thermal=thermal or ThermalConfig())
    rng = np.random.default_rng(seed)
    lam = workload.utilization_to_rate(0.5, 0.01, n_servers, 4)
    arr = workload.poisson_arrivals(lam, n_jobs, seed=seed)
    specs = [dag_single(rng.exponential(0.01)) for _ in range(n_jobs)]
    best = 0.0
    for _ in range(repeats + 1):
        t0 = time.time()
        res = farm_mod.simulate(cfg, arr, specs)
        best = max(best, res.events / (time.time() - t0))
    return best, res


def network_farm(n_jobs=300, seed=0, repeats=0,
                 sched=SchedPolicy.NETWORK_AWARE, max_flows=256):
    """2-task chains with 100MB edges over a k=4 fat-tree.  With the
    default NETWORK_AWARE policy this is the case-study-D shape
    (benchmarks/case_d_network.py): the shared-snapshot argmin colocates
    each chain, so edges resolve locally and no flow spawns.  Pass
    sched=ROUND_ROBIN (+ max_flows=1024 headroom) to split every chain
    across servers and drive the flow-spawn / rate-recompute path."""
    cfg = SimConfig(n_servers=16, n_cores=4, max_jobs=512, tasks_per_job=2,
                    max_children=2, max_flows=max_flows, local_q=64,
                    sched_policy=sched,
                    sleep_policy=SleepPolicy.SINGLE_TIMER,
                    sleep_state=SrvState.S3, has_network=True,
                    comm_model=0, max_events=60_000)
    topo = topology.fat_tree(4, link_cap=1.25e9)
    rng = np.random.default_rng(seed)
    specs = [dag_chain(rng.uniform(0.01, 0.05, size=2), edge_bytes=100e6)
             for _ in range(n_jobs)]
    arr = workload.poisson_arrivals(30.0, n_jobs, seed=4)
    best = 0.0
    for _ in range(repeats + 1):
        t0 = time.time()
        res = farm_mod.simulate(cfg, arr, specs, tau=0.2, topo=topo)
        best = max(best, res.events / (time.time() - t0))
    return best, res


def control_plane_farm(n_jobs=600, seed=0, repeats=0):
    """The full PR-5 carbon/thermal control plane armed at once on a
    512-server farm: per-rack CRAC setpoints + the setpoint controller
    (its tick is an extra event source), diurnal ambient on the supply
    temperature, and CARBON_AWARE deferral with half the jobs deferrable
    — the overhead acceptance case for the control-plane event sources
    and the in-trace per-rack COP path."""
    thermal = ThermalConfig(enabled=True, r_th=0.25, tau_th=30.0,
                            t_setpoint=18.0, ctrl_period=0.5,
                            ctrl_target=45.0,
                            ambient_swing=3.0, ambient_period=120.0,
                            carbon_base=350.0, carbon_swing=0.5,
                            carbon_period=120.0, defer_threshold=350.0)
    cfg = SimConfig(n_servers=512, n_cores=4, local_q=64,
                    max_jobs=max(n_jobs, 16), tasks_per_job=1,
                    sched_policy=SchedPolicy.CARBON_AWARE,
                    sleep_policy=SleepPolicy.ALWAYS_ON,
                    max_events=20_000, thermal=thermal)
    rng = np.random.default_rng(seed)
    lam = workload.utilization_to_rate(0.5, 0.01, 512, 4)
    arr = workload.poisson_arrivals(lam, n_jobs, seed=seed)
    specs = [dag_single(rng.exponential(0.01), deferrable=(j % 2 == 0),
                        defer_slack=30.0) for j in range(n_jobs)]
    best = 0.0
    for _ in range(repeats + 1):
        t0 = time.time()
        res = farm_mod.simulate(cfg, arr, specs)
        best = max(best, res.events / (time.time() - t0))
    return best, res


def perf_cases(repeats=2, verbose=True):
    """The fixed acceptance configs, compared to the recorded pre-PR-2
    baseline (cases introduced later carry no pre-PR-2 number).
    Post-jit best-of-(repeats) events/s."""
    out = {}
    for name, fn in [("no_network",
                      lambda: one_farm(512, n_jobs=600, repeats=repeats)),
                     ("network_case_d",
                      lambda: network_farm(n_jobs=300, repeats=repeats)),
                     ("network_flows_rr",
                      lambda: network_farm(n_jobs=300, repeats=repeats,
                                           sched=SchedPolicy.ROUND_ROBIN,
                                           max_flows=1024)),
                     ("control_plane",
                      lambda: control_plane_farm(n_jobs=600,
                                                 repeats=repeats))]:
        eps, res = fn()
        base = BASELINE_PRE_PR2.get(name)
        out[name] = {"events_per_s": eps, "finished": res.n_finished,
                     "events": res.events}
        if base is not None:
            out[name].update(baseline_events_per_s=base,
                             speedup_vs_baseline=eps / base)
        if verbose:
            vs = f" ({eps / base:.2f}x baseline {base:.0f})" if base \
                else ""
            row(f"bench_engine_{name}", 1e6 / eps,
                f"events/s={eps:.0f}{vs} finished={res.n_finished}")
    return out


def _interleaved_engine_eps(cfgs, n_jobs=600, seed=0, rounds=5):
    """events/s of the jitted loop alone (build/init/summarize excluded)
    for several configs, measured in INTERLEAVED rounds so slow drift in
    background machine load cancels out of the ratios — the honest shape
    for per-step overhead probes.  Within each round the configs run in
    alternating order (forward, then reversed) so a load transient never
    systematically lands on the same config, and the reported number is
    the per-config MEDIAN across rounds: best-of-N maxima let one lucky
    quiet slice report a negative overhead for the more expensive config
    (the -8% artifact the seed probe recorded).  cfgs: {name: SimConfig};
    returns {name: median events/s}."""
    from repro.core.jobs import build_jobs
    rng = np.random.default_rng(seed)
    specs = [dag_single(rng.exponential(0.01)) for _ in range(n_jobs)]
    runs = {}
    for name, cfg in cfgs.items():
        lam = workload.utilization_to_rate(0.5, 0.01, cfg.n_servers,
                                           cfg.n_cores)
        arr = workload.poisson_arrivals(lam, n_jobs, seed=seed)
        jt = build_jobs(cfg, np.asarray(arr), specs)
        state, tc = engine.init_state(cfg, jt)
        out = engine.run(state, cfg, tc)
        jax.block_until_ready(out.t)              # compile + warm
        runs[name] = (state, cfg, tc)
    eps = {name: [] for name in cfgs}
    order = list(runs.items())
    for r in range(rounds):
        for name, (state, cfg, tc) in (order if r % 2 == 0
                                       else order[::-1]):
            t0 = time.perf_counter()
            out = engine.run(state, cfg, tc)
            jax.block_until_ready(out.t)
            eps[name].append(int(out.events) / (time.perf_counter() - t0))
    return {name: float(np.median(v)) for name, v in eps.items()}


def telemetry_overhead(n_servers=512, n_jobs=600, repeats=2):
    """Per-step cost of the instrumented loop: events/s with telemetry
    off vs on, timing ``engine.run`` only (the simulate-path numbers of
    PR 1/2 also counted host-side table building and summarization, which
    drowned the in-loop signal).  The new-finishes compaction
    (TelemetryConfig.compact) keeps this within the 15% budget — the
    dense path measured ~20% on the same probe."""
    def cfg(mode):
        return SimConfig(n_servers=n_servers, n_cores=4, local_q=64,
                         max_jobs=max(n_jobs, 16), tasks_per_job=1,
                         sleep_policy=SleepPolicy.ALWAYS_ON,
                         max_events=20_000,
                         telemetry=TelemetryConfig(enabled=mode))
    # the loop is fast enough post-macro-stepping that a run is ~0.1 s:
    # a handful of rounds is pure noise on a busy CI box (the seed probe
    # recorded -8.1% from 4 samples), so take the median of many
    eps = _interleaved_engine_eps({"off": cfg(False), "on": cfg(True)},
                                  n_jobs=n_jobs, rounds=2 * repeats + 8)
    return {"events_per_s_off": eps["off"], "events_per_s_on": eps["on"],
            "overhead_frac": eps["off"] / max(eps["on"], 1e-9) - 1.0}


def trace_overhead(n_servers=512, n_jobs=600, repeats=2):
    """Per-step cost of the flight recorder on the 512-server acceptance
    farm: events/s with tracing off vs on (default 65536-slot ring),
    timing ``engine.run`` only.  Budget: <15% — emission is a few masked
    scatter slices per applied event, each cond-gated behind mask.any().
    Keyed ``events_per_s`` (the traced number) so check_regression guards
    it like every other perf case."""
    def cfg(mode):
        return SimConfig(n_servers=n_servers, n_cores=4, local_q=64,
                         max_jobs=max(n_jobs, 16), tasks_per_job=1,
                         sleep_policy=SleepPolicy.ALWAYS_ON,
                         max_events=20_000,
                         trace=TraceConfig(enabled=mode))
    eps = _interleaved_engine_eps({"off": cfg(False), "on": cfg(True)},
                                  n_jobs=n_jobs, rounds=2 * repeats + 8)
    return {"events_per_s": eps["on"],
            "events_per_s_off": eps["off"],
            "overhead_frac": eps["off"] / max(eps["on"], 1e-9) - 1.0}


def thermal_overhead(n_servers=512, n_jobs=600, repeats=2):
    """Cost of the thermal subsystem in the jitted loop: events/s with
    thermal off vs tracking-only (RC temps + carbon/cost) vs fully
    coupled (throttling crossings armed — an extra per-step event source
    plus the latch/stretch pass).  The thermal-OFF step is structurally
    identical to pre-thermal code (static gating), so "off" doubles as
    the <2%-regression acceptance point."""
    therm_track = ThermalConfig(enabled=True, r_th=0.25, tau_th=30.0)
    therm_full = ThermalConfig(enabled=True, r_th=0.25, tau_th=30.0,
                               t_throttle=70.0, t_release=65.0)

    def cfg(th):
        return SimConfig(n_servers=n_servers, n_cores=4, local_q=64,
                         max_jobs=max(n_jobs, 16), tasks_per_job=1,
                         sleep_policy=SleepPolicy.ALWAYS_ON,
                         max_events=20_000, thermal=th)
    eps = _interleaved_engine_eps(
        {"off": cfg(ThermalConfig()), "tracking": cfg(therm_track),
         "throttling": cfg(therm_full)},
        n_jobs=n_jobs, rounds=2 * repeats + 8)
    return {"events_per_s_off": eps["off"],
            "events_per_s_tracking": eps["tracking"],
            "events_per_s_throttling": eps["throttling"],
            "overhead_frac_tracking":
                eps["off"] / max(eps["tracking"], 1e-9) - 1.0,
            "overhead_frac_throttling":
                eps["off"] / max(eps["throttling"], 1e-9) - 1.0}


def replica_throughput(n_replicas=8, n_servers=64, n_jobs=400,
                       max_jobs=512):
    cfg = SimConfig(n_servers=n_servers, n_cores=4, local_q=64,
                    max_jobs=max_jobs, tasks_per_job=1,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=10_000)
    rng = np.random.default_rng(1)
    lam = workload.utilization_to_rate(0.5, 0.01, n_servers, 4)
    arrs = np.stack([workload.poisson_arrivals(lam, n_jobs, seed=s)
                     for s in range(n_replicas)])
    specs = [dag_single(rng.exponential(0.01)) for _ in range(n_jobs)]
    state_b, tc = montecarlo.batched_state(cfg, arrs, specs)
    t0 = time.time()
    out = montecarlo.run_replicas(cfg, state_b, tc)
    jax.block_until_ready(out.t)
    dt = time.time() - t0
    ev = int(np.asarray(out.events).sum())
    return ev / dt, out


def shard_point(n_shards, n_servers, n_jobs=600, seed=0):
    """events/s of the rack-sharded engine on ``n_shards`` devices (this
    process must already see that many).  Times ``run_sharded`` (plain
    ``engine.run`` for 1 shard — what a single-device user runs) warm,
    and reports per-device throughput plus the collective count per
    macro-step read off the shard-mapped jaxpr."""
    from repro.core import shard_sim
    from repro.core.jobs import build_jobs
    from repro.core.types import PartitionConfig
    cfg = SimConfig(n_servers=n_servers, n_cores=4, local_q=64,
                    max_jobs=max(n_jobs, 16), tasks_per_job=1,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=20_000,
                    partition=PartitionConfig(n_shards=n_shards))
    cfg = farm_mod.pad_to_racks(cfg)
    rng = np.random.default_rng(seed)
    lam = workload.utilization_to_rate(0.5, 0.01, n_servers, 4)
    arr = workload.poisson_arrivals(lam, n_jobs, seed=seed)
    specs = [dag_single(rng.exponential(0.01)) for _ in range(n_jobs)]
    jt = build_jobs(cfg, np.asarray(arr), specs)
    state, tc = engine.init_state(cfg, jt)
    rec = {"devices": n_shards, "n_servers": cfg.n_servers}
    if n_shards == 1:
        runner = lambda: engine.run(state, cfg, tc)
    else:
        from repro.analysis import jaxpr_audit
        mesh = shard_sim.make_mesh(n_shards)
        runner = lambda: shard_sim.run_sharded(state, cfg, tc, mesh)
        inv = jaxpr_audit.audit(
            shard_sim.sharded_step_jaxpr(state, cfg, tc, mesh))
        counts = {p: inv.count(frozenset({p}))
                  for p in sorted(jaxpr_audit.COLLECTIVE_PRIMS)
                  if inv.count(frozenset({p}))}
        rec["collectives_per_macro_step"] = counts
        rec["collective_total"] = sum(counts.values())
    out = jax.block_until_ready(runner())          # compile + warm
    t0 = time.perf_counter()
    out = jax.block_until_ready(runner())
    dt = time.perf_counter() - t0
    ev = int(out.events)
    rec.update(events=ev, events_per_s=ev / dt,
               events_per_s_per_device=ev / dt / n_shards)
    return rec


def shard_scaling(devices=(1, 2, 8), n_servers=65536, n_jobs=600,
                  verbose=True):
    """Devices-{1,2,8} throughput curve for one farm, each point in a
    fresh subprocess so XLA_FLAGS can pin its virtual CPU device count.
    On a single-core host the virtual devices timeshare one core, so the
    curve measures sharding OVERHEAD there, not speedup — the recorded
    host_cpus field says which regime produced the numbers."""
    import os
    import subprocess
    import sys
    rec = {"n_servers": n_servers, "host_cpus": os.cpu_count() or 1,
           "devices": {}}
    for k in devices:
        env = dict(os.environ,
                   XLA_FLAGS=f"--xla_force_host_platform_device_count={k}")
        env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
        r = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_engine",
             "--shard-point", f"{k},{n_servers},{n_jobs}"],
            env=env, capture_output=True, text=True, timeout=1800)
        if r.returncode:
            raise RuntimeError(f"shard point k={k} failed:\n"
                               f"{r.stdout}{r.stderr}")
        point = json.loads(r.stdout.splitlines()[-1])
        rec["devices"][str(k)] = point
        if verbose:
            cc = point.get("collective_total", 0)
            row(f"bench_engine_shard_k{k}",
                1e6 / max(point["events_per_s"], 1e-9),
                f"events/s={point['events_per_s']:.0f} "
                f"per_device={point['events_per_s_per_device']:.0f} "
                f"collectives/step={cc}")
    base = rec["devices"].get("1")
    if base:
        # the regression guard keys on events_per_s: use the 1-device
        # point (the stablest) as the guarded number
        rec["events_per_s"] = base["events_per_s"]
        for k, point in rec["devices"].items():
            point["speedup_vs_1"] = (point["events_per_s"]
                                     / max(base["events_per_s"], 1e-9))
    return rec


def run(verbose=True, sizes=(64, 512, 4096, 20480, 65536), smoke=False):
    out = {"smoke": smoke}
    if smoke:
        # the 20480-server point rides in smoke too (ROADMAP scale check:
        # not re-measured since the scatter elimination) — same 600-job
        # budget as the full run, ~10 s post-compile at ~120 ev/s
        sizes = (64, 20480)
    for n in sizes:
        # repeats=1: best-of includes a post-jit run, so the sweep tracks
        # the engine's steady-state events/s (the macro-stepping engine
        # compiles a noticeably larger program, which used to drown the
        # n512 point in one-shot compile time; perf_cases already
        # measured warm)
        eps, res = one_farm(n, n_jobs=600, repeats=1)
        out[f"n{n}"] = {"events_per_s": eps, "finished": res.n_finished}
        if verbose:
            row(f"bench_engine_n{n}", 1e6 / eps,
                f"events/s={eps:.0f} finished={res.n_finished}")
    out["perf"] = perf_cases(repeats=1 if smoke else 2, verbose=verbose)
    # rack-sharded scaling curve (core/shard_sim.py): the guarded
    # perf case uses the same 4096-server farm in smoke and full runs so
    # the CI comparison is like-for-like; the full run also records the
    # 65536-server acceptance curve (unguarded — its 8-device point is
    # dominated by collective emulation cost on low-core hosts)
    out["perf"]["shard_scaling"] = shard_scaling(
        n_servers=4096, n_jobs=200, verbose=verbose)
    if not smoke:
        out["shard_scaling_n65536"] = shard_scaling(
            n_servers=65536, n_jobs=600, verbose=verbose)
    tro = trace_overhead(repeats=1 if smoke else 2)
    out["perf"]["trace_overhead"] = tro      # under the --check guard
    if verbose:
        row("bench_engine_trace",
            1e6 / max(tro["events_per_s"], 1e-9),
            f"off={tro['events_per_s_off']:.0f}ev/s "
            f"on={tro['events_per_s']:.0f}ev/s "
            f"overhead={tro['overhead_frac']:.1%}")
    therm = thermal_overhead(repeats=1 if smoke else 2)
    out["thermal"] = therm
    if verbose:
        row("bench_engine_thermal",
            1e6 / max(therm["events_per_s_tracking"], 1e-9),
            f"off={therm['events_per_s_off']:.0f}ev/s "
            f"tracking={therm['events_per_s_tracking']:.0f}ev/s "
            f"(+{therm['overhead_frac_tracking']:.1%}) "
            f"throttling={therm['events_per_s_throttling']:.0f}ev/s "
            f"(+{therm['overhead_frac_throttling']:.1%})")
    tel = telemetry_overhead(repeats=1 if smoke else 2)
    out["telemetry"] = tel
    if verbose:
        row("bench_engine_telemetry",
            1e6 / max(tel["events_per_s_on"], 1e-9),
            f"off={tel['events_per_s_off']:.0f}ev/s "
            f"on={tel['events_per_s_on']:.0f}ev/s "
            f"overhead={tel['overhead_frac']:.1%}")
    if not smoke:
        eps, _ = replica_throughput()
        out["replicas8"] = {"events_per_s": eps}
        if verbose:
            row("bench_engine_replicas8", 1e6 / eps,
                f"agg_events/s={eps:.0f}")
        # the ROADMAP >1000-replica vmapped sweep, re-measured after the
        # task-major scatter elimination: a medium (64 x 64-server) batch
        # and the 1024-replica small-farm point that shard_maps across a
        # mesh (here it exercises the vmapped-while path on one device)
        eps, _ = replica_throughput(n_replicas=64, n_servers=64,
                                    n_jobs=200, max_jobs=256)
        out["replicas64"] = {"events_per_s": eps}
        if verbose:
            row("bench_engine_replicas64", 1e6 / eps,
                f"agg_events/s={eps:.0f}")
        eps, _ = replica_throughput(n_replicas=1024, n_servers=16,
                                    n_jobs=100, max_jobs=128)
        out["replicas1024"] = {"events_per_s": eps}
        if verbose:
            row("bench_engine_replicas1024", 1e6 / eps,
                f"agg_events/s={eps:.0f}")
    return out


def check_regression(fresh, committed_path, tol=0.30):
    """CI guard: every perf.* case in ``fresh`` must reach at least
    (1 - tol) of the committed BENCH_engine.json value.  Returns a list
    of failure strings (empty = pass)."""
    try:
        with open(committed_path) as f:
            committed = json.load(f)
    except FileNotFoundError:
        return [f"committed record {committed_path} not found"]
    failures = []
    for case, rec in committed.get("perf", {}).items():
        if case not in fresh.get("perf", {}):
            continue
        base = rec.get("events_per_s")
        got = fresh["perf"][case].get("events_per_s")
        if base is None or got is None:
            continue
        if got < (1.0 - tol) * base:
            failures.append(
                f"perf.{case}: {got:.0f} ev/s < {(1 - tol):.0%} of "
                f"committed {base:.0f} ev/s")
    return failures


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: perf acceptance configs + the 64-server "
                         "point only (skips the 20K-server sweep)")
    ap.add_argument("--out", default="BENCH_engine.json",
                    help="where to write the JSON record")
    ap.add_argument("--check", metavar="COMMITTED.json", default=None,
                    help="fail (exit 1) if any perf.* case drops >30%% "
                         "below the committed record at this path")
    ap.add_argument("--shard-point", metavar="K,N_SERVERS,N_JOBS",
                    default=None,
                    help="internal: measure ONE shard-scaling point in "
                         "this process (launched by shard_scaling with "
                         "XLA_FLAGS pinning K virtual devices) and print "
                         "its JSON record")
    args = ap.parse_args(argv)
    if args.shard_point:
        k, n_servers, n_jobs = map(int, args.shard_point.split(","))
        print(json.dumps(shard_point(k, n_servers, n_jobs)))
        return None
    out = run(smoke=args.smoke)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")
    print(json.dumps(out, indent=1))
    if args.check:
        failures = check_regression(out, args.check)
        if failures:
            for msg in failures:
                print(f"BENCH REGRESSION: {msg}")
            raise SystemExit(1)
        print(f"bench regression guard: all perf cases within 30% of "
              f"{args.check}")
    return out


if __name__ == "__main__":
    main()

"""Engine scalability (paper Table I: >20K servers).

Measures events/second of the jitted engine as the farm grows, and the
replica-parallel throughput (vmapped Monte-Carlo batch — the axis that
shard_maps across a TPU mesh).  The per-event cost of the dense engine is
O(state) but it executes at VPU width; the paper's Java heap engine is
O(log n) pointer chasing — crossover favors the dense engine once replicas
or farm width amortize the streaming.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from .common import row
from repro.core import engine, farm as farm_mod, montecarlo, workload
from repro.core.jobs import dag_single
from repro.core.types import SimConfig, SleepPolicy, TelemetryConfig


def one_farm(n_servers, n_jobs=1000, seed=0, telemetry=True):
    cfg = SimConfig(n_servers=n_servers, n_cores=4, local_q=64,
                    max_jobs=max(n_jobs, 16), tasks_per_job=1,
                    sleep_policy=SleepPolicy.ALWAYS_ON,
                    max_events=20_000,
                    telemetry=TelemetryConfig(enabled=telemetry))
    rng = np.random.default_rng(seed)
    lam = workload.utilization_to_rate(0.5, 0.01, n_servers, 4)
    arr = workload.poisson_arrivals(lam, n_jobs, seed=seed)
    specs = [dag_single(rng.exponential(0.01)) for _ in range(n_jobs)]
    t0 = time.time()
    res = farm_mod.simulate(cfg, arr, specs)
    dt = time.time() - t0
    return res.events / dt, res


def telemetry_overhead(n_servers=512, n_jobs=600, repeats=2):
    """Wall-clock cost of the instrumented step: events/s with telemetry
    off vs on (best of ``repeats``, post-jit).  Tracked in the perf
    trajectory; the acceptance budget is <15% overhead."""
    eps = {}
    for mode in (False, True):
        best = 0.0
        for r in range(repeats + 1):    # first rep includes jit compile
            # same seed every rep: repeats re-time the identical jitted
            # computation rather than different workload instances
            e, _ = one_farm(n_servers, n_jobs=n_jobs, seed=0,
                            telemetry=mode)
            best = max(best, e)
        eps[mode] = best
    return {"events_per_s_off": eps[False], "events_per_s_on": eps[True],
            "overhead_frac": eps[False] / max(eps[True], 1e-9) - 1.0}


def replica_throughput(n_replicas=8, n_servers=64, n_jobs=400):
    cfg = SimConfig(n_servers=n_servers, n_cores=4, local_q=64,
                    max_jobs=512, tasks_per_job=1,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=10_000)
    rng = np.random.default_rng(1)
    lam = workload.utilization_to_rate(0.5, 0.01, n_servers, 4)
    arrs = np.stack([workload.poisson_arrivals(lam, n_jobs, seed=s)
                     for s in range(n_replicas)])
    specs = [dag_single(rng.exponential(0.01)) for _ in range(n_jobs)]
    state_b, tc = montecarlo.batched_state(cfg, arrs, specs)
    t0 = time.time()
    out = montecarlo.run_replicas(cfg, state_b, tc)
    jax.block_until_ready(out.t)
    dt = time.time() - t0
    ev = int(np.asarray(out.events).sum())
    return ev / dt, out


def run(verbose=True, sizes=(64, 512, 4096, 20480)):
    out = {}
    for n in sizes:
        eps, res = one_farm(n, n_jobs=600)
        out[f"n{n}"] = {"events_per_s": eps, "finished": res.n_finished}
        if verbose:
            row(f"bench_engine_n{n}", 1e6 / eps,
                f"events/s={eps:.0f} finished={res.n_finished}")
    eps, _ = replica_throughput()
    out["replicas8"] = {"events_per_s": eps}
    if verbose:
        row("bench_engine_replicas8", 1e6 / eps, f"agg_events/s={eps:.0f}")
    tel = telemetry_overhead()
    out["telemetry"] = tel
    if verbose:
        row("bench_engine_telemetry", 1e6 / max(tel["events_per_s_on"], 1e-9),
            f"off={tel['events_per_s_off']:.0f}ev/s "
            f"on={tel['events_per_s_on']:.0f}ev/s "
            f"overhead={tel['overhead_frac']:.1%}")
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))

"""Case study A (paper §IV-A, Fig 4): threshold-driven resource
provisioning tracks a fluctuating (Wikipedia-like diurnal) load.

Claim reproduced: the number of enabled servers follows the job arrival
rate; active-server count stabilizes between the load thresholds.
"""
from __future__ import annotations

import numpy as np

from .common import WEB_SEARCH_SVC, make_jobs, row, timed, wiki_arrivals
from repro.core import farm as farm_mod
from repro.core.types import SchedPolicy, SimConfig, SleepPolicy, SrvState


def run(n_jobs=3000, seed=0, verbose=True):
    cfg = SimConfig(n_servers=50, n_cores=4, max_jobs=4096, tasks_per_job=1,
                    sched_policy=SchedPolicy.PROVISIONED,
                    sleep_policy=SleepPolicy.SINGLE_TIMER,
                    sleep_state=SrvState.PKG_C6,
                    prov_lo=0.3, prov_hi=0.9, max_events=100_000)
    rng = np.random.default_rng(seed)
    # paper: execution times 3-10ms
    specs = [
        __import__("repro.core.jobs", fromlist=["dag_single"]).dag_single(
            rng.uniform(0.003, 0.010)) for _ in range(n_jobs)]
    arr = wiki_arrivals(n_jobs, rho=0.35, cfg=cfg, mean_svc=0.0065,
                        seed=seed)
    res, dt = timed(farm_mod.simulate, cfg, arr, specs, tau=0.05)

    # "tracking": active-state residency should be far below always-on
    # (servers put aside) while all jobs still finish
    frac_sleeping = res.residency[:, SrvState.PKG_C6].sum() \
        / res.residency.sum()
    ts = res.telemetry        # device-side histograms / QoS (telemetry.py)
    stats = {
        "finished": res.n_finished, "n_jobs": res.n_jobs,
        "mean_power_W": res.mean_power,
        "p95_ms": res.p95_latency * 1e3,
        "hist_p99_ms": ts.job_p99 * 1e3,
        "ed_product_Js": ts.energy_delay_product,
        "tail_violations": ts.tail_violations,
        "frac_time_sleeping": frac_sleeping,
        "events": res.events, "wall_s": dt,
    }
    if verbose:
        row("case_a_provisioning", dt / max(res.events, 1) * 1e6,
            f"finished={res.n_finished}/{res.n_jobs} "
            f"sleep_frac={frac_sleeping:.2f} p95={res.p95_latency*1e3:.1f}ms "
            f"p99={ts.job_p99*1e3:.1f}ms ED={ts.energy_delay_product:.1f}J.s")
    assert res.n_finished == res.n_jobs
    assert frac_sleeping > 0.3, "provisioning failed to park servers"
    return stats


if __name__ == "__main__":
    print(run())

"""CI smoke for the flight recorder's export path.

Runs the 512-server acceptance farm with tracing (and telemetry, so the
counter tracks exercise too) enabled, exports the ring as a Chrome-trace
JSON (the Perfetto artifact CI uploads), and validates the document
against the Chrome trace event format schema: every entry must carry a
phase, duration/instant/counter events must carry name + ts, and the
task duration events must cover every finished task.  Exits nonzero on
any violation so a silently-broken export fails the build rather than
shipping an unloadable artifact.

Usage::

    PYTHONPATH=src python -m benchmarks.trace_smoke [--out trace.json]
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from repro.core import engine, traceio, workload
from repro.core.jobs import build_jobs
from repro.core.types import (SimConfig, SleepPolicy, TelemetryConfig,
                              TraceConfig, TraceKind)
from benchmarks.bench_engine import dag_single

REQUIRED_PHASES = {"X": ("name", "ts", "dur", "pid", "tid"),
                   "i": ("name", "ts", "pid", "tid"),
                   "C": ("name", "ts", "args"),
                   "M": ("name", "args")}


def build_trace(n_servers=512, n_jobs=600, seed=0):
    cfg = SimConfig(n_servers=n_servers, n_cores=4, local_q=64,
                    max_jobs=max(n_jobs, 16), tasks_per_job=1,
                    sleep_policy=SleepPolicy.ALWAYS_ON,
                    max_events=20_000,
                    trace=TraceConfig(enabled=True),
                    telemetry=TelemetryConfig(enabled=True))
    rng = np.random.default_rng(seed)
    specs = [dag_single(rng.exponential(0.01)) for _ in range(n_jobs)]
    lam = workload.utilization_to_rate(0.5, 0.01, n_servers, cfg.n_cores)
    arr = workload.poisson_arrivals(lam, n_jobs, seed=seed)
    jt = build_jobs(cfg, np.asarray(arr), specs)
    state, tc = engine.init_state(cfg, jt)
    final = engine.run(state, cfg, tc)
    return cfg, final


def validate(doc, path) -> list:
    """Schema violations in an exported Chrome-trace document (JSON
    object format: {"traceEvents": [...]})."""
    errors = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return [f"{path}: document is not a JSON object with traceEvents"]
    events = doc["traceEvents"]
    if not isinstance(events, list) or not events:
        return [f"{path}: traceEvents is not a non-empty array"]
    n_by_phase = {}
    for i, e in enumerate(events):
        ph = e.get("ph")
        if ph is None:
            errors.append(f"entry {i}: missing 'ph'")
            continue
        n_by_phase[ph] = n_by_phase.get(ph, 0) + 1
        for field in REQUIRED_PHASES.get(ph, ()):
            if field not in e:
                errors.append(f"entry {i} (ph={ph}): missing '{field}'")
        if ph == "X" and e.get("dur", 0) < 0:
            errors.append(f"entry {i}: negative duration {e['dur']}")
    # 'i' events only exist when the ring holds instant kinds (sleeps,
    # drops, thermal crossings, flows) — not in every config
    for ph in ("M", "X"):
        if n_by_phase.get(ph, 0) == 0:
            errors.append(f"no '{ph}' events in document")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="trace_smoke.json",
                    help="exported Chrome-trace path")
    ap.add_argument("--servers", type=int, default=512)
    ap.add_argument("--jobs", type=int, default=600)
    args = ap.parse_args(argv)

    cfg, final = build_trace(args.servers, args.jobs)
    ev, n_drop = traceio.decode(final.trace, cfg)
    if len(ev) == 0:
        print("trace_smoke: FAIL — empty ring after a 600-job run")
        return 1
    traceio.save_chrome_trace(args.out, ev, cfg, state=final,
                              n_dropped=n_drop)
    with open(args.out) as f:           # validate what actually landed
        doc = json.load(f)
    errors = validate(doc, args.out)

    n_task = sum(1 for e in doc.get("traceEvents", [])
                 if e.get("ph") == "X" and e.get("cat") != "flow")
    n_fin = int((ev["kind"] == TraceKind.FINISH).sum())
    if n_task < n_fin:
        errors.append(f"{n_task} task duration events < "
                      f"{n_fin} FINISH records in the ring")

    if errors:
        print(f"trace_smoke: FAIL — {len(errors)} schema violation(s)")
        for msg in errors[:20]:
            print(f"  - {msg}")
        return 1
    print(f"trace_smoke: OK — {len(doc['traceEvents'])} entries "
          f"({n_task} task spans, {len(ev)} ring records, "
          f"{n_drop} dropped) -> {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

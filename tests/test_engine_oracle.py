"""Event-for-event validation of the vectorized engine against the
sequential heapq oracle (DESIGN.md §3: semantics preserved exactly)."""
import numpy as np
import pytest

from repro.core import farm as farm_mod
from repro.core import workload
from repro.core.jobs import dag_chain, dag_fanout, dag_single
from repro.core.types import (SchedPolicy, SimConfig, SleepPolicy,
                              SrvState)

from oracle import OracleSim


def _run_both(cfg, arr, specs, tau=None):
    res = farm_mod.simulate(cfg, arr, specs, tau=tau)
    orc = OracleSim(cfg, arr, specs, tau=tau).run()
    return res, orc


def _compare(res, orc, n_jobs, energy_rtol=2e-3):
    lat_o = orc.latencies()
    assert res.n_finished == n_jobs
    assert len(lat_o) == n_jobs
    np.testing.assert_allclose(np.sort(res.latencies), np.sort(lat_o),
                               rtol=1e-4, atol=1e-4)
    assert res.server_energy == pytest.approx(orc.total_energy(),
                                              rel=energy_rtol)


@pytest.mark.parametrize("policy,tau,sleep_state", [
    (SleepPolicy.ALWAYS_ON, None, SrvState.S3),
    (SleepPolicy.SINGLE_TIMER, 0.05, SrvState.S3),
    (SleepPolicy.SINGLE_TIMER, 0.02, SrvState.PKG_C6),
])
def test_single_task_jobs_match_oracle(policy, tau, sleep_state):
    n_jobs = 200
    cfg = SimConfig(n_servers=6, n_cores=2, max_jobs=256, tasks_per_job=1,
                    sched_policy=SchedPolicy.LOAD_BALANCE,
                    sleep_policy=policy, sleep_state=sleep_state,
                    max_events=50_000)
    rng = np.random.default_rng(7)
    arr = workload.poisson_arrivals(120.0, n_jobs, seed=3)
    specs = [dag_single(rng.exponential(0.02)) for _ in range(n_jobs)]
    res, orc = _run_both(cfg, arr, specs, tau=tau)
    _compare(res, orc, n_jobs)
    wakes = np.asarray([s.wake_count for s in orc.servers])
    np.testing.assert_array_equal(res.wake_count, wakes)


def test_round_robin_matches_oracle():
    n_jobs = 150
    cfg = SimConfig(n_servers=5, n_cores=1, max_jobs=256, tasks_per_job=1,
                    sched_policy=SchedPolicy.ROUND_ROBIN,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=50_000)
    rng = np.random.default_rng(11)
    arr = workload.poisson_arrivals(60.0, n_jobs, seed=5)
    specs = [dag_single(rng.exponential(0.03)) for _ in range(n_jobs)]
    res, orc = _run_both(cfg, arr, specs)
    _compare(res, orc, n_jobs)


def test_dag_chain_matches_oracle():
    n_jobs = 80
    cfg = SimConfig(n_servers=4, n_cores=2, max_jobs=128, tasks_per_job=3,
                    sched_policy=SchedPolicy.LOAD_BALANCE,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=50_000)
    rng = np.random.default_rng(13)
    arr = workload.poisson_arrivals(40.0, n_jobs, seed=6)
    specs = [dag_chain(rng.exponential(0.01, size=3)) for _ in range(n_jobs)]
    res, orc = _run_both(cfg, arr, specs)
    _compare(res, orc, n_jobs)


def test_dag_fanout_matches_oracle():
    n_jobs = 60
    cfg = SimConfig(n_servers=4, n_cores=2, max_jobs=64, tasks_per_job=4,
                    sched_policy=SchedPolicy.LOAD_BALANCE,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=50_000)
    rng = np.random.default_rng(17)
    arr = workload.poisson_arrivals(30.0, n_jobs, seed=8)
    specs = [dag_fanout(rng.exponential(0.005),
                        rng.exponential(0.01, size=2),
                        rng.exponential(0.005)) for _ in range(n_jobs)]
    res, orc = _run_both(cfg, arr, specs)
    _compare(res, orc, n_jobs)


def test_dual_timer_pools_match_oracle():
    n_jobs = 150
    N = 6
    cfg = SimConfig(n_servers=N, n_cores=2, max_jobs=256, tasks_per_job=1,
                    sched_policy=SchedPolicy.LOAD_BALANCE,
                    sleep_policy=SleepPolicy.DUAL_TIMER,
                    sleep_state=SrvState.S3, max_events=50_000)
    rng = np.random.default_rng(23)
    arr = workload.poisson_arrivals(80.0, n_jobs, seed=9)
    specs = [dag_single(rng.exponential(0.02)) for _ in range(n_jobs)]
    tau = np.where(np.arange(N) < N // 2, 1.0, 0.01)   # high-τ pool first
    pools = (np.arange(N) >= N // 2).astype(np.int32)

    res = farm_mod.simulate(cfg, arr, specs, tau=tau, pools=pools)
    orc = OracleSim(cfg, arr, specs, tau=tau)
    for s, p in zip(orc.servers, pools):
        s.pool = int(p)
    orc.run()
    _compare(res, orc, n_jobs)

"""The static auditor itself (src/repro/analysis/).

Planted-violation fixtures: synthetic jaxprs that each break exactly one
pinned contract (scatter in a forbidden region, stray collective, host
callback, degraded clock, baseline drift) must trip the matching rule with
the offending source location.  Plus a green run of the full rule set on a
real engine config, and the retrace sentinel plumbing.
"""
import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.analysis import jaxpr_audit, retrace, rules
from repro.analysis.jaxpr_audit import (
    CALLBACK_PRIMS, COLLECTIVE_PRIMS, SCATTER_PRIMS, audit, clock_audit)
from repro.core.types import pytree_dataclass
from repro.sharding.compat import shard_map

THIS_FILE = "test_analysis.py"


def _one(violations, rule_name):
    """Exactly one violation, from the named rule, located in this file."""
    assert len(violations) == 1, violations
    v = violations[0]
    assert v.rule == rule_name
    located = [s for s in v.sites if THIS_FILE in s]
    assert located, (v.message, v.sites)
    return v


# ==========================================================================
# planted violations
# ==========================================================================

def test_planted_scatter_in_forbidden_region():
    def step(x, idx):
        with jax.named_scope("cheap_core"):
            return x.at[idx].set(0.0)

    jx = jax.make_jaxpr(step)(jnp.zeros(8), jnp.array([1]))
    inv = audit(jx)
    rule = rules.ForbidPrimitive(
        name="cheap-core-scatter-free", prims=SCATTER_PRIMS,
        region="cheap_core")
    v = _one(rule.check("fixture", inv, None), "cheap-core-scatter-free")
    assert "scatter" in v.message
    # the same scatter OUTSIDE the region does not fire
    jx2 = jax.make_jaxpr(lambda x, i: x.at[i].set(0.0))(
        jnp.zeros(8), jnp.array([1]))
    assert rule.check("fixture", audit(jx2), None) == []


def test_planted_stray_psum():
    mesh = jax.make_mesh((1,), ("racks",))

    def step(x):
        return jax.lax.psum(x, "racks")

    fn = shard_map(step, mesh=mesh, in_specs=P("racks"), out_specs=P())
    jx = jax.make_jaxpr(fn)(jnp.zeros(4))
    inv = audit(jx)
    rule = rules.ForbidPrimitive(
        name="no-other-collectives",
        prims=COLLECTIVE_PRIMS - {"all_gather"})
    v = _one(rule.check("fixture", inv, None), "no-other-collectives")
    assert "psum" in v.message


def test_planted_host_callback():
    def step(x):
        return jax.pure_callback(
            lambda a: np.asarray(a) * 2,
            jax.ShapeDtypeStruct((4,), jnp.float32), x)

    jx = jax.make_jaxpr(step)(jnp.zeros(4, jnp.float32))
    inv = audit(jx)
    rule = rules.ForbidPrimitive(
        name="no-host-callbacks", prims=CALLBACK_PRIMS)
    _one(rule.check("fixture", inv, None), "no-host-callbacks")


@pytree_dataclass
class TinyState:
    t: jnp.ndarray      # declared clock leaf (keystr suffix ".t")
    x: jnp.ndarray


def test_planted_degraded_clock():
    tmpl = TinyState(t=jnp.zeros((), jnp.float32),
                     x=jnp.zeros(4, jnp.float32))

    def step(s):
        # clock round-trips through f16: precision silently lost
        bad = s.t.astype(jnp.float16).astype(jnp.float32)
        return TinyState(t=bad + 1.0, x=s.x * 2.0)

    report = clock_audit(jax.make_jaxpr(step)(tmpl), tmpl, jnp.float32)
    violations = rules.DtypePolicy().check_clock("fixture", report)
    v = _one(violations, "clock-dtype-policy")
    assert ".t" in v.message and "downcast" in v.message

    # the identical downcast inside a declared f32_domain scope is an
    # intentional physics exit — no violation
    def step_tagged(s):
        with jax.named_scope(jaxpr_audit.F32_DOMAIN):
            phys = s.t.astype(jnp.float16).astype(jnp.float32)
        return TinyState(t=s.t + 1.0, x=s.x + phys)

    report2 = clock_audit(
        jax.make_jaxpr(step_tagged)(tmpl), tmpl, jnp.float32)
    assert rules.DtypePolicy().check_clock("fixture", report2) == []
    assert report2.degraded_leaves == []


def test_planted_clock_census_violation():
    tmpl = TinyState(t=jnp.zeros((), jnp.float32),
                     x=jnp.zeros(4, jnp.float32))

    def step(s):
        return TinyState(t=s.t.astype(jnp.float16), x=s.x)

    report = clock_audit(jax.make_jaxpr(step)(tmpl), tmpl, jnp.float32)
    bad = rules.DtypePolicy().check_clock("fixture", report)
    # fires as BOTH a census violation and a detected downcast
    assert bad and any("has dtype float16" in v.message for v in bad)
    assert all(v.rule == "clock-dtype-policy" for v in bad)


def test_planted_baseline_drift():
    def v1(x):
        return x * 2.0

    def v2(x):
        return jnp.exp(x) * 2.0  # structural drift: a new primitive

    inv1 = audit(jax.make_jaxpr(v1)(jnp.zeros(4)))
    inv2 = audit(jax.make_jaxpr(v2)(jnp.zeros(4)))
    entry = rules.baseline_entry_from(inv1)
    rule = rules.NoNewPrimitives()
    assert rule.check("fixture", inv1, entry) == []
    v = _one(rule.check("fixture", inv2, entry), "no-new-primitives")
    assert "exp" in v.message
    # an explicit waiver silences exactly that drift
    entry["waivers"] = [{"config": "fixture", "prim": "exp",
                         "reason": "test waiver"}]
    assert rule.check("fixture", inv2, entry) == []
    # missing baseline is itself a violation (forces --update)
    missing = rule.check("fixture", inv2, None)
    assert missing and "run --update" in missing[0].message


def test_exact_count_reports_mismatch_with_sites():
    def step(x, i):
        y = x.at[i].set(1.0)
        return y.at[i].add(2.0)

    inv = audit(jax.make_jaxpr(step)(jnp.zeros(8), jnp.array([1])))
    rule = rules.ExactCount(
        name="one-all-gather-per-sharded-leaf", prims=SCATTER_PRIMS,
        expect=1)
    v = _one(rule.check("fixture", inv, None),
             "one-all-gather-per-sharded-leaf")
    assert "expected exactly 1" in v.message


# ==========================================================================
# walker mechanics
# ==========================================================================

def test_region_provenance_inherits_into_sub_jaxprs():
    def f(x):
        def hot(v):
            with jax.named_scope("cheap_core"):
                return v.at[0].set(v[1] * 3.0)

        return jax.lax.cond(x[0] > 0, hot, lambda v: v, x)

    inv = audit(jax.make_jaxpr(f)(jnp.zeros(4)))
    hits = inv.sites_of(SCATTER_PRIMS, "cheap_core")
    assert hits, inv.histogram()
    assert inv.count(SCATTER_PRIMS, "cheap_core") == \
        inv.count(SCATTER_PRIMS)


def test_clock_taint_through_while_carry():
    tmpl = TinyState(t=jnp.zeros((), jnp.float32),
                     x=jnp.zeros((), jnp.float32))

    def step(s):
        # degradation enters the carry on iteration 1 and must still be
        # seen at the output (fixpoint propagation)
        def body(c):
            t, k = c
            t = jnp.where(k == 1,
                          t.astype(jnp.float16).astype(jnp.float32), t)
            return t, k + 1

        t, _ = jax.lax.while_loop(lambda c: c[1] < 3, body,
                                  (s.t, jnp.int32(0)))
        return TinyState(t=t, x=s.x)

    report = clock_audit(jax.make_jaxpr(step)(tmpl), tmpl, jnp.float32)
    assert [leaf for leaf, _ in report.degraded_leaves] == [".t"]


# ==========================================================================
# retrace sentinel plumbing
# ==========================================================================

def test_retrace_guard_counts_only_inside_guard():
    retrace.note_trace("tag", ("outside",))  # guard off: ignored
    with retrace.retrace_guard() as retraced:
        retrace.note_trace("engine.run", ("k1",))
        retrace.note_trace("engine.run", ("k1",))
        retrace.note_trace("engine.run", ("k2",))
        hits = retraced()
    assert len(hits) == 1 and hits[0]["traces"] == 2
    assert "k1" in hits[0]["key"]
    # guard exited: counting off again
    retrace.note_trace("tag", ("after",))
    with retrace.retrace_guard() as retraced:
        assert retraced() == []


# ==========================================================================
# the real engine, green end to end
# ==========================================================================

def test_real_engine_config_passes_full_rule_set():
    """One real config through the exact rule set the CI simlint job
    applies: zero violations against a baseline pinned from itself, and
    the committed repo baseline stays in sync when the jax version
    matches."""
    import json
    import os

    from repro.analysis import matrix, simlint

    case = matrix.build_case("policy_load_balance")
    inv = audit(case.closed_jaxpr)
    report = clock_audit(case.closed_jaxpr, case.state_template,
                         case.time_dtype)
    entry = rules.baseline_entry_from(inv)
    violations = []
    for rule in simlint._rules_for(case, entry, advisory=False):
        violations.extend(rule.check(case.name, inv, entry))
    violations.extend(rules.DtypePolicy().check_clock(case.name, report))
    assert violations == [], "\n".join(v.render() for v in violations)
    # the scatter-free contract is a real budget, not vacuous
    assert inv.count(SCATTER_PRIMS, "cheap_core") > 0
    assert inv.count(COLLECTIVE_PRIMS) == 0
    assert inv.count(CALLBACK_PRIMS) == 0

    path = os.path.join(os.path.dirname(__file__), "..",
                        "ANALYSIS_BASELINE.json")
    committed = rules.load_baseline(path)
    assert "policy_load_balance" in committed["cases"]
    if committed["jax"] == jax.__version__:
        pinned = committed["cases"]["policy_load_balance"]
        assert rules.NoNewPrimitives().check(
            "policy_load_balance", inv, pinned) == [], (
            "committed ANALYSIS_BASELINE.json is stale — rerun "
            "PYTHONPATH=src python -m repro.analysis.simlint "
            "--update ANALYSIS_BASELINE.json")
    assert isinstance(json.dumps(entry), str)  # entry is JSON-clean

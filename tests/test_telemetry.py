"""Device-side telemetry subsystem: histogram percentiles vs the exact
host-side oracle on an M/M/k scenario (both the jnp reference path and the
fused Pallas kernel), window-series conservation laws, QoS/SLA counters,
and the vmapped replica path."""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import farm, montecarlo, telemetry, workload
from repro.core.jobs import dag_single
from repro.core.types import INF, SimConfig, SleepPolicy, TelemetryConfig
from repro.kernels import ref
from repro.kernels.telemetry_bin import telemetry_accum

# tight bins so "within one bin width" is a meaningful tolerance:
# ratio between adjacent edges = (10/1e-4)^(1/128) ~ 1.094
TEL = TelemetryConfig(n_bins=128, lat_lo=1e-4, lat_hi=10.0,
                      n_windows=128, window_dt=0.05, tail_thresh=0.04)


def _mmk_run(sla=INF, tel=TEL, n_jobs=400):
    """Poisson arrivals + exponential service on k parallel servers."""
    cfg = SimConfig(n_servers=4, n_cores=2, local_q=64, max_jobs=512,
                    tasks_per_job=1, sleep_policy=SleepPolicy.ALWAYS_ON,
                    max_events=20_000, telemetry=tel)
    rng = np.random.default_rng(0)
    lam = workload.utilization_to_rate(0.6, 0.01, 4, 2)
    arr = workload.poisson_arrivals(lam, n_jobs, seed=1)
    specs = [dag_single(rng.exponential(0.01), sla=sla)
             for _ in range(n_jobs)]
    return cfg, farm.simulate(cfg, arr, specs)


def _bin_ratio(tcfg):
    return (tcfg.lat_hi / tcfg.lat_lo) ** (1.0 / tcfg.n_bins)


def _assert_within_one_bin(approx, exact, tcfg):
    r = _bin_ratio(tcfg)
    assert exact / r <= approx <= exact * r, (approx, exact, r)


def test_histogram_percentiles_match_oracle_mmk():
    """Engine-accumulated histogram p50/p95/p99 within one (log) bin of the
    exact percentiles over the same finished jobs."""
    cfg, res = _mmk_run()
    assert res.telemetry is not None
    assert res.telemetry.jobs_binned == res.n_finished
    for q, approx in [(50, res.telemetry.job_p50),
                      (95, res.telemetry.job_p95),
                      (99, res.telemetry.job_p99)]:
        exact = float(np.percentile(res.latencies, q,
                                    method="inverted_cdf"))
        _assert_within_one_bin(approx, exact, cfg.telemetry)
    # single-task jobs: task histogram == job histogram
    assert res.telemetry.tasks_binned == res.telemetry.jobs_binned
    _assert_within_one_bin(
        res.telemetry.task_p95,
        float(np.percentile(res.latencies, 95, method="inverted_cdf")),
        cfg.telemetry)


def test_pallas_kernel_percentiles_match_oracle_mmk():
    """The same latencies pushed through the fused Pallas kernel
    (interpret mode) recover oracle percentiles within one bin."""
    cfg, res = _mmk_run()
    tcfg = cfg.telemetry
    lat = jnp.asarray(res.latencies, jnp.float32)
    w = jnp.ones_like(lat)
    B, K, W = tcfg.n_bins, telemetry.WIN_COLS, 4
    jh, th, _ = telemetry_accum(
        lat, w, lat, w, jnp.zeros((B,), jnp.float32),
        jnp.zeros((B,), jnp.float32), jnp.zeros((W, K), jnp.float32),
        jnp.asarray(0, jnp.int32), jnp.zeros((K,), jnp.float32),
        tcfg.lat_lo, tcfg.lat_hi, interpret=True)
    np.testing.assert_allclose(np.asarray(jh), np.asarray(th))
    for q in (50, 95, 99):
        approx = float(telemetry.hist_percentile(
            np.asarray(jh), tcfg.lat_lo, tcfg.lat_hi, q))
        _assert_within_one_bin(
            approx,
            float(np.percentile(res.latencies, q, method="inverted_cdf")),
            tcfg)
    # kernel histogram == engine (jnp path) histogram on identical inputs:
    # engine bins (finish - arrival) in f32, res.latencies is that value
    eng = ref.telemetry_accum_reference(
        lat, w, lat, w, jnp.zeros((B,), jnp.float32),
        jnp.zeros((B,), jnp.float32), jnp.zeros((W, K), jnp.float32),
        jnp.asarray(0, jnp.int32), jnp.zeros((K,), jnp.float32),
        tcfg.lat_lo, tcfg.lat_hi)[0]
    np.testing.assert_allclose(np.asarray(jh), np.asarray(eng))


def test_window_series_conservation():
    """Windowed series integrate exactly: occupancy sums to sim time and
    the power column integrates back to the accrued energy."""
    cfg, res = _mmk_run()
    ts = res.telemetry
    assert ts.occupancy.sum() == pytest.approx(res.sim_time, rel=1e-5)
    with warnings.catch_warnings():
        warnings.simplefilter("error")      # no NaN-warnings allowed
        joules = np.nansum(ts.server_power * ts.occupancy)
    assert joules == pytest.approx(res.server_energy, rel=1e-4)
    # state residency columns also integrate to N * sim_time
    assert ts.state_residency.sum() == pytest.approx(
        cfg.n_servers * res.sim_time, rel=1e-5)
    # always-on farm: awake server average == N in every occupied window
    occ = ts.occupancy > 0
    np.testing.assert_allclose(ts.awake_servers[occ], cfg.n_servers,
                               rtol=1e-5)


def test_sla_and_tail_counters():
    # generous SLA: no misses
    _, res_ok = _mmk_run(sla=100.0)
    assert res_ok.telemetry.sla_total == res_ok.n_finished
    assert res_ok.telemetry.sla_miss == 0
    # impossible SLA (below min service time): every job misses
    _, res_bad = _mmk_run(sla=1e-7)
    assert res_bad.telemetry.sla_miss == res_bad.n_finished
    # tail threshold at 0.04s: matches the exact count
    exact_tail = int((res_ok.latencies > TEL.tail_thresh).sum())
    assert res_ok.telemetry.tail_violations == exact_tail
    # no SLA at all -> nothing tracked
    _, res_none = _mmk_run(sla=INF)
    assert res_none.telemetry.sla_total == 0
    assert res_none.telemetry.sla_miss_rate == 0.0


def test_telemetry_disabled_path():
    cfg, res = _mmk_run(tel=TelemetryConfig(enabled=False))
    assert res.telemetry is None
    assert res.n_finished == 400         # dynamics unaffected


def test_replica_stats_from_device_histograms():
    """run_replicas vmaps cleanly with Telemetry in state; per-replica
    percentiles come from the (R, B) histograms within one bin of exact."""
    cfg = SimConfig(n_servers=4, n_cores=2, local_q=64, max_jobs=128,
                    tasks_per_job=1, sleep_policy=SleepPolicy.ALWAYS_ON,
                    max_events=10_000, telemetry=TEL)
    n_jobs, R = 80, 3
    rng = np.random.default_rng(0)
    specs = [dag_single(rng.exponential(0.01)) for _ in range(n_jobs)]
    arrs = np.stack([workload.poisson_arrivals(150.0, n_jobs, seed=s)
                     for s in range(R)])
    state_b, tc = montecarlo.batched_state(cfg, arrs, specs)
    out = montecarlo.run_replicas(cfg, state_b, tc)
    stats = montecarlo.replica_stats(out, cfg)
    assert (stats["finished"] == n_jobs).all()
    for r in range(R):
        solo = farm.simulate(cfg, arrs[r], specs)
        for q, key in [(50, "p50_latency"), (95, "p95_latency"),
                       (99, "p99_latency")]:
            _assert_within_one_bin(stats[key][r],
                                   float(np.percentile(solo.latencies, q,
                                                       method="inverted_cdf")),
                                   cfg.telemetry)


def test_replica_stats_empty_replica_no_warnings():
    """A replica finishing zero jobs yields NaN stats without numpy
    RuntimeWarnings (the montecarlo bugfix)."""
    cfg = SimConfig(n_servers=2, n_cores=1, local_q=8, max_jobs=16,
                    tasks_per_job=1, sleep_policy=SleepPolicy.ALWAYS_ON,
                    max_events=1, events_per_step=1,   # too few events to
                    telemetry=TEL)                     # finish anything
    n_jobs, R = 8, 2
    rng = np.random.default_rng(0)
    specs = [dag_single(rng.exponential(0.01)) for _ in range(n_jobs)]
    arrs = np.stack([workload.poisson_arrivals(50.0, n_jobs, seed=s)
                     for s in range(R)])
    state_b, tc = montecarlo.batched_state(cfg, arrs, specs)
    out = montecarlo.run_replicas(cfg, state_b, tc)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        stats = montecarlo.replica_stats(out, cfg)
    assert (stats["finished"] == 0).all()
    assert np.isnan(stats["mean_latency"]).all()
    assert np.isnan(stats["p99_latency"]).all()


def test_empty_histogram_summary_is_nan_clean():
    """A run that bins ZERO jobs (here: everything still carbon-deferred
    when max_events truncates the run) must summarize to NaN percentiles
    and a NaN energy·delay product with no numpy RuntimeWarnings — the
    empty-histogram path of telemetry.summarize/hist_percentile."""
    from repro.core.types import SchedPolicy, ThermalConfig
    tcfg = ThermalConfig(enabled=True, carbon_base=300.0, carbon_swing=0.2,
                         carbon_period=600.0, defer_threshold=100.0)
    cfg = SimConfig(n_servers=2, n_cores=1, max_jobs=16, tasks_per_job=1,
                    sched_policy=SchedPolicy.CARBON_AWARE,
                    sleep_policy=SleepPolicy.ALWAYS_ON,
                    max_events=2, events_per_step=1,  # stop mid-deferral
                    thermal=tcfg, telemetry=TEL)
    specs = [dag_single(1.0, deferrable=True, defer_slack=1e6)
             for _ in range(4)]
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        res = farm.simulate(cfg, np.zeros(4), specs)
        ts = res.telemetry
    assert res.n_finished == 0 and ts.jobs_binned == 0
    for v in (ts.job_p50, ts.job_p95, ts.job_p99, ts.task_p50,
              ts.mean_latency, ts.energy_delay_product):
        assert np.isnan(v)
    assert ts.sla_total == 0 and ts.tail_violations == 0

    # zero-arrival run: histograms AND windows are empty
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        res0 = farm.simulate(
            SimConfig(n_servers=2, n_cores=1, max_jobs=16,
                      tasks_per_job=1, max_events=100, telemetry=TEL),
            np.empty(0), [])
        ts0 = res0.telemetry
        # the whole window block divides by an all-NaN occupancy
        assert ts0.n_windows_used == 0
        assert np.isnan(ts0.active_jobs).all()
    assert np.isnan(ts0.job_p50) and np.isnan(ts0.energy_delay_product)

    # direct empty-histogram helpers
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        h = np.zeros((3, 64))
        assert np.isnan(telemetry.hist_percentile(h, 1e-4, 10.0, 95)).all()
        assert np.isnan(telemetry.hist_mean(h, 1e-4, 10.0)).all()


def test_summary_qos_and_ed_product():
    cfg, res = _mmk_run()
    ts = res.telemetry
    # E·D: energy × histogram-mean latency; mean within a bin of exact
    exact_mean = float(res.latencies.mean())
    r = _bin_ratio(cfg.telemetry)
    assert exact_mean / r <= ts.mean_latency <= exact_mean * r
    total_e = res.server_energy + res.switch_energy
    assert ts.energy_delay_product == pytest.approx(
        total_e * ts.mean_latency, rel=1e-6)
    assert ts.n_windows_used > 0


def test_window_horizon_spillover_flagged():
    """A run that outlives the n_windows·window_dt horizon clamps its
    tail into the last window: win_overflow accrues the clamped seconds,
    the last window's time-averaged series are NaN-ed as contaminated,
    and the raw integrals still conserve total sim time.  (Regression:
    previously the contamination was silent.)"""
    tel = TelemetryConfig(n_bins=64, lat_lo=1e-4, lat_hi=10.0,
                          n_windows=8, window_dt=0.05)   # 0.4 s horizon
    cfg = SimConfig(n_servers=1, n_cores=1, max_jobs=8, tasks_per_job=1,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=2_000,
                    telemetry=tel)
    # 2x the horizon: last job arrives at 0.7 and runs 0.1 s
    res = farm.simulate(cfg, np.asarray([0.0, 0.7]),
                        [dag_single(0.1), dag_single(0.1)])
    ts = res.telemetry
    assert res.sim_time == pytest.approx(0.8, rel=1e-5)
    assert ts.win_overflow > 0.0
    assert ts.last_window_contaminated
    assert np.isnan(ts.queue_depth[-1]) and np.isnan(ts.server_power[-1])
    # only the LAST window was poisoned — earlier occupied windows stay
    assert np.isfinite(ts.server_power[:-1]).any()
    # conservation on the raw integrals is untouched by the NaN-ing
    assert ts.occupancy.sum() == pytest.approx(res.sim_time, rel=1e-5)

    # control: a run inside the horizon stays clean
    short = farm.simulate(cfg, np.asarray([0.0]), [dag_single(0.1)])
    assert short.telemetry.win_overflow == 0.0
    assert not short.telemetry.last_window_contaminated

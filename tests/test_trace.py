"""Device-side flight recorder validation:

  * the jitted ring buffer matches the heapq oracle's event stream
    event-for-event (kind, time, server, tid) via traceio.diff_traces,
    for events_per_step 1 and 8, with sleep timers + throttling armed
  * the trace ring itself is macro-step invariant: K=1 and K=8 produce
    leaf-EXACT final states INCLUDING the ring, under the full control
    plane (setpoints + controller + ambient + deferral + throttling)
  * tracing disabled is statically absent: every non-trace state leaf is
    bit-identical to the enabled run, and the placeholder ring is (1,)
  * wrap-around: a tiny capacity keeps the most recent records and
    counts evictions exactly (ptr - capacity == oracle total - capacity)
  * host-side consumers: lifecycle spans + critical-path decomposition
    reconstruct per-job latency exactly; the Chrome-trace export is
    valid JSON with metadata/duration/instant/counter records
  * run provenance: simulate(profile=True) fills SimResult.run_info
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, farm as farm_mod, traceio, workload
from repro.core.jobs import build_jobs, dag_chain, dag_single
from repro.core.types import (SchedPolicy, SimConfig, SleepPolicy,
                              SrvState, TelemetryConfig, ThermalConfig,
                              TraceConfig, TraceKind)

from oracle import OracleSim

HOT = dict(enabled=True, r_th=0.5, tau_th=2.0, t_inlet=22.0, recirc=0.2,
           rack_size=3)


def _workload(n_jobs=150, lam=60.0, seed=3, svc_seed=7, mean=0.02):
    rng = np.random.default_rng(svc_seed)
    arr = workload.poisson_arrivals(lam, n_jobs, seed=seed)
    specs = [dag_single(rng.exponential(mean)) for _ in range(n_jobs)]
    return arr, specs


def _rich_cfg(**kw):
    """Sleep timers + thermal throttling: one run exercises arrival,
    admit, start, finish, job_finish, wakeup, sleep, and
    throttle_crossing records."""
    tcfg = ThermalConfig(**HOT, t_throttle=50.0, t_release=45.0,
                         throttle_freq=0.5, throttle_power_scale=0.6,
                         carbon_period=600.0, price_period=600.0)
    return SimConfig(n_servers=6, n_cores=2, max_jobs=256, tasks_per_job=1,
                     sched_policy=SchedPolicy.LOAD_BALANCE,
                     sleep_policy=SleepPolicy.SINGLE_TIMER,
                     sleep_state=SrvState.S3, max_events=60_000,
                     thermal=tcfg, trace=TraceConfig(enabled=True), **kw)


def _run_engine(cfg, arr, specs, tau=None):
    jt = build_jobs(cfg, np.asarray(arr), specs)
    state, tc = engine.init_state(cfg, jt)
    if tau is not None:
        state = dataclasses.replace(state, farm=dataclasses.replace(
            state.farm,
            srv_tau=jnp.full((cfg.n_servers,), tau, cfg.time_dtype)))
    return engine.run(state, cfg, tc)


@pytest.mark.parametrize("k", [1, 8])
def test_trace_matches_oracle_event_for_event(k):
    """Acceptance: the decoded ring agrees with the heapq oracle's
    emission on kind/time/server/tid for every record, at K=1 and K=8."""
    cfg = _rich_cfg(events_per_step=k)
    arr, specs = _workload()
    res = farm_mod.simulate(cfg, arr, specs, tau=0.05)
    orc = OracleSim(cfg, arr, specs, tau=0.05).run()
    assert res.n_finished == len(arr)
    assert res.trace_dropped == 0
    assert len(res.trace_events) == len(orc.trace)
    msg = traceio.diff_traces(res.trace_events,
                              traceio.as_events(orc.trace),
                              time_tol=5e-3)
    assert msg is None, msg
    kinds = set(res.trace_events["kind"].tolist())
    for needed in (TraceKind.ARRIVAL, TraceKind.ADMIT, TraceKind.START,
                   TraceKind.FINISH, TraceKind.JOB_FINISH,
                   TraceKind.WAKEUP, TraceKind.SLEEP,
                   TraceKind.THROTTLE_CROSSING):
        assert needed in kinds, TraceKind.NAMES[needed]


def test_trace_k_sweep_leaf_exact_with_control_plane():
    """The ring is macro-step invariant: emission happens per applied
    event, not per step, so K=1 and K=8 runs are leaf-exact INCLUDING
    the trace — under setpoints + controller + diurnal ambient +
    CARBON_AWARE deferral + throttling (release/ctrl_tick records)."""
    tcfg = ThermalConfig(**HOT, t_setpoint=(16.0, 24.0),
                         ambient_swing=3.0, ambient_period=40.0,
                         ctrl_period=0.5, ctrl_target=55.0,
                         t_throttle=58.0, t_release=52.0,
                         throttle_freq=0.5, throttle_power_scale=0.6,
                         carbon_base=300.0, carbon_swing=0.6,
                         carbon_period=60.0, defer_threshold=330.0)
    cfg0 = SimConfig(n_servers=6, n_cores=2, max_jobs=256, tasks_per_job=1,
                     sched_policy=SchedPolicy.CARBON_AWARE,
                     sleep_policy=SleepPolicy.SINGLE_TIMER,
                     sleep_state=SrvState.PKG_C6, max_events=80_000,
                     thermal=tcfg, trace=TraceConfig(enabled=True))
    rng = np.random.default_rng(7)
    n = 120
    arr = workload.wiki_like_trace(n, 4.0, period=60.0, swing=0.5, seed=3)
    specs = [dag_single(rng.exponential(0.05), deferrable=(j % 2 == 0),
                        defer_slack=30.0) for j in range(n)]
    outs = {k: _run_engine(dataclasses.replace(cfg0, events_per_step=k),
                           arr, specs, tau=0.5)
            for k in (1, 8)}
    # steps counts while-loop iterations, which is exactly what K trades
    # away — every OTHER leaf (including the ring) must be bit-equal
    norm = {k: dataclasses.replace(v, steps=jnp.zeros((), jnp.int32))
            for k, v in outs.items()}
    for (kp, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(norm[1]),
            jax.tree_util.tree_leaves_with_path(norm[8])):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"K=8 vs K=1: leaf {jax.tree_util.keystr(kp)}")
    ev, _ = traceio.decode(outs[1].trace, cfg0)
    kinds = set(ev["kind"].tolist())
    assert TraceKind.RELEASE in kinds and TraceKind.CTRL_TICK in kinds
    assert int(outs[1].thermal.defer_count) > 0


def test_trace_off_bit_identical_and_statically_absent():
    """cfg.trace.enabled=False must not perturb the simulation at all
    (every non-trace leaf bit-identical) and must cost nothing: the
    placeholder ring is a (1, 5) stub that never advances."""
    cfg_on = _rich_cfg()
    cfg_off = dataclasses.replace(cfg_on, trace=TraceConfig())
    arr, specs = _workload(n_jobs=100)
    on = _run_engine(cfg_on, arr, specs, tau=0.05)
    off = _run_engine(cfg_off, arr, specs, tau=0.05)
    a = dataclasses.replace(on, trace=None)
    b = dataclasses.replace(off, trace=None)
    for (kp, la), (_, lb) in zip(jax.tree_util.tree_leaves_with_path(a),
                                 jax.tree_util.tree_leaves_with_path(b)):
        np.testing.assert_array_equal(
            np.asarray(la), np.asarray(lb),
            err_msg=f"on vs off: leaf {jax.tree_util.keystr(kp)}")
    assert off.trace.buf.shape == (1, 5)
    assert int(off.trace.ptr) == 0 and int(off.trace.dropped) == 0
    assert int(on.trace.ptr) > 0


def test_trace_ring_wraparound_counts_drops_exactly():
    """A 64-slot ring under thousands of events keeps the most recent 64
    records and counts every eviction: dropped == total_emitted - 64,
    with total_emitted cross-checked against the oracle's stream."""
    cap = 64
    cfg = dataclasses.replace(
        _rich_cfg(), trace=TraceConfig(enabled=True, capacity=cap))
    arr, specs = _workload()
    res = farm_mod.simulate(cfg, arr, specs, tau=0.05)
    orc = OracleSim(cfg, arr, specs, tau=0.05).run()
    total = len(orc.trace)
    assert total > cap
    assert res.trace_dropped == total - cap
    assert len(res.trace_events) == cap
    # the survivors are the newest records: none predates the oracle's
    # (total-cap)-th emission (times are nondecreasing in both streams)
    t_floor = float(orc.trace[total - cap][0])
    assert (res.trace_events["time"] >= t_floor - 5e-3).all()


def test_lifecycle_spans_and_critical_path():
    """Two 2-chains contending for one core: spans tile each task's
    queued->running->finish, and the critical-path decomposition
    (queueing + service + flow) reconstructs each job's latency
    exactly."""
    cfg = SimConfig(n_servers=1, n_cores=1, max_jobs=8, tasks_per_job=2,
                    max_children=2, sleep_policy=SleepPolicy.ALWAYS_ON,
                    max_events=1_000, trace=TraceConfig(enabled=True))
    arr = np.asarray([0.0, 0.1])
    specs = [dag_chain([0.5, 0.25]), dag_chain([0.5, 0.25])]
    final = _run_engine(cfg, arr, specs)
    ev, n_drop = traceio.decode(final.trace, cfg)
    assert n_drop == 0

    spans = traceio.lifecycle_spans(ev, final, cfg)
    assert len(spans) == 4
    for s in spans:
        q0, q1 = s["queued"]
        r0, r1 = s["running"]
        assert q0 <= q1 == r0 <= r1
        svc = 0.5 if s["tid"] % 2 == 0 else 0.25
        assert r1 - r0 == pytest.approx(svc, rel=1e-4)
        assert s["server"] == 0

    cp = traceio.critical_path(ev, final, cfg)
    assert [c["job"] for c in cp] == [0, 1]
    for c in cp:
        assert c["path"] == [c["job"] * 2, c["job"] * 2 + 1]
        assert c["flow"] == 0.0
        assert c["queueing"] + c["service"] == pytest.approx(
            c["latency"], rel=1e-4, abs=1e-4)
        assert c["service"] == pytest.approx(0.75, rel=1e-4)
    # one core serializes 1.5 s of work: somebody queued
    assert max(c["queueing"] for c in cp) > 0.1


def test_chrome_trace_export_schema(tmp_path):
    """The exported Chrome-trace JSON round-trips and carries metadata
    (process/thread rows), one duration event per START record, instant
    events, and telemetry-backed counter tracks."""
    cfg = dataclasses.replace(
        _rich_cfg(),
        telemetry=TelemetryConfig(n_windows=64, window_dt=0.2))
    arr, specs = _workload(n_jobs=60)
    final = _run_engine(cfg, arr, specs, tau=0.05)
    ev, n_drop = traceio.decode(final.trace, cfg)
    path = tmp_path / "trace.json"
    traceio.save_chrome_trace(str(path), ev, cfg, state=final,
                              n_dropped=n_drop)
    loaded = json.loads(path.read_text())
    assert loaded["otherData"]["n_servers"] == cfg.n_servers
    assert loaded["otherData"]["trace_dropped"] == n_drop
    tes = loaded["traceEvents"]
    assert {"M", "X", "i", "C"} <= {e["ph"] for e in tes}
    for e in tes:
        assert "ph" in e and "pid" in e
        if e["ph"] == "X":
            assert e["dur"] >= 0.0 and e["ts"] >= 0.0
    n_started = int(np.sum(ev["kind"] == TraceKind.START))
    assert len([e for e in tes if e["ph"] == "X"]) == n_started
    thread_rows = [e for e in tes
                   if e["ph"] == "M" and e["name"] == "thread_name"]
    assert len(thread_rows) == cfg.n_servers


def test_diff_traces_reports_first_divergence():
    """diff_traces localizes the first mismatch with kind/time/server,
    and tolerates same-instant reordering + sub-tolerance time skew."""
    a = traceio.as_events([(0.0, TraceKind.ARRIVAL, -1, 0, 0.0),
                           (0.0, TraceKind.ADMIT, 2, 0, 0.0),
                           (1.0, TraceKind.START, 2, 0, 0.5)])
    # same instant swapped + 1e-5 skew: still a match
    b = traceio.as_events([(1e-5, TraceKind.ADMIT, 2, 0, 0.0),
                           (0.0, TraceKind.ARRIVAL, -1, 0, 0.0),
                           (1.0, TraceKind.START, 2, 0, 0.5)])
    assert traceio.diff_traces(a, b, time_tol=1e-4) is None
    # wrong server on the START record
    c = traceio.as_events([(0.0, TraceKind.ARRIVAL, -1, 0, 0.0),
                           (0.0, TraceKind.ADMIT, 2, 0, 0.0),
                           (1.0, TraceKind.START, 3, 0, 0.5)])
    msg = traceio.diff_traces(a, c, time_tol=1e-4)
    assert msg is not None and "event #2" in msg and "start" in msg
    # length mismatch
    msg = traceio.diff_traces(a, b[:2], time_tol=1e-4)
    assert msg is not None and "extra event" in msg


def test_run_info_provenance():
    """simulate(profile=True) splits compile from steady-state wall time
    and records steps/events/throughput/backend plus a JSON-safe config
    dump."""
    cfg = SimConfig(n_servers=2, n_cores=1, max_jobs=16, tasks_per_job=1,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=2_000)
    res = farm_mod.simulate(cfg, np.asarray([0.0, 0.1]),
                            [dag_single(0.2), dag_single(0.2)],
                            profile=True)
    ri = res.run_info
    assert ri is not None
    assert ri.wall_s > 0.0
    assert ri.events == res.events > 0
    assert ri.steps > 0
    assert ri.events_per_s == pytest.approx(ri.events / ri.wall_s)
    assert isinstance(ri.backend, str) and ri.backend
    assert np.isfinite(ri.jit_compile_s) and ri.jit_compile_s >= 0.0
    assert ri.config["n_servers"] == 2
    assert ri.config["trace"]["enabled"] is False
    json.dumps(ri.config)        # fully JSON-serializable

"""Network-flow fidelity + same-timestamp arrival batching:

  * the equal-share fluid model matches the heapq oracle's flow model on
    a star topology event-for-event (bytes drain exactly between events —
    the advance_flows fix; previously every intervening event pushed
    done_at later and re-charged the latency budget)
  * flow-slot exhaustion no longer deadlocks: a tiny-max_flows DAG config
    completes, drop-resolves the edges, and matches the oracle's drop
    semantics (flows_dropped counted, children unblocked immediately)
  * same-timestamp arrival bursts are admitted in one pass against a
    shared scheduler snapshot, matching the oracle's batched admission,
    and the vectorized admit equals the sequential scalar admit
"""
import dataclasses

import numpy as np
import pytest

from repro.core import engine, farm as farm_mod, topology, traceio, workload
from repro.core.jobs import build_jobs, dag_chain, dag_single
from repro.core.types import SchedPolicy, SimConfig, SleepPolicy, TraceConfig

from oracle import OracleSim


def _star_cfg(max_flows, n_jobs=30, vectorized=True):
    # ROUND_ROBIN splits every 2-task chain across servers, so each job
    # routes one flow over the star; link caps make transfers overlap
    return SimConfig(n_servers=6, n_cores=2, max_jobs=64, tasks_per_job=2,
                     max_children=2, max_flows=max_flows, local_q=32,
                     sched_policy=SchedPolicy.ROUND_ROBIN,
                     sleep_policy=SleepPolicy.ALWAYS_ON,
                     has_network=True, comm_model=0, max_events=60_000,
                     use_vectorized_hot_loop=vectorized)


def _star_workload(n_jobs=30, seed=2):
    rng = np.random.default_rng(seed)
    arr = workload.poisson_arrivals(25.0, n_jobs, seed=seed)
    specs = [dag_chain(rng.uniform(0.01, 0.04, size=2),
                       edge_bytes=float(rng.uniform(4e6, 8e6)))
             for _ in range(n_jobs)]
    return arr, specs


def test_fluid_flows_match_oracle_star():
    """Ample slots: overlapping flows share links; latencies, flow
    accounting, AND the full event stream (flow spawns/finishes
    included) must match the sequential fluid oracle."""
    n_jobs = 30
    cfg = dataclasses.replace(_star_cfg(max_flows=64, n_jobs=n_jobs),
                              trace=TraceConfig(enabled=True))
    topo = topology.star(cfg.n_servers, link_cap=1.0e8)
    arr, specs = _star_workload(n_jobs)
    res = farm_mod.simulate(cfg, arr, specs, topo=topo)
    orc = OracleSim(cfg, arr, specs, topo=topo).run()
    assert res.n_finished == n_jobs == len(orc.job_finish)
    assert res.flows_dropped == orc.flows_dropped == 0
    msg = traceio.diff_traces(res.trace_events,
                              traceio.as_events(orc.trace),
                              time_tol=1e-3)
    assert msg is None, msg
    from repro.core.types import TraceKind
    kinds = set(res.trace_events["kind"].tolist())
    assert TraceKind.FLOW_SPAWN in kinds
    assert TraceKind.FLOW_FINISH in kinds
    np.testing.assert_allclose(np.sort(res.latencies),
                               np.sort(orc.latencies()),
                               rtol=1e-4, atol=1e-4)
    assert res.server_energy == pytest.approx(orc.total_energy(), rel=2e-3)


@pytest.mark.parametrize("vectorized", [True, False])
def test_flow_slot_exhaustion_matches_oracle(vectorized):
    """max_flows=2 under ~10 concurrent transfers: before the fix the
    spawn silently vanished and the child stayed BLOCKED forever (the sim
    spun to max_events).  Now the edge drop-resolves like a queue drop."""
    n_jobs = 30
    cfg = _star_cfg(max_flows=2, n_jobs=n_jobs, vectorized=vectorized)
    topo = topology.star(cfg.n_servers, link_cap=1.0e8)
    arr, specs = _star_workload(n_jobs)
    res = farm_mod.simulate(cfg, arr, specs, topo=topo)
    orc = OracleSim(cfg, arr, specs, topo=topo).run()

    assert res.events < cfg.max_events            # terminates, no deadlock
    assert res.n_finished == n_jobs == len(orc.job_finish)
    assert res.flows_dropped == orc.flows_dropped > 0
    np.testing.assert_allclose(np.sort(res.latencies),
                               np.sort(orc.latencies()),
                               rtol=1e-4, atol=1e-4)


def test_flow_exhaustion_vectorized_matches_scalar():
    n_jobs = 25
    cfg = _star_cfg(max_flows=3, n_jobs=n_jobs)
    topo = topology.star(cfg.n_servers, link_cap=1.0e8)
    arr, specs = _star_workload(n_jobs, seed=5)
    jt = build_jobs(cfg, np.asarray(arr), specs)
    outs = []
    for vec in (True, False):
        c = dataclasses.replace(cfg, use_vectorized_hot_loop=vec)
        state, tc = engine.init_state(c, jt, topo)
        outs.append(engine.run(state, c, tc))
    import jax
    for name, lv, ls in zip(
            [".".join(str(p) for p in kp) for kp, _ in
             jax.tree_util.tree_leaves_with_path(outs[0])],
            jax.tree.leaves(outs[0]), jax.tree.leaves(outs[1])):
        np.testing.assert_allclose(
            np.asarray(lv, np.float64), np.asarray(ls, np.float64),
            rtol=1e-6, atol=1e-6, err_msg=f"state leaf {name} diverged")
    assert int(outs[0].flows.flows_dropped) > 0


# --------------------------------------------------------------------------
# same-timestamp arrival batching
# --------------------------------------------------------------------------

def _burst_workload(n_bursts=6, burst=5, gap=0.3, seed=11, mean=0.03):
    """Bursts of exactly-tied arrival timestamps (the MMPP-high shape)."""
    rng = np.random.default_rng(seed)
    arr = np.repeat(np.arange(1, n_bursts + 1) * gap, burst)
    specs = [dag_single(rng.exponential(mean))
             for _ in range(n_bursts * burst)]
    return arr, specs


@pytest.mark.parametrize("policy", [SchedPolicy.LOAD_BALANCE,
                                    SchedPolicy.ROUND_ROBIN])
def test_same_time_bursts_match_oracle(policy):
    """Tied arrivals admit in one pass against a shared load snapshot —
    the oracle batches identically."""
    arr, specs = _burst_workload()
    cfg = SimConfig(n_servers=4, n_cores=2, max_jobs=64, tasks_per_job=1,
                    sched_policy=policy,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=40_000,
                    arrivals_per_step=8)
    res = farm_mod.simulate(cfg, arr, specs)
    orc = OracleSim(cfg, arr, specs).run()
    assert res.n_finished == len(arr) == len(orc.job_finish)
    np.testing.assert_allclose(np.sort(res.latencies),
                               np.sort(orc.latencies()),
                               rtol=1e-4, atol=1e-4)
    assert res.server_energy == pytest.approx(orc.total_energy(), rel=2e-3)


def test_burst_larger_than_admit_cap_matches_oracle():
    """Bursts beyond arrivals_per_step admit in chunks, each against a
    fresh snapshot with the previous chunk's roots drained — the oracle
    chunks identically (exact while a chunk's roots fit ready_per_step)."""
    arr, specs = _burst_workload(n_bursts=3, burst=12, seed=19)
    cfg = SimConfig(n_servers=4, n_cores=2, max_jobs=64, tasks_per_job=1,
                    sched_policy=SchedPolicy.LOAD_BALANCE,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=40_000,
                    arrivals_per_step=8, ready_per_step=8)
    res = farm_mod.simulate(cfg, arr, specs)
    orc = OracleSim(cfg, arr, specs).run()
    assert res.n_finished == len(arr) == len(orc.job_finish)
    np.testing.assert_allclose(np.sort(res.latencies),
                               np.sort(orc.latencies()),
                               rtol=1e-4, atol=1e-4)


def test_burst_admission_vectorized_matches_scalar():
    """Property: the batched multi-job admit equals K sequential scalar
    picks against the same snapshot (both inside one step)."""
    arr, specs = _burst_workload(n_bursts=5, burst=7, seed=13)
    for policy in (SchedPolicy.LOAD_BALANCE, SchedPolicy.ROUND_ROBIN):
        cfg = SimConfig(n_servers=5, n_cores=1, max_jobs=64,
                        tasks_per_job=1, sched_policy=policy,
                        sleep_policy=SleepPolicy.ALWAYS_ON,
                        max_events=40_000, arrivals_per_step=8)
        jt = build_jobs(cfg, np.asarray(arr), specs)
        outs = []
        for vec in (True, False):
            c = dataclasses.replace(cfg, use_vectorized_hot_loop=vec)
            state, tc = engine.init_state(c, jt)
            outs.append(engine.run(state, c, tc))
        import jax
        for lv, ls in zip(jax.tree.leaves(outs[0]),
                          jax.tree.leaves(outs[1])):
            np.testing.assert_allclose(
                np.asarray(lv, np.float64), np.asarray(ls, np.float64),
                rtol=1e-6, atol=1e-6)


def test_burst_spreads_under_load_balance():
    """Regression: a same-timestamp burst under LOAD_BALANCE must spread
    across servers exactly like the one-job-per-step path (each pick sees
    the previous jobs' committed roots), not pile onto the single
    pre-batch argmin server."""
    rng = np.random.default_rng(23)
    arr = np.full(8, 1.0)
    specs = [dag_single(float(rng.uniform(0.4, 0.6))) for _ in range(8)]
    base = SimConfig(n_servers=4, n_cores=2, max_jobs=16, tasks_per_job=1,
                     sched_policy=SchedPolicy.LOAD_BALANCE,
                     sleep_policy=SleepPolicy.ALWAYS_ON, max_events=10_000)
    fast = farm_mod.simulate(
        dataclasses.replace(base, arrivals_per_step=8), arr, specs)
    slow = farm_mod.simulate(
        dataclasses.replace(base, arrivals_per_step=1), arr, specs)
    # 8 jobs onto 8 cores: every job starts immediately, so each latency
    # equals its service time (piling onto one 2-core server would queue
    # 6 of them); and the batched path equals the one-per-step path
    np.testing.assert_allclose(np.sort(fast.latencies),
                               np.sort([s.service[0] for s in specs]),
                               rtol=1e-5)
    np.testing.assert_allclose(np.sort(fast.latencies),
                               np.sort(slow.latencies), rtol=1e-6)
    assert fast.events < slow.events


def test_burst_batching_speeds_up_and_rr_invariant():
    """A burst no longer costs one step per job (events shrink), and for
    ROUND_ROBIN the batched admission is placement-identical to the
    one-per-step path."""
    arr, specs = _burst_workload(n_bursts=4, burst=8, seed=17)
    base = SimConfig(n_servers=4, n_cores=2, max_jobs=64, tasks_per_job=1,
                     sched_policy=SchedPolicy.ROUND_ROBIN,
                     sleep_policy=SleepPolicy.ALWAYS_ON, max_events=40_000)
    fast = farm_mod.simulate(
        dataclasses.replace(base, arrivals_per_step=8), arr, specs)
    slow = farm_mod.simulate(
        dataclasses.replace(base, arrivals_per_step=1), arr, specs)
    assert fast.n_finished == slow.n_finished == len(arr)
    assert fast.events < slow.events
    np.testing.assert_allclose(np.sort(fast.latencies),
                               np.sort(slow.latencies),
                               rtol=1e-5, atol=1e-6)
    assert fast.server_energy == pytest.approx(slow.server_energy,
                                               rel=1e-4)

import pathlib
import sys

# tests import the heapq oracle as a plain module; make the tests dir
# importable regardless of how pytest was invoked
sys.path.insert(0, str(pathlib.Path(__file__).parent))

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke
# tests and benches must see ONE real CPU device; only launch/dryrun.py
# requests 512 placeholder devices (and only for itself).


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-device subprocess tests (run explicitly)")

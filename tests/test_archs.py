"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
same-family config and runs one forward + one train step + one decode step
on CPU, asserting output shapes and finiteness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer
from repro.train import optim, step as step_lib

ARCHS = configs.list_archs()


def _inputs(cfg, B=2, S=16):
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    frames = jnp.ones((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16) \
        if cfg.is_enc_dec else None
    return tokens, frames


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_is_exact(arch):
    """The full-scale config matches the assignment sheet."""
    cfg = configs.get_config(arch)
    sheet = {
        "qwen3_moe_235b_a22b": (94, 4096, 64, 4, 151936),
        "moonshot_v1_16b_a3b": (48, 2048, 16, 16, 163840),
        "qwen1_5_4b": (40, 2560, 20, 20, 151936),
        "smollm_360m": (32, 960, 15, 5, 49152),
        "gemma2_9b": (42, 3584, 16, 8, 256000),
        "llama3_2_1b": (16, 2048, 32, 8, 128256),
        "hymba_1_5b": (32, 1600, 25, 5, 32001),
        "xlstm_350m": (24, 1024, 4, 4, 50304),
        "chameleon_34b": (48, 8192, 64, 8, 65536),
        "whisper_large_v3": (32, 1280, 20, 20, 51866),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.vocab) == sheet
    if arch == "qwen3_moe_235b_a22b":
        assert (cfg.n_experts, cfg.top_k, cfg.d_expert) == (128, 8, 1536)
    if arch == "moonshot_v1_16b_a3b":
        assert (cfg.n_experts, cfg.top_k, cfg.d_expert) == (64, 6, 1408)
    if arch == "hymba_1_5b":
        assert cfg.ssm_state == 16
    if arch == "gemma2_9b":
        assert cfg.block_pattern == ("swa", "attn")
    if arch == "whisper_large_v3":
        assert cfg.enc_layers == 32 and cfg.cross_attn


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = configs.get_smoke(arch)
    B, S = 2, 16
    max_seq = 32 if cfg.pos == "learned" else 0
    params, specs = transformer.make_params(cfg, jax.random.key(0), max_seq)
    tokens, frames = _inputs(cfg, B, S)
    logits, _, aux = transformer.forward(cfg, params, tokens, mode="train",
                                         frames=frames)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.float32(logits)).all()
    assert np.isfinite(float(aux))
    # specs mirror params structurally
    jax.tree.map(lambda p, s: None, params, specs,
                 is_leaf=lambda x: isinstance(x, tuple) and not
                 isinstance(x, dict))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = dataclasses.replace(configs.get_smoke(arch), microbatches=2)
    B, S = 4, 16
    max_seq = 32 if cfg.pos == "learned" else 0
    state = step_lib.init_state(cfg, jax.random.key(0), max_seq)
    opt_cfg = optim.AdamWConfig(warmup_steps=0)      # lr>0 from step 0
    ts = jax.jit(step_lib.make_train_step(cfg, opt_cfg=opt_cfg))
    tokens, frames = _inputs(cfg, B, S)
    batch = {"tokens": tokens, "labels": tokens}
    if frames is not None:
        batch["frames"] = frames
    state2, m = ts(state, batch)
    assert np.isfinite(float(m["loss"]))
    assert int(state2["step"]) == 1
    # parameters actually moved
    d = jax.tree.map(lambda a, b: float(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)).max()),
        state["params"], state2["params"])
    assert max(jax.tree.leaves(d)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = configs.get_smoke(arch)
    B, S = 2, 8
    max_seq = S + 8 if cfg.pos == "learned" else 0
    params, _ = transformer.make_params(cfg, jax.random.key(0), max_seq)
    cache, _ = transformer.init_cache(cfg, B, S + 8)
    ss = jax.jit(step_lib.make_serve_step(cfg))
    tok = jnp.ones((B, 1), jnp.int32)
    logits, cache = ss(params, cache, tok, 0)
    logits, cache = ss(params, cache, tok, 1)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.float32(logits)).all()


@pytest.mark.parametrize("arch", ["llama3_2_1b", "gemma2_9b", "hymba_1_5b",
                                  "xlstm_350m", "whisper_large_v3",
                                  "chameleon_34b", "smollm_360m",
                                  "qwen1_5_4b"])
def test_decode_matches_train_logits(arch):
    """prefill(S) + decode(S..S+2) == train forward at those positions."""
    cfg = configs.get_smoke(arch)
    B, S, extra = 2, 12, 3
    max_seq = S + extra if cfg.pos == "learned" else 0
    params, _ = transformer.make_params(cfg, jax.random.key(0), max_seq)
    tokens, frames = _inputs(cfg, B, S + extra)
    cache, _ = transformer.init_cache(cfg, B, S + extra)
    _, cache, _ = transformer.forward(cfg, params, tokens[:, :S],
                                      mode="prefill", cache=cache,
                                      frames=frames)
    for t in range(S, S + extra):
        dec, cache, _ = transformer.forward(cfg, params, tokens[:, t:t + 1],
                                            mode="decode", cache=cache,
                                            pos=t)
        full, _, _ = transformer.forward(cfg, params, tokens[:, :t + 1],
                                         mode="train", frames=frames)
        np.testing.assert_allclose(np.float32(dec[:, 0]),
                                   np.float32(full[:, t]),
                                   atol=5e-2, rtol=5e-2)


def test_param_counts_are_plausible():
    """Full configs land near their nameplate sizes (±30%)."""
    # moonshot: the ASSIGNED dims (48L × 64e × d_exp 1408) give ~29B total;
    # the hf nameplate "16B" corresponds to the real model's 27 layers.
    # We implement the assigned config verbatim.
    expect = {"qwen3_moe_235b_a22b": 235e9, "moonshot_v1_16b_a3b": 29e9,
              "qwen1_5_4b": 4e9, "smollm_360m": 360e6, "gemma2_9b": 9e9,
              "llama3_2_1b": 1.2e9, "hymba_1_5b": 1.5e9,
              "chameleon_34b": 34e9}
    for arch, n in expect.items():
        got = configs.get_config(arch).param_count()
        assert 0.7 * n < got < 1.4 * n, (arch, got, n)
    # MoE active counts
    q3 = configs.get_config("qwen3_moe_235b_a22b")
    assert q3.param_count(active_only=True) < 0.15 * q3.param_count()

"""Rack-major sharded execution (core/shard_sim.py).

The contract under test: ``run_sharded`` on ANY device count is
bit-identical — every state leaf, including the trace ring — to
``engine.run`` on one device, because each macro-step gathers the rack
shards and runs the unmodified event core on the full arrays.  Fast
tests pin the mesh-of-1 identity, the padding/provenance satellites, and
the jaxpr collective count; the slow subprocess test reruns the four
pinned policy configs on 8 virtual CPU devices.
"""
import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.core import engine, farm as farm_mod, jobs as jobs_mod, \
    shard_sim, workload
from repro.core.jobs import dag_single
from repro.core.types import (PartitionConfig, SchedPolicy, SimConfig,
                              SrvState, ThermalConfig, TraceConfig)
from repro.sharding import partition as mesh_lib


def _workload(n_jobs=80, lam=60.0, seed=3):
    rng = np.random.default_rng(seed)
    arr = workload.poisson_arrivals(lam, n_jobs, seed=seed)
    specs = [dag_single(rng.exponential(0.02)) for _ in range(n_jobs)]
    return arr, specs


# ==========================================================================
# pad_to_racks + inert filler rows
# ==========================================================================

def test_pad_to_racks_rounds_up_to_shardable_blocks():
    cfg = SimConfig(n_servers=13, n_cores=2,
                    thermal=ThermalConfig(enabled=True, rack_size=3))
    p = farm_mod.pad_to_racks(cfg, n_shards=4)
    # 13 real servers -> ceil(13 / (3*4)) * 12 = 24: whole racks of 3,
    # rack count (8) divisible by 4 shards
    assert p.n_servers == 24 and p.present == 13 and p.has_padding
    assert p.partition.n_shards == 4
    assert p.n_servers % (p.thermal.rack_size * 4) == 0
    # idempotent: already-padded config comes back unchanged
    assert farm_mod.pad_to_racks(p) is p
    # no thermal -> block is just the shard count
    cfg2 = SimConfig(n_servers=13, n_cores=2)
    p2 = farm_mod.pad_to_racks(cfg2, n_shards=8)
    assert p2.n_servers == 16 and p2.present == 13
    # already divisible -> untouched
    cfg3 = SimConfig(n_servers=16, n_cores=2,
                     partition=PartitionConfig(n_shards=8))
    assert farm_mod.pad_to_racks(cfg3) is cfg3


def test_padded_rows_boot_off_and_disabled():
    cfg = farm_mod.pad_to_racks(
        SimConfig(n_servers=5, n_cores=2), n_shards=8)
    jt = jobs_mod.build_jobs(cfg, np.zeros(1), [dag_single(0.01)])
    state, _ = engine.init_state(cfg, jt)
    st = np.asarray(state.farm.srv_state)
    en = np.asarray(state.farm.srv_enabled)
    assert (st[:5] == SrvState.IDLE).all() and en[:5].all()
    assert (st[5:] == SrvState.OFF).all() and not en[5:].any()
    assert int(state.sched.n_enabled) == 5


def test_padded_farm_matches_unpadded_results():
    """Filler rows are inert: same jobs finish with the same latencies,
    zero energy accrues on the pad, temps/telemetry stay masked."""
    base = SimConfig(n_servers=5, n_cores=2, max_jobs=64,
                     max_events=20_000,
                     sched_policy=SchedPolicy.LOAD_BALANCE)
    # pad for an 8-way layout but run unsharded (padding is a pure
    # layout change; sharded execution is pinned separately below)
    pad = dataclasses.replace(farm_mod.pad_to_racks(base, n_shards=8),
                              partition=PartitionConfig())
    arr, specs = _workload(n_jobs=50, lam=80.0)
    ra = farm_mod.simulate(base, arr, specs)
    rb = farm_mod.simulate(pad, arr, specs)
    assert rb.n_finished == ra.n_finished == 50
    assert np.allclose(rb.latencies, ra.latencies)
    assert np.isclose(rb.server_energy, ra.server_energy, rtol=1e-6)
    assert (np.asarray(rb.energy_per_server[5:]) == 0.0).all()
    assert (np.asarray(rb.wake_count[5:]) == 0).all()


# ==========================================================================
# RunInfo provenance + digest
# ==========================================================================

def test_run_info_provenance_and_digest():
    cfg = SimConfig(n_servers=4, n_cores=2, max_jobs=32, max_events=5000)
    arr, specs = _workload(n_jobs=10, lam=40.0)
    res = farm_mod.simulate(cfg, arr, specs)
    ri = res.run_info
    assert ri.devices == 1 and ri.mesh_shape == () and ri.sharding == ""
    assert len(ri.config_digest) == 40
    # the digest is an execution-mesh-free scenario id: changing the
    # shard count must not move it, changing the scenario must
    c8 = dataclasses.replace(cfg, partition=PartitionConfig(n_shards=8))
    assert farm_mod.config_digest(c8) == ri.config_digest
    c_other = dataclasses.replace(cfg, n_servers=8)
    assert farm_mod.config_digest(c_other) != ri.config_digest


# ==========================================================================
# mesh-of-1 identity + guards + jaxpr probe (single-device backend)
# ==========================================================================

def _built_state(cfg, arr, specs, topo=None):
    jt = jobs_mod.build_jobs(cfg, np.asarray(arr), specs)
    return engine.init_state(cfg, jt, topo)


def test_mesh_of_one_is_bitwise_engine_run():
    cfg = SimConfig(n_servers=8, n_cores=2, max_jobs=128,
                    max_events=20_000, trace=TraceConfig(enabled=True))
    arr, specs = _workload()
    state, tc = _built_state(cfg, arr, specs)
    ref = jax.block_until_ready(engine.run(state, cfg, tc))
    mesh = shard_sim.make_mesh(1)
    out = jax.block_until_ready(shard_sim.run_sharded(state, cfg, tc, mesh))
    la, lb = jax.tree.leaves(ref), jax.tree.leaves(out)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_sim_state_specs_mark_only_rack_major_axes():
    cfg = SimConfig(n_servers=8, n_cores=2, max_jobs=32, max_events=1000,
                    thermal=ThermalConfig(enabled=True, rack_size=2))
    arr, specs = _workload(n_jobs=5)
    state, _ = _built_state(cfg, arr, specs)
    mesh = shard_sim.make_mesh(1)
    ps = mesh_lib.sim_state_specs(state, cfg, mesh)
    lp, _ = jax.tree_util.tree_flatten_with_path(state)
    sharded = {jax.tree_util.keystr(p)
               for (p, _), sp in zip(lp, ps) if len(sp)}
    # every farm per-server axis + the thermal server/rack fields, and
    # nothing from the replicated tables (jobs/flows/net/sched/telem/trace)
    assert any(".farm.srv_state" in s for s in sharded)
    assert any(".thermal.t_srv" in s for s in sharded)
    assert any(".thermal.t_set" in s for s in sharded)
    assert not any(".jobs." in s or ".trace." in s or ".sched." in s
                   for s in sharded)


def test_collective_count_is_one_gather_per_sharded_leaf():
    """The macro-step's whole collective phase is the top-of-step gather:
    exactly one all_gather per rack-sharded leaf, nothing else — the
    cheap-event chew loop is collective-free.  Expressed as the same
    named rules the simlint CI job pins (analysis/rules.py)."""
    from repro.analysis import jaxpr_audit, rules

    cfg = SimConfig(n_servers=8, n_cores=2, max_jobs=32, max_events=1000,
                    thermal=ThermalConfig(enabled=True, rack_size=2),
                    trace=TraceConfig(enabled=True))
    arr, specs = _workload(n_jobs=5)
    state, tc = _built_state(cfg, arr, specs)
    mesh = shard_sim.make_mesh(1)
    jx = shard_sim.sharded_step_jaxpr(state, cfg, tc, mesh)
    inv = jaxpr_audit.audit(jx)
    n_sharded = shard_sim.n_sharded_leaves(state, cfg, mesh)
    assert n_sharded > 0
    gather_rule = rules.ExactCount(
        name="one-all-gather-per-sharded-leaf",
        prims=frozenset({"all_gather"}), expect=n_sharded)
    other_rule = rules.ForbidPrimitive(
        name="no-other-collectives",
        prims=jaxpr_audit.COLLECTIVE_PRIMS - {"all_gather"})
    bad = gather_rule.check("d1", inv, None) + other_rule.check("d1", inv, None)
    assert not bad, "\n".join(v.render() for v in bad)


def test_validate_sharding_rejects_bad_layouts():
    cfg = SimConfig(n_servers=6, n_cores=2)
    with pytest.raises(ValueError, match="divisible"):
        shard_sim.validate_sharding(cfg, 4)
    # uneven racks force the general one-hot grouping, which the sharded
    # path refuses up front (init_state already raises for it)
    cfg2 = SimConfig(n_servers=8, n_cores=2,
                     partition=PartitionConfig(n_shards=2),
                     thermal=ThermalConfig(enabled=True, rack_size=3))
    jt = jobs_mod.build_jobs(cfg2, np.zeros(1), [dag_single(0.01)])
    with pytest.raises(ValueError, match="pad_to_racks"):
        engine.init_state(cfg2, jt)


def test_n_present_validation():
    cfg = SimConfig(n_servers=4, n_cores=2, n_present=9)
    jt = jobs_mod.build_jobs(cfg, np.zeros(1), [dag_single(0.01)])
    with pytest.raises(ValueError, match="n_present"):
        engine.init_state(cfg, jt)


# ==========================================================================
# 8 virtual devices: the four pinned configs, leaf-exact (slow)
# ==========================================================================

_EQ_SCRIPT = r"""
import sys; sys.path.insert(0, "src")
import dataclasses
import numpy as np
import jax

from repro.analysis import jaxpr_audit, rules
from repro.core import engine, jobs as jobs_mod, shard_sim, topology, \
    traceio, workload
from repro.core.jobs import dag_chain, dag_single
from repro.core.types import (SchedPolicy, SimConfig, SleepPolicy,
                              ThermalConfig, TraceConfig)

assert len(jax.devices()) >= 8, jax.devices()
TH = dict(enabled=True, r_th=0.5, tau_th=2.0, t_inlet=22.0, recirc=0.2,
          rack_size=2)

def lb_sleep():
    cfg = SimConfig(n_servers=16, n_cores=2, max_jobs=256,
                    sched_policy=SchedPolicy.LOAD_BALANCE,
                    sleep_policy=SleepPolicy.SINGLE_TIMER,
                    max_events=60_000, trace=TraceConfig(enabled=True))
    rng = np.random.default_rng(7)
    arr = workload.poisson_arrivals(60.0, 150, seed=3)
    specs = [dag_single(rng.exponential(0.02)) for _ in range(150)]
    return cfg, arr, specs, None, 0.05

def rr_star():
    cfg = SimConfig(n_servers=16, n_cores=2, max_jobs=64, tasks_per_job=2,
                    max_children=2, max_flows=64, local_q=32,
                    sched_policy=SchedPolicy.ROUND_ROBIN,
                    sleep_policy=SleepPolicy.ALWAYS_ON,
                    has_network=True, comm_model=0, max_events=60_000,
                    trace=TraceConfig(enabled=True))
    rng = np.random.default_rng(2)
    arr = workload.poisson_arrivals(25.0, 30, seed=2)
    specs = [dag_chain(rng.uniform(0.01, 0.04, size=2),
                       edge_bytes=float(rng.uniform(4e6, 8e6)))
             for _ in range(30)]
    return cfg, arr, specs, topology.star(16, link_cap=1.0e8), None

def thermal_throttle():
    tcfg = ThermalConfig(**TH, t_throttle=50.0, t_release=45.0,
                         throttle_freq=0.5, throttle_power_scale=0.6,
                         carbon_period=600.0, price_period=600.0)
    cfg = SimConfig(n_servers=16, n_cores=2, max_jobs=256,
                    sched_policy=SchedPolicy.THERMAL_AWARE,
                    max_events=60_000, thermal=tcfg,
                    trace=TraceConfig(enabled=True))
    rng = np.random.default_rng(11)
    arr = workload.poisson_arrivals(80.0, 150, seed=5)
    specs = [dag_single(rng.exponential(0.02)) for _ in range(150)]
    return cfg, arr, specs, None, None

def carbon_aware():
    tcfg = ThermalConfig(**TH, defer_threshold=350.0,
                         carbon_period=600.0, carbon_swing=0.5)
    cfg = SimConfig(n_servers=16, n_cores=2, max_jobs=256,
                    sched_policy=SchedPolicy.CARBON_AWARE,
                    max_events=60_000, thermal=tcfg,
                    trace=TraceConfig(enabled=True))
    rng = np.random.default_rng(13)
    arr = workload.poisson_arrivals(40.0, 120, seed=9)
    specs = [dag_single(rng.exponential(0.02), defer_slack=300.0)
             for _ in range(120)]
    return cfg, arr, specs, None, None

mesh = shard_sim.make_mesh(8)
for build in (lb_sleep, rr_star, thermal_throttle, carbon_aware):
    cfg, arr, specs, topo, tau = build()
    jt = jobs_mod.build_jobs(cfg, np.asarray(arr), specs)
    state, tc = engine.init_state(cfg, jt, topo)
    # the same named rules the simlint CI job pins, on the real 8-device
    # shard-mapped program of each policy config
    jx = shard_sim.sharded_step_jaxpr(state, cfg, tc, mesh)
    inv = jaxpr_audit.audit(jx)
    n_sharded = shard_sim.n_sharded_leaves(state, cfg, mesh)
    assert n_sharded > 0
    audit_bad = []
    for rule in (
            rules.ExactCount(name="one-all-gather-per-sharded-leaf",
                             prims=frozenset({"all_gather"}),
                             expect=n_sharded),
            rules.ForbidPrimitive(
                name="no-other-collectives",
                prims=jaxpr_audit.COLLECTIVE_PRIMS - {"all_gather"}),
            rules.ForbidPrimitive(name="no-host-callbacks",
                                  prims=jaxpr_audit.CALLBACK_PRIMS)):
        audit_bad.extend(rule.check(build.__name__, inv, None))
    assert not audit_bad, "\n".join(v.render() for v in audit_bad)
    if tau is not None:
        state = dataclasses.replace(
            state, farm=dataclasses.replace(
                state.farm,
                srv_tau=jax.numpy.full((cfg.n_servers,), tau,
                                       cfg.time_dtype)))
    ref = jax.block_until_ready(engine.run(state, cfg, tc))
    out = jax.block_until_ready(
        shard_sim.run_sharded(state, cfg, tc, mesh))
    lp, _ = jax.tree_util.tree_flatten_with_path(ref)
    bad = [jax.tree_util.keystr(p)
           for (p, a), b in zip(lp, jax.tree.leaves(out))
           if not np.array_equal(np.asarray(a), np.asarray(b))]
    ev_a, _ = traceio.decode(ref.trace, cfg)
    ev_b, _ = traceio.decode(out.trace, cfg)
    d = traceio.diff_traces(ev_a, ev_b)
    assert int(ref.events) > 0
    assert not bad and d is None, (build.__name__, bad, d)
    print(build.__name__, "OK", int(ref.events))
print("SHARDED-BITWISE-EQUAL")
"""


@pytest.mark.slow
def test_sharded_equals_unsharded_bitwise_8_devices():
    """8 virtual devices, four pinned policy configs (sleep states, star
    flows, throttling, carbon deferral): every state leaf AND the decoded
    trace ring match the single-device engine exactly, and each config's
    shard-mapped jaxpr passes the named collective-contract rules."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    r = subprocess.run([sys.executable, "-c", _EQ_SCRIPT], env=env,
                       capture_output=True, text=True, cwd="/root/repo",
                       timeout=900)
    assert "SHARDED-BITWISE-EQUAL" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_replicas_compose_with_rack_axis_on_2d_mesh():
    """Monte Carlo replicas shard over the axis ORTHOGONAL to "racks" on
    a 2-D mesh: same stats as the single-device vmap."""
    script = r"""
import sys; sys.path.insert(0, "src")
import jax, numpy as np
from jax.sharding import Mesh
from repro.core import montecarlo, workload
from repro.core.jobs import dag_single
from repro.core.types import SimConfig
cfg = SimConfig(n_servers=8, n_cores=2, max_jobs=64, max_events=20_000)
R = 4
arrs = np.stack([workload.poisson_arrivals(40.0, 30, seed=s)
                 for s in range(R)])
specs = [dag_single(0.02) for _ in range(30)]
state_b, tc = montecarlo.batched_state(cfg, arrs, specs)
mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2),
            ("replicas", "racks"))
out = montecarlo.run_replicas(cfg, state_b, tc, mesh=mesh)
ref = montecarlo.run_replicas(cfg, state_b, tc)
sa = montecarlo.replica_stats(out, cfg)
sb = montecarlo.replica_stats(ref, cfg)
for k in ("mean_latency", "energy", "events", "finished"):
    assert np.allclose(sa[k], sb[k], equal_nan=True), k
print("MC-2D-MESH-OK")
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, cwd="/root/repo",
                       timeout=600)
    assert "MC-2D-MESH-OK" in r.stdout, r.stdout + r.stderr

"""MoE layer: scatter production path vs one-hot einsum oracle, capacity
semantics, and routing invariants (hypothesis)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    # optional dev dependency (pyproject [dev]); without it the routing
    # invariant sweep falls back to fixed parametrized examples
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                       # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro import configs
from repro.models import moe, transformer


def _cfg(**kw):
    base = configs.get_smoke("qwen3_moe_235b_a22b")
    return dataclasses.replace(base, **kw)


@pytest.mark.parametrize("B,S,E,k,cf", [
    (2, 16, 8, 2, 1.25),
    (1, 32, 4, 1, 1.0),
    (3, 8, 8, 4, 2.0),
    (2, 1, 8, 2, 1.25),          # decode shape
])
def test_scatter_matches_einsum(B, S, E, k, cf):
    cfg = _cfg(n_experts=E, top_k=k, capacity_factor=cf)
    p, _ = transformer._moe_params(cfg, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    a, aux_a, drop_a = moe.moe_scatter(p, x, cfg)
    b, aux_b, drop_b = moe.moe_einsum(p, x, cfg)
    np.testing.assert_allclose(np.float32(a), np.float32(b), atol=2e-2,
                               rtol=2e-2)
    assert int(drop_a) == int(drop_b)
    assert float(aux_a) == pytest.approx(float(aux_b), rel=1e-5)


def test_high_capacity_is_dropless():
    cfg = _cfg(capacity_factor=8.0)
    p, _ = transformer._moe_params(cfg, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model))
    _, _, dropped = moe.moe_scatter(p, x, cfg)
    assert int(dropped) == 0


def test_capacity_drops_monotone():
    cfg_lo = _cfg(capacity_factor=0.5)
    cfg_hi = _cfg(capacity_factor=1.5)
    p, _ = transformer._moe_params(cfg_lo, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 64, cfg_lo.d_model))
    _, _, d_lo = moe.moe_scatter(p, x, cfg_lo)
    _, _, d_hi = moe.moe_scatter(p, x, cfg_hi)
    assert int(d_lo) > int(d_hi)


def test_shared_experts_add_dense_path():
    cfg = _cfg(n_shared_experts=1)
    p, _ = transformer._moe_params(cfg, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    out_with, _, _ = moe.moe_scatter(p, x, cfg)
    p2 = {k: v for k, v in p.items() if k != "shared"}
    cfg2 = _cfg(n_shared_experts=0)
    out_wo, _, _ = moe.moe_scatter(p2, x, cfg2)
    assert np.abs(np.float32(out_with) - np.float32(out_wo)).max() > 1e-3


def _check_positions_unique(seed, S):
    cfg = _cfg()
    topi = jax.random.randint(jax.random.key(seed), (2, S, cfg.top_k), 0,
                              cfg.n_experts)
    pos = moe._positions_in_expert(topi, cfg)
    t = np.asarray(topi).reshape(2, -1)
    q = np.asarray(pos).reshape(2, -1)
    for b in range(2):
        for e in range(cfg.n_experts):
            sel = q[b][t[b] == e]
            assert len(np.unique(sel)) == len(sel)          # no collisions
            if len(sel):
                assert set(sel) == set(range(len(sel)))     # dense 0..n-1


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 99), S=st.integers(1, 24))
    def test_positions_in_expert_are_unique_per_expert(seed, S):
        _check_positions_unique(seed, S)
else:
    @pytest.mark.parametrize("seed,S", [(0, 1), (7, 8), (42, 24)])
    def test_positions_in_expert_are_unique_per_expert(seed, S):
        _check_positions_unique(seed, S)


def test_router_gates_normalized():
    cfg = _cfg()
    p, _ = transformer._moe_params(cfg, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model))
    topi, gates, aux = moe.route(p, x, cfg)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
    assert (np.asarray(topi) < cfg.n_experts).all()

"""Arrival-model sanity: empirical rates, monotonicity, and burstiness of
the paper's three workload generators (core/workload.py §III-D)."""
import numpy as np
import pytest

from repro.core import workload


def _empirical_rate(ts):
    return len(ts) / (ts[-1] - ts[0] + 1e-12)


def test_poisson_rate_and_monotone():
    lam, n = 100.0, 20_000
    ts = workload.poisson_arrivals(lam, n, seed=0)
    assert ts.shape == (n,)
    assert (np.diff(ts) > 0).all()
    assert _empirical_rate(ts) == pytest.approx(lam, rel=0.05)
    # exponential gaps: CV ~ 1
    gaps = np.diff(ts)
    assert gaps.std() / gaps.mean() == pytest.approx(1.0, abs=0.1)


def test_poisson_t0_offset():
    ts = workload.poisson_arrivals(50.0, 100, seed=1, t0=10.0)
    assert ts[0] > 10.0


def test_mmpp2_rate_between_states_and_bursty():
    lam_h, lam_l = 2000.0, 100.0
    n = 30_000
    ts = workload.mmpp2_arrivals(lam_h=lam_h, lam_l=lam_l, r_hl=2.0,
                                 r_lh=1.0, n_jobs=n, seed=2)
    assert (np.diff(ts) > 0).all()
    rate = _empirical_rate(ts)
    assert lam_l < rate < lam_h
    # stationary mix: pi_H = r_lh/(r_lh+r_hl) = 1/3 of *time* in H
    expect = (lam_h * 1.0 + lam_l * 2.0) / 3.0
    assert rate == pytest.approx(expect, rel=0.15)
    # modulation makes inter-arrivals over-dispersed vs Poisson (CV > 1)
    gaps = np.diff(ts)
    assert gaps.std() / gaps.mean() > 1.2


def test_wiki_like_trace_rate_and_monotone():
    mean_rate, n = 500.0, 40_000
    ts = workload.wiki_like_trace(n, mean_rate, period=10.0, swing=0.6,
                                  seed=3)
    assert (np.diff(ts) > 0).all()
    assert _empirical_rate(ts) == pytest.approx(mean_rate, rel=0.1)
    # diurnal swing: rate in the peak half-period beats the trough
    phase = (ts % 10.0) / 10.0
    peak = ((phase > 0.0) & (phase < 0.5)).sum()      # sin > 0 half
    trough = ((phase > 0.5) & (phase < 1.0)).sum()
    assert peak > 1.2 * trough


def test_trace_arrivals_sorted_truncated_rescaled():
    raw = [3.0, 1.0, 2.0, 8.0]
    ts = workload.trace_arrivals(raw, n_jobs=3, rate_scale=2.0)
    np.testing.assert_allclose(ts, [0.5, 1.0, 1.5])


def test_utilization_to_rate_roundtrip():
    lam = workload.utilization_to_rate(0.5, 0.01, 10, 4)
    assert lam == pytest.approx(0.5 / 0.01 * 40)

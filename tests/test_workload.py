"""Arrival-model sanity: empirical rates, monotonicity, and burstiness of
the paper's three workload generators (core/workload.py §III-D)."""
import numpy as np
import pytest

from repro.core import workload


def _empirical_rate(ts):
    return len(ts) / (ts[-1] - ts[0] + 1e-12)


def test_poisson_rate_and_monotone():
    lam, n = 100.0, 20_000
    ts = workload.poisson_arrivals(lam, n, seed=0)
    assert ts.shape == (n,)
    assert (np.diff(ts) > 0).all()
    assert _empirical_rate(ts) == pytest.approx(lam, rel=0.05)
    # exponential gaps: CV ~ 1
    gaps = np.diff(ts)
    assert gaps.std() / gaps.mean() == pytest.approx(1.0, abs=0.1)


def test_poisson_t0_offset():
    ts = workload.poisson_arrivals(50.0, 100, seed=1, t0=10.0)
    assert ts[0] > 10.0


def test_mmpp2_rate_between_states_and_bursty():
    lam_h, lam_l = 2000.0, 100.0
    n = 30_000
    ts = workload.mmpp2_arrivals(lam_h=lam_h, lam_l=lam_l, r_hl=2.0,
                                 r_lh=1.0, n_jobs=n, seed=2)
    assert (np.diff(ts) > 0).all()
    rate = _empirical_rate(ts)
    assert lam_l < rate < lam_h
    # stationary mix: pi_H = r_lh/(r_lh+r_hl) = 1/3 of *time* in H
    expect = (lam_h * 1.0 + lam_l * 2.0) / 3.0
    assert rate == pytest.approx(expect, rel=0.15)
    # modulation makes inter-arrivals over-dispersed vs Poisson (CV > 1)
    gaps = np.diff(ts)
    assert gaps.std() / gaps.mean() > 1.2


def test_wiki_like_trace_rate_and_monotone():
    mean_rate, n = 500.0, 40_000
    ts = workload.wiki_like_trace(n, mean_rate, period=10.0, swing=0.6,
                                  seed=3)
    assert (np.diff(ts) > 0).all()
    assert _empirical_rate(ts) == pytest.approx(mean_rate, rel=0.1)
    # diurnal swing: rate in the peak half-period beats the trough
    phase = (ts % 10.0) / 10.0
    peak = ((phase > 0.0) & (phase < 0.5)).sum()      # sin > 0 half
    trough = ((phase > 0.5) & (phase < 1.0)).sum()
    assert peak > 1.2 * trough


def _scalar_wiki(n_jobs, mean_rate, period, swing, seed):
    """Independent one-candidate-at-a-time reimplementation of the
    vectorized wiki_like_trace draw discipline (dedicated gap/acceptance
    streams, u·lam_max < rate(t) predicate, sequential time accumulation
    — np.cumsum accumulates in the same order)."""
    gap_rng, acc_rng = [np.random.default_rng(s)
                        for s in np.random.SeedSequence(seed).spawn(2)]
    lam_max = mean_rate * (1.0 + swing)
    out, t = [], 0.0
    while len(out) < n_jobs:
        t += gap_rng.exponential(1.0 / lam_max)
        u = acc_rng.random()
        if u * lam_max < mean_rate * (1.0 + swing
                                      * np.sin(2.0 * np.pi * t / period)):
            out.append(t)
    return np.asarray(out)


def _scalar_mmpp2(lam_h, lam_l, r_hl, r_lh, n_jobs, seed):
    """Independent scalar reimplementation of the vectorized MMPP(2)
    discipline: the modulating trajectory comes lazily from its own
    stream (standard exponentials scaled per state), candidates from the
    gap stream, acceptance from the uniform stream."""
    state_rng, gap_rng, acc_rng = [
        np.random.default_rng(s)
        for s in np.random.SeedSequence(seed).spawn(3)]
    start_h = bool(state_rng.random() < r_lh / (r_lh + r_hl))
    lam_max = max(lam_h, lam_l)
    switch, sw_end, k = [], 0.0, 0
    out, t = [], 0.0
    while len(out) < n_jobs:
        t += gap_rng.exponential(1.0 / lam_max)
        u = acc_rng.random()
        while sw_end < t:
            in_h = (k % 2 == 0) == start_h
            sw_end += state_rng.exponential(1.0) \
                * (1.0 / r_hl if in_h else 1.0 / r_lh)
            switch.append(sw_end)
            k += 1
        idx = np.searchsorted(switch, t, side="right")
        lam = lam_h if ((idx % 2 == 0) == start_h) else lam_l
        if u * lam_max < lam:
            out.append(t)
    return np.asarray(out)


def test_wiki_vectorized_matches_scalar_reference():
    """Regression (PR 5 vectorization): the chunked thinning sampler is
    bit-equal to the scalar one-draw-at-a-time reference for a fixed
    seed, at any chunk size."""
    args = dict(n_jobs=3000, mean_rate=80.0, period=20.0, swing=0.6)
    ref = _scalar_wiki(seed=11, **args)
    for chunk in (1, 257, 16384):
        vec = workload.wiki_like_trace(seed=11, chunk=chunk, **args)
        np.testing.assert_array_equal(vec, ref)


def test_mmpp2_vectorized_matches_scalar_reference():
    ref = _scalar_mmpp2(500.0, 40.0, 1.5, 0.7, 3000, seed=13)
    for chunk in (1, 257, 16384):
        vec = workload.mmpp2_arrivals(500.0, 40.0, 1.5, 0.7, 3000,
                                      seed=13, chunk=chunk)
        np.testing.assert_array_equal(vec, ref)


def test_trace_arrivals_sorted_truncated_rescaled():
    raw = [3.0, 1.0, 2.0, 8.0]
    ts = workload.trace_arrivals(raw, n_jobs=3, rate_scale=2.0)
    np.testing.assert_allclose(ts, [0.5, 1.0, 1.5])


def test_utilization_to_rate_roundtrip():
    lam = workload.utilization_to_rate(0.5, 0.01, 10, 4)
    assert lam == pytest.approx(0.5 / 0.01 * 40)

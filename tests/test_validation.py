"""Analytic validation (paper §V analogue, hardware-free):

The paper validates against a physical Xeon + Cisco switch; we have no lab,
so we validate the *same property* — simulated latency/power matching an
independent reference — against closed-form queueing theory (M/M/c via
Erlang-C) and conservation laws.  The heapq oracle (test_engine_oracle)
covers event-exactness; these tests cover statistical correctness.
"""
import math

import numpy as np
import pytest

try:
    # optional dev dependency (pyproject [dev]); without it the invariant
    # sweep falls back to fixed parametrized examples
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                       # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.core import farm as farm_mod
from repro.core import workload
from repro.core.jobs import dag_single
from repro.core.types import (SchedPolicy,
                              SimConfig, SleepPolicy, SrvState)


def erlang_c_wait(c, lam, mu):
    """Mean sojourn time W = Wq + 1/mu for M/M/c."""
    a = lam / mu
    rho = a / c
    assert rho < 1
    p0 = 1.0 / (sum(a ** k / math.factorial(k) for k in range(c))
                + a ** c / (math.factorial(c) * (1 - rho)))
    erl = a ** c / (math.factorial(c) * (1 - rho)) * p0
    return erl / (c * mu - lam) + 1 / mu


@pytest.mark.parametrize("rho", [0.3, 0.6, 0.8])
def test_mmc_mean_latency(rho):
    """One server with c cores and a single queue IS M/M/c exactly."""
    c, svc, n_jobs = 8, 0.01, 4000
    cfg = SimConfig(n_servers=1, n_cores=c, local_q=512, max_jobs=4096,
                    tasks_per_job=1, sleep_policy=SleepPolicy.ALWAYS_ON,
                    max_events=100_000)
    mu = 1.0 / svc
    lam = rho * mu * c
    rng = np.random.default_rng(42)
    arr = workload.poisson_arrivals(lam, n_jobs, seed=2)
    specs = [dag_single(rng.exponential(svc)) for _ in range(n_jobs)]
    res = farm_mod.simulate(cfg, arr, specs)
    w_theory = erlang_c_wait(c, lam, mu)
    assert res.n_finished == n_jobs
    assert res.mean_latency == pytest.approx(w_theory, rel=0.08)
    assert res.utilization == pytest.approx(rho, rel=0.08)


def test_energy_conservation_always_on():
    """Active-Idle farm: E = P_idle_farm·T + (P_busy-P_idle)·busy_core_s."""
    cfg = SimConfig(n_servers=4, n_cores=2, max_jobs=512, tasks_per_job=1,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=50_000)
    sp = cfg.server_power
    rng = np.random.default_rng(3)
    arr = workload.poisson_arrivals(100.0, 400, seed=4)
    specs = [dag_single(rng.exponential(0.01)) for _ in range(400)]
    res = farm_mod.simulate(cfg, arr, specs)
    base = (sp.p_base + cfg.n_cores * sp.p_core_idle) * cfg.n_servers \
        * res.sim_time
    expected = base + (sp.p_core_active - sp.p_core_idle) \
        * res.busy_core_seconds
    assert res.server_energy == pytest.approx(expected, rel=1e-3)


def test_residency_sums_to_sim_time():
    cfg = SimConfig(n_servers=5, n_cores=2, max_jobs=256, tasks_per_job=1,
                    sleep_policy=SleepPolicy.SINGLE_TIMER,
                    sleep_state=SrvState.S3, max_events=50_000)
    rng = np.random.default_rng(5)
    arr = workload.poisson_arrivals(50.0, 200, seed=6)
    specs = [dag_single(rng.exponential(0.02)) for _ in range(200)]
    res = farm_mod.simulate(cfg, arr, specs, tau=0.1)
    np.testing.assert_allclose(res.residency.sum(axis=1),
                               res.sim_time, rtol=1e-4)


def test_sleep_saves_energy_at_low_util():
    """Paper §IV-B premise: at low utilization a delay timer into a shallow
    state (PkgC6, <1ms wake) saves energy vs Active-Idle at some latency
    cost.  (With a DEEP state whose wake latency exceeds the idle gaps the
    timer *loses* — the paper's own caveat about aggressive sleeping; the
    case-B benchmark sweeps τ to exhibit exactly that U-shape.)"""
    cfg_on = SimConfig(n_servers=8, n_cores=2, max_jobs=2048,
                       tasks_per_job=1,
                       sleep_policy=SleepPolicy.ALWAYS_ON, max_events=80_000)
    cfg_tm = SimConfig(n_servers=8, n_cores=2, max_jobs=2048,
                       tasks_per_job=1,
                       sleep_policy=SleepPolicy.SINGLE_TIMER,
                       sleep_state=SrvState.PKG_C6, max_events=80_000)
    rng = np.random.default_rng(9)
    svc = 0.005
    n_jobs = 2000
    lam = workload.utilization_to_rate(0.10, svc, 8, 2)
    arr = workload.poisson_arrivals(lam, n_jobs, seed=10)
    specs = [dag_single(rng.exponential(svc)) for _ in range(n_jobs)]
    on = farm_mod.simulate(cfg_on, arr, specs)
    tm = farm_mod.simulate(cfg_tm, arr, specs, tau=0.02)
    assert tm.server_energy < 0.75 * on.server_energy
    assert tm.p95_latency >= on.p95_latency - 1e-6

    # deep sleep with second-scale wakeups at millisecond gaps backfires
    cfg_s3 = SimConfig(n_servers=8, n_cores=2, max_jobs=2048,
                       tasks_per_job=1,
                       sleep_policy=SleepPolicy.SINGLE_TIMER,
                       sleep_state=SrvState.S3, max_events=80_000)
    s3 = farm_mod.simulate(cfg_s3, arr, specs, tau=0.02)
    assert s3.server_energy > on.server_energy


def test_mmpp_burstiness():
    """MMPP(2) with Ra >> 1 must produce a burstier arrival process than
    Poisson at the same mean rate (higher CV of inter-arrivals)."""
    lam = 100.0
    pois = workload.poisson_arrivals(lam, 20_000, seed=1)
    mmpp = workload.mmpp2_arrivals(lam_h=4 * lam / 2.2, lam_l=0.4 * lam / 2.2,
                                   r_hl=1.0, r_lh=2.0, n_jobs=20_000, seed=1)
    def cv(a):
        return np.std(np.diff(a)) / np.mean(np.diff(a))
    assert cv(mmpp) > 1.3 * cv(pois)
    assert cv(pois) == pytest.approx(1.0, abs=0.05)


def _check_engine_invariants(n_servers, n_cores, n_jobs, policy, sched, tau,
                             seed):
    """Property check: for any small config, the engine terminates with all
    jobs finished, time/energy accounting consistent, and no NaNs."""
    cfg = SimConfig(n_servers=n_servers, n_cores=n_cores, local_q=64,
                    max_jobs=64, tasks_per_job=1, sched_policy=sched,
                    sleep_policy=policy, sleep_state=SrvState.S3,
                    max_events=20_000)
    rng = np.random.default_rng(seed)
    arr = workload.poisson_arrivals(20.0 * n_servers, n_jobs, seed=seed)
    specs = [dag_single(rng.exponential(0.02)) for _ in range(n_jobs)]
    res = farm_mod.simulate(cfg, arr, specs, tau=tau)
    assert res.n_finished == n_jobs
    assert res.events < cfg.max_events
    assert np.all(res.latencies > 0)
    assert np.isfinite(res.server_energy) and res.server_energy > 0
    np.testing.assert_allclose(res.residency.sum(axis=1), res.sim_time,
                               rtol=1e-3, atol=1e-5)
    # work conservation: busy core-seconds == sum of service requirements
    total_svc = sum(float(s.service[0]) for s in specs)
    assert res.busy_core_seconds == pytest.approx(total_svc, rel=1e-3)


if HAVE_HYPOTHESIS:
    @settings(max_examples=12, deadline=None)
    @given(
        n_servers=st.integers(1, 6),
        n_cores=st.integers(1, 3),
        n_jobs=st.integers(5, 40),
        policy=st.sampled_from([SleepPolicy.ALWAYS_ON,
                                SleepPolicy.SINGLE_TIMER]),
        sched=st.sampled_from([SchedPolicy.LOAD_BALANCE,
                               SchedPolicy.ROUND_ROBIN]),
        tau=st.floats(0.01, 1.0),
        seed=st.integers(0, 2**16),
    )
    def test_engine_invariants(n_servers, n_cores, n_jobs, policy, sched,
                               tau, seed):
        _check_engine_invariants(n_servers, n_cores, n_jobs, policy, sched,
                                 tau, seed)
else:
    @pytest.mark.parametrize("n_servers,n_cores,n_jobs,policy,sched,tau,seed", [
        (1, 1, 5, SleepPolicy.ALWAYS_ON, SchedPolicy.LOAD_BALANCE, 0.1, 0),
        (4, 2, 40, SleepPolicy.SINGLE_TIMER, SchedPolicy.ROUND_ROBIN,
         0.05, 7),
        (6, 3, 25, SleepPolicy.SINGLE_TIMER, SchedPolicy.LOAD_BALANCE,
         1.0, 42),
        (3, 1, 12, SleepPolicy.ALWAYS_ON, SchedPolicy.ROUND_ROBIN, 0.5, 99),
    ])
    def test_engine_invariants(n_servers, n_cores, n_jobs, policy, sched,
                               tau, seed):
        _check_engine_invariants(n_servers, n_cores, n_jobs, policy, sched,
                                 tau, seed)

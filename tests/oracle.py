"""Sequential heapq-based DES oracle — the classical implementation of the
paper's engine, used to validate the vectorized JAX engine event-for-event.

Replicates the engine's semantics exactly:
  * global scheduler assigns every task of a job at arrival, using a
    load snapshot taken before any of the job's tasks are enqueued
    (LOAD_BALANCE ties break to the lowest server index, like argmin);
    ALL jobs arriving at the same timestamp share one snapshot (the
    engine's batched same-time admission)
  * ROUND_ROBIN advances the pointer per task
  * a task becomes READY when all DAG parents finished (dep_count == 0);
    READY tasks enqueue at their assigned server and trigger wakeups
  * servers sleep after τ seconds of idleness (SINGLE/DUAL timer) into
    cfg.sleep_state; wake latency/power follow the ACPI profile
  * energy integrates the piecewise-constant power curve exactly
  * a task hitting a full local queue (cfg.local_q) is DROPPED: it counts
    toward job completion (finish stamped at drop time) and resolves its
    DAG edges immediately; newly-unblocked children enqueue via a deferred
    same-time event (matching the engine, which drains them next step)

Optional network mode (pass ``topo=``): the equal-share fluid flow model
over the topology's BFS routes — per-link flow counts, rate = min over
route links of cap/share, bytes drained exactly between events, and
``max_flows`` slot exhaustion drop-resolving the edge (dep decremented
immediately, counted in ``flows_dropped``).  Supports comm_model=0 and
topologies whose route links never charge LPI/switch wake extras on spawn
(star: every link's side-a endpoint is a server, all switches awake) so
the fixed-latency budget is zero, like the engine.

Optional thermal mode (cfg.thermal.enabled): the numpy reference
integrator for core/thermal.py — per-server RC temperatures advanced with
the same closed-form exponential between events (rack-recirculated inlet
held piecewise constant), CRAC cooling energy, closed-form diurnal
carbon/cost integrals, and threshold-crossing throttle events with
hysteresis that stretch in-flight work by the frequency ratio.

Control plane (PR 5): per-rack CRAC setpoints (``t_setpoint`` /
``ThermalState.t_set``) with per-rack quadratic COP, the diurnal ambient
sinusoid on the supply temperature (held piecewise constant per interval,
honored by the crossing solve), setpoint-controller ticks as events, and
SchedPolicy.CARBON_AWARE deferral: deferrable jobs arriving while the
carbon/price signal is above ``defer_threshold`` are parked and released
at the solved sinusoid down-crossing or their deadline — release events
sit between timers and arrivals (released job ids are always lower than
now-arriving ids, matching the engine's release-before-arrival order) and
admit in arrivals_per_step chunks against shared snapshots.
"""
from __future__ import annotations

import heapq
import math

import numpy as np

from repro.core.types import (INF, SchedPolicy, SimConfig, SleepPolicy,
                              SrvState, TraceKind)
from repro.core.thermal import TEMP_TOL, _CROSS_EPS


class OracleServer:
    def __init__(self, cfg, i):
        self.cfg = cfg
        self.i = i
        self.cores = [None] * cfg.n_cores     # task id or None
        self.core_end = [INF] * cfg.n_cores
        self.queue = []
        self.state = SrvState.IDLE
        self.idle_since = 0.0
        self.wake_at = INF
        self.tau = INF
        self.energy = 0.0
        self.residency = np.zeros(SrvState.NUM)
        self.busy_core_seconds = 0.0
        self.wake_count = 0
        self.throttled = False

    def busy(self):
        return sum(1 for c in self.cores if c is not None)

    def load(self):
        return self.busy() + len(self.queue)

    def freq(self):
        f = self.cfg.core_freq
        if self.throttled:
            f *= self.cfg.thermal.throttle_freq
        return f

    def power(self):
        sp = self.cfg.server_power
        if self.state in (SrvState.ACTIVE, SrvState.IDLE):
            b = self.busy()
            p_act = sp.p_core_active
            if self.throttled:
                p_act *= self.cfg.thermal.throttle_power_scale
            return (sp.p_base + b * p_act
                    + (self.cfg.n_cores - b) * sp.p_core_idle)
        return {SrvState.PKG_C6: sp.p_pkg_c6, SrvState.S3: sp.p_s3,
                SrvState.OFF: 0.0, SrvState.WAKING: sp.p_wake}[self.state]

    def accrue(self, dt):
        self.energy += self.power() * dt
        self.residency[self.state] += dt
        self.busy_core_seconds += self.busy() * dt


class OracleFlow:
    __slots__ = ("src", "dst", "rem", "extra", "rate", "child", "links",
                 "done_at", "active")

    def __init__(self, src, dst, nbytes, child, links):
        self.src, self.dst, self.child = src, dst, child
        self.rem = float(nbytes)
        self.extra = 0.0              # star/comm_model=0: no wake charges
        self.rate = 0.0
        self.links = links
        self.done_at = INF
        self.active = True


def _rate_integral(base, swing, period, phase, t1, t2):
    w = 2.0 * math.pi / period
    osc = (math.cos(w * (t1 + phase)) - math.cos(w * (t2 + phase))) / w
    return base * ((t2 - t1) + swing * osc)


class OracleSim:
    """Run with the same (cfg, arrivals, specs, tau[, topo, racks]) as
    farm.simulate."""

    def __init__(self, cfg: SimConfig, arrivals, specs, tau=None, topo=None,
                 racks=None):
        self.cfg = cfg
        self.arrivals = np.asarray(arrivals, float)
        self.specs = specs
        self.servers = [OracleServer(cfg, i) for i in range(cfg.n_servers)]
        if tau is not None:
            tau = np.broadcast_to(np.asarray(tau, float),
                                  (cfg.n_servers,))
            for s, tv in zip(self.servers, tau):
                s.tau = float(tv)
        self.t = 0.0
        self.rr = 0
        self.finish = {}
        self.job_finish = {}
        self.events = []
        self.dropped = 0
        # flight-recorder mirror: (time, kind, server, tid, aux) tuples,
        # semantically matching engine emission (traceio.as_events shape)
        self.trace = []
        self.start_t = {}

        # network (optional)
        self.topo = topo
        self.flows = {}
        self.flow_seq = 0
        self.flows_dropped = 0

        # thermal (optional)
        tcfg = cfg.thermal
        self.thermal_on = tcfg.enabled
        if self.thermal_on:
            N = cfg.n_servers
            if racks is None:
                racks = np.arange(N) // max(tcfg.rack_size, 1)
            _, self.rack = np.unique(np.asarray(racks), return_inverse=True)
            R = int(self.rack.max()) + 1
            sp = tcfg.t_inlet if tcfg.t_setpoint is None else tcfg.t_setpoint
            self.t_set = np.broadcast_to(
                np.asarray(sp, float), (R,)).copy()
            t0 = self.t_set[self.rack] + self._ambient(0.0)
            self.temp = t0.copy()
            self.t_peak = t0.copy()
            self.throttle_seconds = np.zeros(N)
            self.cool_energy = 0.0
            self.carbon_g = 0.0
            self.cost = 0.0
            self.cop = tcfg.cop
            self.ctrl_next = tcfg.ctrl_period if tcfg.has_ctrl else INF
        # carbon-aware deferral (SchedPolicy.CARBON_AWARE)
        self.defer_count = 0
        self.defer_seconds = 0.0
        self.grams_avoided = 0.0

    # ---- helpers ------------------------------------------------------
    def _wake_latency(self, state):
        sp = self.cfg.server_power
        return {SrvState.PKG_C6: sp.t_wake_pkg_c6, SrvState.S3: sp.t_wake_s3,
                SrvState.OFF: sp.t_wake_off}.get(state, 0.0)

    def _ambient(self, t):
        tcfg = self.cfg.thermal
        if tcfg.ambient_swing == 0.0:
            return 0.0
        w = 2.0 * math.pi / tcfg.ambient_period
        return tcfg.ambient_swing * math.sin(w * (t + tcfg.ambient_phase))

    def _inlet(self):
        tcfg = self.cfg.thermal
        if not tcfg.per_rack and not tcfg.ambient_on:
            excess = self.temp - tcfg.t_inlet
            means = np.bincount(self.rack, weights=excess) \
                / np.bincount(self.rack)
            return tcfg.t_inlet + tcfg.recirc * means[self.rack]
        base = self.t_set[self.rack] + self._ambient(self.t)
        excess = self.temp - base
        means = np.bincount(self.rack, weights=excess) \
            / np.bincount(self.rack)
        return base + tcfg.recirc * means[self.rack]

    def _cop_at(self, t_sup):
        tcfg = self.cfg.thermal
        return tcfg.cop_a * t_sup * t_sup + tcfg.cop_b * t_sup + tcfg.cop_c

    def _cooling_power(self, p):
        """CRAC watts for per-server IT load ``p`` (no switch-side load in
        the oracle's thermal scenarios) — mirrors thermal.cooling_power."""
        tcfg = self.cfg.thermal
        if not tcfg.per_rack:
            return p.sum() / self.cop
        rack_p = np.bincount(self.rack, weights=p)
        return (rack_p / self._cop_at(self.t_set)).sum()

    def _apply_ctrl(self):
        """Setpoint-controller tick — mirrors thermal.apply_setpoint_ctrl
        (runs after accrue+throttle whenever t reaches ctrl_next)."""
        tcfg = self.cfg.thermal
        if not (self.thermal_on and tcfg.has_ctrl) \
                or self.t < self.ctrl_next:
            return
        self.trace.append((self.t, TraceKind.CTRL_TICK, -1, -1, 0.0))
        rack_max = np.full(self.t_set.shape[0], -INF)
        np.maximum.at(rack_max, self.rack, self.temp)
        down = rack_max > tcfg.ctrl_target
        up = ~down & (rack_max < tcfg.ctrl_target - tcfg.ctrl_band)
        self.t_set = np.clip(
            self.t_set - np.where(down, tcfg.ctrl_step, 0.0)
            + np.where(up, tcfg.ctrl_step, 0.0),
            tcfg.ctrl_min, tcfg.ctrl_max)
        self.ctrl_next = self.ctrl_next + tcfg.ctrl_period

    def _powers(self):
        return np.asarray([s.power() for s in self.servers])

    def _accrue_all(self, t_next):
        dt = t_next - self.t
        assert dt >= -1e-9, (t_next, self.t)
        dt = max(dt, 0.0)
        for s in self.servers:
            s.accrue(dt)
        if self.thermal_on and dt > 0.0:
            tcfg = self.cfg.thermal
            p = self._powers()
            target = p * tcfg.r_th + self._inlet()
            alpha = 1.0 - math.exp(-dt / tcfg.tau_th)
            self.temp = self.temp + (target - self.temp) * alpha
            self.t_peak = np.maximum(self.t_peak, self.temp)
            thr_mask = np.asarray([s.throttled for s in self.servers])
            self.throttle_seconds += thr_mask * dt
            p_it = p.sum()
            p_cool = self._cooling_power(p)
            self.cool_energy += p_cool * dt
            kw = (p_it + p_cool) * 1e-3
            self.carbon_g += kw * _rate_integral(
                tcfg.carbon_base, tcfg.carbon_swing, tcfg.carbon_period,
                tcfg.carbon_phase, self.t, t_next) / 3600.0
            self.cost += kw * _rate_integral(
                tcfg.price_base, tcfg.price_swing, tcfg.price_period,
                tcfg.price_phase, self.t, t_next) / 3600.0
        if self.topo is not None and dt > 0.0:
            for f in self.flows.values():
                lat = min(f.extra, dt)
                f.rem = max(f.rem - f.rate * (dt - lat), 0.0)
                f.extra -= lat
        self.t = t_next

    # ---- thermal throttling ------------------------------------------
    def _throttling(self):
        return self.thermal_on and self.cfg.thermal.t_throttle < INF / 2

    def _next_thermal_crossing(self):
        if not self._throttling():
            return INF
        tcfg = self.cfg.thermal
        thr = tcfg.t_throttle
        rel = min(tcfg.t_release, thr)
        guard = tcfg.crossing_guard
        # mirror the engine's crossing-guard gating: only servers within
        # ``crossing_guard`` °C of their pending threshold get a solved
        # crossing event; the rest latch at the next ordinary event via
        # _apply_throttle (thermal.next_crossing has the same band)
        target = self._powers() * tcfg.r_th + self._inlet()
        dt = INF
        for i, s in enumerate(self.servers):
            ti = self.temp[i]
            if not s.throttled and ti >= thr - guard \
                    and ti < thr - TEMP_TOL and target[i] > thr:
                dt = min(dt, tcfg.tau_th
                         * math.log((target[i] - ti) / (target[i] - thr)))
            if s.throttled and ti <= rel + guard \
                    and ti > rel + TEMP_TOL and target[i] < rel:
                dt = min(dt, tcfg.tau_th
                         * math.log((ti - target[i]) / (rel - target[i])))
        if dt >= INF / 2:
            return INF
        return self.t + dt * (1.0 + _CROSS_EPS) + 1e-9

    def _apply_throttle(self):
        if not self._throttling():
            return
        tcfg = self.cfg.thermal
        thr = tcfg.t_throttle
        rel = min(tcfg.t_release, thr)
        for i, s in enumerate(self.servers):
            was = s.throttled
            if not was and self.temp[i] >= thr - TEMP_TOL:
                s.throttled = True
            elif was and self.temp[i] <= rel + TEMP_TOL:
                s.throttled = False
            if s.throttled != was:
                self.trace.append((self.t, TraceKind.THROTTLE_CROSSING,
                                   i, -1, float(self.temp[i])))
                # stretch in-flight work about *now* by the freq ratio
                f_old = tcfg.throttle_freq if was else 1.0
                f_new = tcfg.throttle_freq if s.throttled else 1.0
                ratio = f_old / f_new
                for c in range(self.cfg.n_cores):
                    if self.t < s.core_end[c] < INF:
                        s.core_end[c] = self.t \
                            + (s.core_end[c] - self.t) * ratio
                        heapq.heappush(self.events,
                                       (s.core_end[c], 0, "complete",
                                        (i, c)))

    # ---- carbon-aware deferral ---------------------------------------
    def _defer_params(self):
        tcfg = self.cfg.thermal
        if tcfg.defer_signal == "price":
            return (tcfg.price_base, tcfg.price_swing, tcfg.price_period,
                    tcfg.price_phase)
        return (tcfg.carbon_base, tcfg.carbon_swing, tcfg.carbon_period,
                tcfg.carbon_phase)

    def _signal(self, t):
        base, swing, period, phase = self._defer_params()
        w = 2.0 * math.pi / period
        return base * (1.0 + swing * math.sin(w * (t + phase)))

    def _carbon_now(self, t):
        tcfg = self.cfg.thermal
        w = 2.0 * math.pi / tcfg.carbon_period
        return tcfg.carbon_base * (1.0 + tcfg.carbon_swing
                                   * math.sin(w * (t + tcfg.carbon_phase)))

    def _next_release(self, t):
        """Earliest down-crossing of the deferral signal below the
        threshold — mirrors thermal.next_release_time."""
        base, swing, period, phase = self._defer_params()
        thr = self.cfg.thermal.defer_threshold
        if base <= 0.0 or swing == 0.0 or thr >= INF / 2:
            return INF
        s = (thr / base - 1.0) / swing
        if s >= 1.0 or s <= -1.0:
            return INF
        w = 2.0 * math.pi / period
        theta_dn = math.pi - math.asin(s)
        k = math.ceil((w * (t + phase) - theta_dn) / (2.0 * math.pi))
        return (theta_dn + 2.0 * math.pi * k) / w - phase

    def _maybe_defer(self, j):
        """True (and a release event pushed) when job ``j`` arriving NOW
        gets carbon-deferred instead of admitted."""
        cfg = self.cfg
        if cfg.sched_policy != SchedPolicy.CARBON_AWARE:
            return False
        tcfg = cfg.thermal
        spec = self.specs[j]
        if not getattr(spec, "deferrable", False):
            return False
        if not self._signal(self.t) > tcfg.defer_threshold:
            return False
        slack = getattr(spec, "defer_slack", INF)
        deadline = self.arrivals[j] + slack if slack < INF / 2 else INF
        cand = min(self._next_release(self.t), deadline)
        if not (self.t < cand < INF / 2):
            return False
        heapq.heappush(self.events, (cand, 2.5, "release", j))
        return True

    # ---- scheduling / queues -----------------------------------------
    def _pick(self, load_snapshot):
        cfg = self.cfg
        if cfg.sched_policy == SchedPolicy.ROUND_ROBIN:
            srv = self.rr % cfg.n_servers
            self.rr = (srv + 1) % cfg.n_servers
            return srv
        scores = list(load_snapshot)
        if cfg.sched_policy == SchedPolicy.THERMAL_AWARE:
            for i in range(cfg.n_servers):
                scores[i] += (self.temp[i] - cfg.thermal.t_inlet) \
                    * cfg.thermal.sched_temp_weight
        elif cfg.sleep_policy == SleepPolicy.DUAL_TIMER:
            for i, s in enumerate(self.servers):
                scores[i] += (1000.0 if getattr(s, "pool", 0) else 0.0)
        best = min(range(cfg.n_servers), key=lambda i: scores[i])
        return best

    def _admit_chunk(self, jobs, T, allow_defer=True):
        """Admit one chunk of same-timestamp jobs against a single farm
        snapshot (the engine's batched admission), then enqueue the
        chunk's roots in task-id order.  For score policies, each job's
        committed roots count as load for the NEXT job's pick, matching
        the engine's in-batch increments (and the old one-job-per-step
        behavior, where roots drained between admits).  Deferred jobs
        (CARBON_AWARE) consume a chunk slot but commit nothing — exactly
        like the engine's in-batch deferral mask."""
        load_snapshot = [s.load() for s in self.servers]
        roots = []
        for j in jobs:
            if allow_defer:
                # the arrival slot is consumed now (deferred jobs too);
                # the release path re-admits without a second ARRIVAL
                self.trace.append((self.t, TraceKind.ARRIVAL, -1, j, 0.0))
            if allow_defer and self._maybe_defer(j):
                continue
            spec = self.specs[j]
            nt = len(spec.service)
            self.remaining[j] = nt
            dep = {i: 0 for i in range(nt)}
            kids = {i: [] for i in range(nt)}
            byts = {}
            for (p, c, b) in spec.edges:
                dep[c] += 1
                kids[p].append(c)
                byts[(p, c)] = b
            job_srv = None
            for i in range(nt):
                tid = j * T + i
                self.task_service[tid] = float(spec.service[i])
                job_srv = self._pick(load_snapshot)
                self.task_server[tid] = job_srv
                self.dep_count[tid] = dep[i]
                self.children[tid] = [j * T + c for c in kids[i]]
                self.child_bytes[tid] = {
                    j * T + c: byts[(i, c)] for c in kids[i]}
            # snapshot the root set BEFORE enqueuing: a root dropped by a
            # full queue zeroes its children's dep_count, but those
            # children are NOT roots (the engine marks roots once, at
            # admit) — they enqueue via the deferred "ready" event
            job_roots = [j * T + i for i in range(nt)
                         if self.dep_count[j * T + i] == 0]
            if job_srv is not None and \
                    self.cfg.sched_policy != SchedPolicy.ROUND_ROBIN:
                # score policies colocate a job's tasks on one pick
                load_snapshot[job_srv] += len(job_roots)
            # ADMIT: the engine stamps the job's first task's pick and the
            # queue depth there BEFORE the chunk's roots drain (queue
            # pushes happen later, at READY drain)
            srv0 = self.task_server[j * T]
            self.trace.append(
                (self.t, TraceKind.ADMIT, srv0, j,
                 float(len(self.servers[srv0].queue))))
            roots += job_roots
        for tid in roots:
            self._enqueue(tid)

    def _try_start(self, srv):
        s = self.servers[srv]
        if s.state not in (SrvState.ACTIVE, SrvState.IDLE):
            return
        while s.queue and None in s.cores:
            c = s.cores.index(None)
            tid = s.queue.pop(0)
            dur = self.task_service[tid] / s.freq()
            s.cores[c] = tid
            s.core_end[c] = self.t + dur
            self.start_t[tid] = self.t
            self.trace.append((self.t, TraceKind.START, srv, tid,
                               float(dur)))
            heapq.heappush(self.events,
                           (self.t + dur, 0, "complete", (srv, c)))
        s.state = SrvState.ACTIVE if s.busy() else SrvState.IDLE

    def _drop(self, tid):
        """Full-queue drop: the task completes-with-drop right now and its
        DAG edges resolve; ready children enqueue on a deferred same-time
        event (priority 4: after completions/wakes/timers/arrivals, the
        engine drains them on the following step at the same sim time)."""
        self.dropped += 1
        self.finish[tid] = self.t
        self.trace.append((self.t, TraceKind.DROP,
                           self.task_server[tid], tid, 0.0))
        j = tid // self.cfg.tasks_per_job
        self.remaining[j] -= 1
        if self.remaining[j] == 0 and j not in self.job_finish:
            self.job_finish[j] = self.t
            self.trace.append((self.t, TraceKind.JOB_FINISH, -1, j,
                               float(self.t - self.arrivals[j])))
        for ch in self.children[tid]:
            self.dep_count[ch] -= 1
            if self.dep_count[ch] == 0:
                heapq.heappush(self.events, (self.t, 4, "ready", ch))

    def _enqueue(self, tid):
        srv = self.task_server[tid]
        s = self.servers[srv]
        if len(s.queue) >= self.cfg.local_q:
            self._drop(tid)
            return
        s.queue.append(tid)
        if s.state in (SrvState.PKG_C6, SrvState.S3, SrvState.OFF):
            lat = self._wake_latency(s.state)
            s.state = SrvState.WAKING
            s.wake_at = self.t + lat
            s.wake_count += 1
            heapq.heappush(self.events, (s.wake_at, 1, "wake", srv))
        self._try_start(srv)

    def _idle_edge(self, srv):
        """Stamp idle_since and schedule the sleep timer."""
        s = self.servers[srv]
        if s.state == SrvState.IDLE and s.tau < INF / 2 \
                and self.cfg.sleep_policy in (SleepPolicy.SINGLE_TIMER,
                                              SleepPolicy.DUAL_TIMER):
            heapq.heappush(self.events,
                           (self.t + s.tau, 2, "timer", (srv, self.t)))

    # ---- fluid flow model (network mode) ------------------------------
    def _spawn_or_drop_edge(self, src, dst, nbytes, ch):
        """Edge needing a flow: allocate a slot or drop-resolve (engine's
        flow-slot-exhaustion semantics — dep decremented immediately)."""
        if len(self.flows) >= self.cfg.max_flows:
            self.flows_dropped += 1
            self.dep_count[ch] -= 1
            if self.dep_count[ch] == 0:
                self._enqueue(ch)
            return
        links = [int(li) for li in self.topo.routes[src, dst]
                 if li >= 0]
        fid = self.flow_seq
        self.flow_seq += 1
        self.flows[fid] = OracleFlow(src, dst, nbytes, ch, links)
        self.trace.append((self.t, TraceKind.FLOW_SPAWN, src, ch,
                           float(nbytes)))

    def _recompute_rates(self):
        if self.topo is None or not self.flows:
            return
        cap = self.topo.link_cap
        count = np.zeros(self.topo.n_links, np.int64)
        for f in self.flows.values():
            count[f.links] += 1
        for fid, f in self.flows.items():
            f.rate = min(cap[li] / count[li] for li in f.links) \
                if f.links else 0.0
            if f.rate > 0:
                f.done_at = self.t + f.extra + f.rem / f.rate
                heapq.heappush(self.events, (f.done_at, 0, "flow", fid))
            else:
                f.done_at = INF

    def _complete_flow(self, fid):
        f = self.flows.pop(fid)
        ch = f.child
        self.trace.append((self.t, TraceKind.FLOW_FINISH, f.dst, ch, 0.0))
        self.dep_count[ch] -= 1
        if self.dep_count[ch] == 0:
            self._enqueue(ch)

    # ---- main loop ----------------------------------------------------
    def run(self):
        cfg = self.cfg
        T = cfg.tasks_per_job
        n_jobs = len(self.arrivals)
        self.task_service = {}
        self.task_server = {}
        self.dep_count = {}
        self.children = {}
        self.child_bytes = {}
        self.remaining = {}

        for j, t in enumerate(self.arrivals):
            heapq.heappush(self.events, (float(t), 3, "arrive", j))

        # servers are IDLE since t=0: their first delay timer is armed
        # immediately (matches the engine's idle_since initialization)
        for srv in range(cfg.n_servers):
            self._idle_edge(srv)

        # setpoint-controller ticks are events (the engine advances to
        # ctrl_next exactly; the update itself runs post-accrue below)
        if self.thermal_on and cfg.thermal.has_ctrl:
            heapq.heappush(self.events, (self.ctrl_next, -1, "ctrl", None))

        while self.events:
            # throttle-threshold crossings are events of their own: the
            # engine solves the RC exponential for the crossing time
            t_cross = self._next_thermal_crossing()
            if t_cross < self.events[0][0]:
                self._accrue_all(t_cross)
                self._apply_throttle()
                self._apply_ctrl()
                continue

            t_next, _, kind, payload = heapq.heappop(self.events)
            self._accrue_all(t_next)
            self._apply_throttle()
            self._apply_ctrl()

            if kind == "ctrl":
                # the tick itself already ran in _apply_ctrl; keep the
                # clock armed while jobs remain
                if len(self.job_finish) < n_jobs:
                    heapq.heappush(self.events,
                                   (self.ctrl_next, -1, "ctrl", None))
                self._recompute_rates()
                continue

            if kind == "release":
                # all same-time releases, lowest job id first, admitted in
                # arrivals_per_step chunks against shared snapshots (the
                # engine's release pass: compact_mask ascending ids, one
                # chunk per step)
                batch = [payload]
                while self.events and self.events[0][0] == t_next \
                        and self.events[0][2] == "release":
                    batch.append(heapq.heappop(self.events)[3])
                batch.sort()
                sp = cfg.server_power
                K = max(int(cfg.arrivals_per_step), 1)
                for c0 in range(0, len(batch), K):
                    chunk = batch[c0:c0 + K]
                    for j in chunk:
                        self.trace.append(
                            (self.t, TraceKind.RELEASE, -1, j,
                             float(self.t - self.arrivals[j])))
                        self.defer_count += 1
                        self.defer_seconds += self.t - self.arrivals[j]
                        e_kwh = float(np.sum(self.specs[j].service)) \
                            * (sp.p_core_active - sp.p_core_idle) / 3.6e6
                        self.grams_avoided += e_kwh * (
                            self._carbon_now(self.arrivals[j])
                            - self._carbon_now(self.t))
                    self._admit_chunk(chunk, T, allow_defer=False)
                self._recompute_rates()
                continue

            if kind == "arrive":
                # the engine admits same-timestamp jobs in passes of
                # cfg.arrivals_per_step, each against one scheduler
                # snapshot, draining the chunk's roots before the next
                # chunk — chunk the tied arrivals identically (exact as
                # long as a chunk's root count fits ready_per_step, which
                # drains fully before the next same-time admit step)
                batch = [payload]
                while self.events and self.events[0][0] == t_next \
                        and self.events[0][2] == "arrive":
                    batch.append(heapq.heappop(self.events)[3])
                K = max(int(self.cfg.arrivals_per_step), 1)
                for c0 in range(0, len(batch), K):
                    self._admit_chunk(batch[c0:c0 + K], T)

            elif kind == "complete":
                srv, c = payload
                s = self.servers[srv]
                if s.core_end[c] > self.t + 1e-12 or s.cores[c] is None:
                    continue                      # stale event
                tid = s.cores[c]
                s.cores[c] = None
                s.core_end[c] = INF
                self.finish[tid] = self.t
                self.trace.append(
                    (self.t, TraceKind.FINISH, srv, tid,
                     float(self.t - self.start_t.get(tid, self.t))))
                j = tid // T
                self.remaining[j] -= 1
                if self.remaining[j] == 0:
                    self.job_finish[j] = self.t
                    self.trace.append(
                        (self.t, TraceKind.JOB_FINISH, -1, j,
                         float(self.t - self.arrivals[j])))
                for ch in self.children[tid]:
                    nbytes = self.child_bytes[tid].get(ch, 0.0)
                    if self.topo is not None and nbytes > 0 \
                            and self.task_server[ch] != srv:
                        self._spawn_or_drop_edge(
                            srv, self.task_server[ch], nbytes, ch)
                        continue
                    self.dep_count[ch] -= 1
                    if self.dep_count[ch] == 0:
                        self._enqueue(ch)
                if len(self.job_finish) == n_jobs:
                    break            # engine stops at the last completion
                was_active = s.state == SrvState.ACTIVE
                self._try_start(srv)
                if s.state == SrvState.IDLE and was_active:
                    s.idle_since = self.t
                    self._idle_edge(srv)

            elif kind == "wake":
                srv = payload
                s = self.servers[srv]
                if s.state == SrvState.WAKING and s.wake_at <= self.t + 1e-12:
                    self.trace.append(
                        (self.t, TraceKind.WAKEUP, srv, -1, 0.0))
                    s.state = SrvState.IDLE
                    s.wake_at = INF
                    s.idle_since = self.t
                    self._try_start(srv)
                    if s.state == SrvState.IDLE:
                        self._idle_edge(srv)

            elif kind == "timer":
                srv, stamp = payload
                s = self.servers[srv]
                if s.state == SrvState.IDLE and \
                        abs(s.idle_since - stamp) < 1e-12:
                    s.state = cfg.sleep_state
                    self.trace.append((self.t, TraceKind.SLEEP, srv, -1,
                                       float(cfg.sleep_state)))

            elif kind == "ready":
                self._enqueue(payload)

            elif kind == "flow":
                f = self.flows.get(payload)
                if f is None or f.done_at > self.t + 1e-9:
                    continue                      # stale / rescheduled
                self._complete_flow(payload)
                if len(self.job_finish) == n_jobs:
                    break

            self._recompute_rates()

        return self

    # ---- results ------------------------------------------------------
    def latencies(self):
        return np.asarray([self.job_finish[j] - self.arrivals[j]
                           for j in sorted(self.job_finish)])

    def total_energy(self):
        return sum(s.energy for s in self.servers)

"""Sequential heapq-based DES oracle — the classical implementation of the
paper's engine, used to validate the vectorized JAX engine event-for-event.

Replicates the engine's semantics exactly (no network mode):
  * global scheduler assigns every task of a job at arrival, using a
    load snapshot taken before any of the job's tasks are enqueued
    (LOAD_BALANCE ties break to the lowest server index, like argmin)
  * ROUND_ROBIN advances the pointer per task
  * a task becomes READY when all DAG parents finished (dep_count == 0);
    READY tasks enqueue at their assigned server and trigger wakeups
  * servers sleep after τ seconds of idleness (SINGLE/DUAL timer) into
    cfg.sleep_state; wake latency/power follow the ACPI profile
  * energy integrates the piecewise-constant power curve exactly
  * a task hitting a full local queue (cfg.local_q) is DROPPED: it counts
    toward job completion (finish stamped at drop time) and resolves its
    DAG edges immediately; newly-unblocked children enqueue via a deferred
    same-time event (matching the engine, which drains them next step)
"""
from __future__ import annotations

import heapq
import math

import numpy as np

from repro.core.types import INF, SchedPolicy, SimConfig, SleepPolicy, SrvState


class OracleServer:
    def __init__(self, cfg, i):
        self.cfg = cfg
        self.i = i
        self.cores = [None] * cfg.n_cores     # task id or None
        self.core_end = [INF] * cfg.n_cores
        self.queue = []
        self.state = SrvState.IDLE
        self.idle_since = 0.0
        self.wake_at = INF
        self.tau = INF
        self.energy = 0.0
        self.residency = np.zeros(SrvState.NUM)
        self.busy_core_seconds = 0.0
        self.wake_count = 0

    def busy(self):
        return sum(1 for c in self.cores if c is not None)

    def load(self):
        return self.busy() + len(self.queue)

    def power(self):
        sp = self.cfg.server_power
        if self.state in (SrvState.ACTIVE, SrvState.IDLE):
            b = self.busy()
            return (sp.p_base + b * sp.p_core_active
                    + (self.cfg.n_cores - b) * sp.p_core_idle)
        return {SrvState.PKG_C6: sp.p_pkg_c6, SrvState.S3: sp.p_s3,
                SrvState.OFF: 0.0, SrvState.WAKING: sp.p_wake}[self.state]

    def accrue(self, dt):
        self.energy += self.power() * dt
        self.residency[self.state] += dt
        self.busy_core_seconds += self.busy() * dt


class OracleSim:
    """Run with the same (cfg, arrivals, specs, tau) as farm.simulate."""

    def __init__(self, cfg: SimConfig, arrivals, specs, tau=None):
        self.cfg = cfg
        self.arrivals = np.asarray(arrivals, float)
        self.specs = specs
        self.servers = [OracleServer(cfg, i) for i in range(cfg.n_servers)]
        if tau is not None:
            tau = np.broadcast_to(np.asarray(tau, float),
                                  (cfg.n_servers,))
            for s, tv in zip(self.servers, tau):
                s.tau = float(tv)
        self.t = 0.0
        self.rr = 0
        self.finish = {}
        self.job_finish = {}
        self.events = []
        self.dropped = 0

    # ---- helpers ------------------------------------------------------
    def _wake_latency(self, state):
        sp = self.cfg.server_power
        return {SrvState.PKG_C6: sp.t_wake_pkg_c6, SrvState.S3: sp.t_wake_s3,
                SrvState.OFF: sp.t_wake_off}.get(state, 0.0)

    def _accrue_all(self, t_next):
        dt = t_next - self.t
        assert dt >= -1e-9, (t_next, self.t)
        for s in self.servers:
            s.accrue(max(dt, 0.0))
        self.t = t_next

    def _pick(self, load_snapshot):
        cfg = self.cfg
        if cfg.sched_policy == SchedPolicy.ROUND_ROBIN:
            srv = self.rr % cfg.n_servers
            self.rr = (srv + 1) % cfg.n_servers
            return srv
        scores = list(load_snapshot)
        if cfg.sleep_policy == SleepPolicy.DUAL_TIMER:
            for i, s in enumerate(self.servers):
                scores[i] += (1000.0 if getattr(s, "pool", 0) else 0.0)
        best = min(range(cfg.n_servers), key=lambda i: scores[i])
        return best

    def _try_start(self, srv):
        s = self.servers[srv]
        if s.state not in (SrvState.ACTIVE, SrvState.IDLE):
            return
        while s.queue and None in s.cores:
            c = s.cores.index(None)
            tid = s.queue.pop(0)
            dur = self.task_service[tid] / self.cfg.core_freq
            s.cores[c] = tid
            s.core_end[c] = self.t + dur
            heapq.heappush(self.events,
                           (self.t + dur, 0, "complete", (srv, c)))
        s.state = SrvState.ACTIVE if s.busy() else SrvState.IDLE

    def _drop(self, tid):
        """Full-queue drop: the task completes-with-drop right now and its
        DAG edges resolve; ready children enqueue on a deferred same-time
        event (priority 4: after completions/wakes/timers/arrivals, the
        engine drains them on the following step at the same sim time)."""
        self.dropped += 1
        self.finish[tid] = self.t
        j = tid // self.cfg.tasks_per_job
        self.remaining[j] -= 1
        if self.remaining[j] == 0 and j not in self.job_finish:
            self.job_finish[j] = self.t
        for ch in self.children[tid]:
            self.dep_count[ch] -= 1
            if self.dep_count[ch] == 0:
                heapq.heappush(self.events, (self.t, 4, "ready", ch))

    def _enqueue(self, tid):
        srv = self.task_server[tid]
        s = self.servers[srv]
        if len(s.queue) >= self.cfg.local_q:
            self._drop(tid)
            return
        s.queue.append(tid)
        if s.state in (SrvState.PKG_C6, SrvState.S3, SrvState.OFF):
            lat = self._wake_latency(s.state)
            s.state = SrvState.WAKING
            s.wake_at = self.t + lat
            s.wake_count += 1
            heapq.heappush(self.events, (s.wake_at, 1, "wake", srv))
        self._try_start(srv)

    def _idle_edge(self, srv):
        """Stamp idle_since and schedule the sleep timer."""
        s = self.servers[srv]
        if s.state == SrvState.IDLE and s.tau < INF / 2 \
                and self.cfg.sleep_policy in (SleepPolicy.SINGLE_TIMER,
                                              SleepPolicy.DUAL_TIMER):
            heapq.heappush(self.events,
                           (self.t + s.tau, 2, "timer", (srv, self.t)))

    # ---- main loop ----------------------------------------------------
    def run(self):
        cfg = self.cfg
        T = cfg.tasks_per_job
        n_jobs = len(self.arrivals)
        self.task_service = {}
        self.task_server = {}
        self.dep_count = {}
        self.children = {}
        self.remaining = {}

        for j, t in enumerate(self.arrivals):
            heapq.heappush(self.events, (float(t), 3, "arrive", j))

        # servers are IDLE since t=0: their first delay timer is armed
        # immediately (matches the engine's idle_since initialization)
        for srv in range(cfg.n_servers):
            self._idle_edge(srv)

        while self.events:
            t_next, _, kind, payload = heapq.heappop(self.events)
            self._accrue_all(t_next)

            if kind == "arrive":
                j = payload
                spec = self.specs[j]
                nt = len(spec.service)
                self.remaining[j] = nt
                load_snapshot = [s.load() for s in self.servers]
                dep = {i: 0 for i in range(nt)}
                kids = {i: [] for i in range(nt)}
                for (p, c, b) in spec.edges:
                    dep[c] += 1
                    kids[p].append(c)
                for i in range(nt):
                    tid = j * T + i
                    self.task_service[tid] = float(spec.service[i])
                    self.task_server[tid] = self._pick(load_snapshot) \
                        if cfg.sched_policy == SchedPolicy.ROUND_ROBIN \
                        else self._pick(load_snapshot)
                    self.dep_count[tid] = dep[i]
                    self.children[tid] = [j * T + c for c in kids[i]]
                # snapshot the root set BEFORE enqueuing: a root dropped by
                # a full queue zeroes its children's dep_count, but those
                # children are NOT roots (the engine marks roots once, at
                # admit) — they enqueue via the deferred "ready" event
                roots = [j * T + i for i in range(nt)
                         if self.dep_count[j * T + i] == 0]
                for tid in roots:
                    self._enqueue(tid)

            elif kind == "complete":
                srv, c = payload
                s = self.servers[srv]
                if s.core_end[c] > self.t + 1e-12 or s.cores[c] is None:
                    continue                      # stale event
                tid = s.cores[c]
                s.cores[c] = None
                s.core_end[c] = INF
                self.finish[tid] = self.t
                j = tid // T
                self.remaining[j] -= 1
                if self.remaining[j] == 0:
                    self.job_finish[j] = self.t
                for ch in self.children[tid]:
                    self.dep_count[ch] -= 1
                    if self.dep_count[ch] == 0:
                        self._enqueue(ch)
                if len(self.job_finish) == n_jobs:
                    break            # engine stops at the last completion
                was_active = s.state == SrvState.ACTIVE
                self._try_start(srv)
                if s.state == SrvState.IDLE and was_active:
                    s.idle_since = self.t
                    self._idle_edge(srv)

            elif kind == "wake":
                srv = payload
                s = self.servers[srv]
                if s.state == SrvState.WAKING and s.wake_at <= self.t + 1e-12:
                    s.state = SrvState.IDLE
                    s.wake_at = INF
                    s.idle_since = self.t
                    self._try_start(srv)
                    if s.state == SrvState.IDLE:
                        self._idle_edge(srv)

            elif kind == "timer":
                srv, stamp = payload
                s = self.servers[srv]
                if s.state == SrvState.IDLE and \
                        abs(s.idle_since - stamp) < 1e-12:
                    s.state = cfg.sleep_state

            elif kind == "ready":
                self._enqueue(payload)

        return self

    # ---- results ------------------------------------------------------
    def latencies(self):
        return np.asarray([self.job_finish[j] - self.arrivals[j]
                           for j in sorted(self.job_finish)])

    def total_energy(self):
        return sum(s.energy for s in self.servers)

"""Thermal/cooling & carbon-cost subsystem validation:

  * jitted RC temperatures / cooling energy / carbon / cost match the
    numpy reference integrator (tests/oracle.py) within f32 tolerance,
    across sleep policies and throttling configs
  * steady state: T -> T_inlet + P·r_th (closed-form fixed point)
  * thermal.enabled=False and a coupling-free thermal run produce
    bit-identical dynamics to each other (temperature tracking alone
    must not perturb the simulation)
  * throttling engages via a solved threshold-crossing event and
    stretches in-flight work by the analytic amount
  * THERMAL_AWARE placement matches the oracle and cools the peak
  * telemetry window conservation for the new thermal columns
  * vmapped replica sweeps carry the thermal stats
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.core import farm as farm_mod
from repro.core import montecarlo, topology, traceio, \
    workload
from repro.core.jobs import dag_single
from repro.core.types import (INF, SchedPolicy, SimConfig, SleepPolicy,
                              SrvState, TelemetryConfig, ThermalConfig,
                              TraceConfig, TraceKind)

from oracle import OracleSim

# hot parameters: a busy server (~84 W at one busy core) targets
# ~22 + 84·0.5 = 64 °C with a 2 s time constant, so temperatures move on
# the same scale as the workload
HOT = dict(enabled=True, r_th=0.5, tau_th=2.0, t_inlet=22.0, recirc=0.2,
           rack_size=3)


def _workload(n_jobs=150, lam=60.0, seed=3, svc_seed=7, mean=0.02):
    rng = np.random.default_rng(svc_seed)
    arr = workload.poisson_arrivals(lam, n_jobs, seed=seed)
    specs = [dag_single(rng.exponential(mean)) for _ in range(n_jobs)]
    return arr, specs


def _run_both(cfg, arr, specs, tau=None):
    res = farm_mod.simulate(cfg, arr, specs, tau=tau)
    orc = OracleSim(cfg, arr, specs, tau=tau).run()
    return res, orc


@pytest.mark.parametrize("policy,tau,throttle", [
    (SleepPolicy.ALWAYS_ON, None, False),
    (SleepPolicy.SINGLE_TIMER, 0.05, False),
    (SleepPolicy.ALWAYS_ON, None, True),
    (SleepPolicy.SINGLE_TIMER, 0.05, True),
])
def test_thermal_matches_numpy_oracle(policy, tau, throttle):
    """Temperatures, cooling energy, carbon, and cost from the jitted
    engine match the sequential numpy integrator within f32 tolerance."""
    tcfg = ThermalConfig(**HOT,
                         t_throttle=50.0 if throttle else INF,
                         t_release=45.0 if throttle else INF,
                         throttle_freq=0.5, throttle_power_scale=0.6,
                         carbon_period=600.0, price_period=600.0)
    cfg = SimConfig(n_servers=6, n_cores=2, max_jobs=256, tasks_per_job=1,
                    sched_policy=SchedPolicy.LOAD_BALANCE,
                    sleep_policy=policy, sleep_state=SrvState.S3,
                    max_events=60_000, thermal=tcfg,
                    trace=TraceConfig(enabled=True))
    arr, specs = _workload()
    res, orc = _run_both(cfg, arr, specs, tau=tau)

    assert res.n_finished == len(arr) == len(orc.job_finish)
    # flight recorder: the full event stream agrees with the oracle's,
    # including the solved throttle crossings
    msg = traceio.diff_traces(res.trace_events,
                              traceio.as_events(orc.trace),
                              time_tol=5e-3)
    assert msg is None, msg
    if throttle:
        kinds = set(res.trace_events["kind"].tolist())
        assert TraceKind.THROTTLE_CROSSING in kinds
    np.testing.assert_allclose(np.sort(res.latencies),
                               np.sort(orc.latencies()),
                               rtol=1e-3, atol=1e-4)
    assert res.server_energy == pytest.approx(orc.total_energy(), rel=2e-3)
    np.testing.assert_allclose(res.temps, orc.temp, rtol=2e-3, atol=5e-2)
    np.testing.assert_allclose(res.peak_temps, orc.t_peak,
                               rtol=2e-3, atol=5e-2)
    assert res.cooling_energy == pytest.approx(orc.cool_energy, rel=2e-3)
    assert res.carbon_g == pytest.approx(orc.carbon_g, rel=2e-3)
    assert res.energy_cost == pytest.approx(orc.cost, rel=2e-3)
    if throttle:
        assert res.throttle_seconds > 0.0
        assert res.throttle_seconds == pytest.approx(
            orc.throttle_seconds.sum(), rel=5e-3, abs=1e-3)


def test_steady_state_temperature():
    """With recirculation off, a held power level converges to the RC
    fixed point T_inlet + P·r_th."""
    tcfg = ThermalConfig(enabled=True, r_th=0.5, tau_th=0.05, recirc=0.0)
    cfg = SimConfig(n_servers=2, n_cores=1, max_jobs=16, tasks_per_job=1,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=10_000,
                    thermal=tcfg)
    # one 5 s task on server 0 (100 time constants): both servers reach
    # their fixed points long before it completes
    res = farm_mod.simulate(cfg, np.asarray([0.0]), [dag_single(5.0)])
    sp = cfg.server_power
    p_busy = sp.p_base + sp.p_core_active            # 1 busy core of 1
    p_idle = sp.p_base + sp.p_core_idle
    busy_srv = int(np.argmax(res.temps))
    assert res.peak_temps[busy_srv] == pytest.approx(
        tcfg.t_inlet + p_busy * tcfg.r_th, rel=1e-4)
    assert res.temps[1 - busy_srv] == pytest.approx(
        tcfg.t_inlet + p_idle * tcfg.r_th, rel=1e-4)


def test_tracking_only_thermal_is_bit_identical_to_disabled():
    """Temperature *tracking* (no throttling, no thermal placement) must
    not perturb the simulation at all: every non-thermal state leaf is
    bit-identical to the thermal-disabled run."""
    arr, specs = _workload(n_jobs=120)
    base = SimConfig(n_servers=5, n_cores=2, max_jobs=128, tasks_per_job=1,
                     sleep_policy=SleepPolicy.SINGLE_TIMER,
                     sleep_state=SrvState.PKG_C6, max_events=40_000)
    off = farm_mod.simulate(base, arr, specs, tau=0.05)
    on = farm_mod.simulate(
        dataclasses.replace(base, thermal=ThermalConfig(**HOT)),
        arr, specs, tau=0.05)
    assert off.events == on.events
    np.testing.assert_array_equal(off.latencies, on.latencies)
    np.testing.assert_array_equal(off.energy_per_server,
                                  on.energy_per_server)
    np.testing.assert_array_equal(off.residency, on.residency)
    assert np.isnan(off.peak_temp) and on.peak_temp > HOT["t_inlet"]


def test_throttle_crossing_is_exact():
    """Single busy server, recirc off: the engine must throttle at the
    analytic RC crossing time and the job must finish at the analytically
    stretched completion time (the crossing is an *event*, not a check at
    the next unrelated event)."""
    tf = 0.5
    # crossing_guard=INF: the crossing here starts 28 °C below the
    # threshold with no intervening events, so the guard-band gating must
    # be disabled for the solve to fire from that far away (the default
    # band defers engagement to the next event — of which there are none
    # until the completion itself)
    tcfg = ThermalConfig(enabled=True, r_th=0.5, tau_th=1.0, recirc=0.0,
                         t_throttle=50.0, t_release=40.0, crossing_guard=INF,
                         throttle_freq=tf, throttle_power_scale=1.0)
    cfg = SimConfig(n_servers=1, n_cores=1, max_jobs=16, tasks_per_job=1,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=5_000,
                    thermal=tcfg)
    svc = 4.0
    res = farm_mod.simulate(cfg, np.asarray([0.0]), [dag_single(svc)])
    sp = cfg.server_power
    target = tcfg.t_inlet + (sp.p_base + sp.p_core_active) * tcfg.r_th
    t0 = tcfg.t_inlet
    t_cross = tcfg.tau_th * math.log((target - t0)
                                     / (target - tcfg.t_throttle))
    expect = t_cross + (svc - t_cross) / tf
    assert res.n_finished == 1
    assert res.latencies[0] == pytest.approx(expect, rel=1e-3)
    assert res.throttle_seconds == pytest.approx(
        res.latencies[0] - t_cross, rel=1e-3)
    # power_scale=1.0 keeps the heat on: temperature still tends to the
    # RC target (throttling here slows work, it does not cool), bounded
    # by the fixed point
    assert tcfg.t_throttle < res.peak_temp <= target + 1e-2


def test_tiny_crossing_at_large_t_makes_progress():
    """ulp regression: at t ~ 86400 s (f32 ulp ~ 8 ms) a sub-ulp solved
    crossing dt must not round t_cross back onto t and spin the frozen
    clock to max_events — next_crossing forces at least one representable
    tick of progress.

    Scenario: the server idles at its 55.5 °C fixed point until a job
    arrives at t=86400; the busy target is 61 °C and the threshold sits
    3 mK above the idle temperature, so the solved crossing dt (~0.5 ms)
    is far below ulp(86400)."""
    tcfg = ThermalConfig(enabled=True, r_th=0.5, tau_th=1.0, recirc=0.0,
                         t_throttle=55.503, t_release=55.0,
                         throttle_freq=0.5)
    cfg = SimConfig(n_servers=1, n_cores=1, max_jobs=16, tasks_per_job=1,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=2_000,
                    thermal=tcfg)
    res = farm_mod.simulate(cfg, np.asarray([86400.0]), [dag_single(2.0)])
    assert res.n_finished == 1
    assert res.events < 200                      # no frozen-time spin
    # throttle engaged just after the arrival, not during the long idle
    assert 0.0 < res.throttle_seconds < 10.0


def test_thermal_aware_matches_oracle_and_cools_peak():
    """THERMAL_AWARE places on the coolest eligible server: it matches
    the oracle's scoring and beats ROUND_ROBIN's peak temperature on an
    asymmetric-rack farm (rack of 4 recirculates hotter than rack of 2)."""
    tcfg = ThermalConfig(**{**HOT, "recirc": 0.6, "rack_size": 4})
    cfg = SimConfig(n_servers=6, n_cores=1, max_jobs=256, tasks_per_job=1,
                    sched_policy=SchedPolicy.THERMAL_AWARE,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=60_000,
                    thermal=tcfg)
    arr, specs = _workload(n_jobs=120, lam=25.0, mean=0.08)
    res, orc = _run_both(cfg, arr, specs)
    assert res.n_finished == len(arr)
    np.testing.assert_allclose(np.sort(res.latencies),
                               np.sort(orc.latencies()),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(res.temps, orc.temp, rtol=2e-3, atol=5e-2)

    rr = farm_mod.simulate(
        dataclasses.replace(cfg, sched_policy=SchedPolicy.ROUND_ROBIN),
        arr, specs)
    assert res.peak_temp <= rr.peak_temp + 1e-3


def test_thermal_window_conservation():
    """The thermal telemetry columns integrate exactly: cooling power
    windows sum to the CRAC energy and the carbon/cost windows sum to the
    accumulated totals (both are closed-form interval integrals)."""
    tcfg = ThermalConfig(**HOT, carbon_period=120.0, carbon_swing=0.5,
                         price_period=120.0, price_swing=0.5)
    cfg = SimConfig(n_servers=4, n_cores=2, max_jobs=256, tasks_per_job=1,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=40_000,
                    thermal=tcfg,
                    telemetry=TelemetryConfig(n_windows=64, window_dt=0.2))
    arr, specs = _workload(n_jobs=150, lam=50.0)
    res = farm_mod.simulate(cfg, arr, specs)
    ts = res.telemetry
    joules_cool = np.nansum(ts.cooling_power * ts.occupancy)
    assert joules_cool == pytest.approx(res.cooling_energy, rel=1e-4)
    assert ts.carbon_per_window.sum() == pytest.approx(res.carbon_g,
                                                       rel=1e-4)
    assert ts.cost_per_window.sum() == pytest.approx(res.energy_cost,
                                                     rel=1e-4)
    occ = ts.occupancy > 0
    assert (ts.max_temp[occ] + 1e-3 >= ts.mean_temp[occ]).all()
    # time-averaged carbon intensity stays inside the diurnal band
    ci = ts.carbon_intensity[occ]
    lo = tcfg.carbon_base * (1 - tcfg.carbon_swing) - 1e-3
    hi = tcfg.carbon_base * (1 + tcfg.carbon_swing) + 1e-3
    assert ((ci >= lo) & (ci <= hi)).all()


def test_topology_rack_grouping():
    """rack_of_servers groups by first-hop switch: fat-tree k=4 pods have
    2-server edge racks; the star is one rack; CamCube falls back to
    chunks."""
    ft = topology.fat_tree(4)
    racks = topology.rack_of_servers(ft)
    _, counts = np.unique(racks, return_counts=True)
    assert (counts == 2).all() and len(counts) == 8
    st = topology.star(6)
    assert len(np.unique(topology.rack_of_servers(st))) == 1
    cc = topology.camcube(2, 2, 2)
    assert len(np.unique(topology.rack_of_servers(cc, rack_size=4))) == 2


def test_per_rack_setpoints_different_steady_states():
    """Two racks at different CRAC setpoints: with recirc off, each
    server's fixed point is its OWN rack's setpoint + P·r_th, and the
    cooling energy integrates each rack's load at its own quadratic COP
    (colder supply => worse COP => more CRAC joules for the same IT)."""
    tcfg = ThermalConfig(enabled=True, r_th=0.5, tau_th=0.05, recirc=0.0,
                         rack_size=1, t_setpoint=(16.0, 26.0))
    cfg = SimConfig(n_servers=2, n_cores=1, max_jobs=16, tasks_per_job=1,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=10_000,
                    thermal=tcfg)
    # both servers busy for 5 s (100 time constants): fixed points reached
    res = farm_mod.simulate(cfg, np.asarray([0.0, 0.0]),
                            [dag_single(5.0), dag_single(5.0)])
    sp = cfg.server_power
    p_busy = sp.p_base + sp.p_core_active
    for i, t_set in enumerate((16.0, 26.0)):
        assert res.peak_temps[i] == pytest.approx(
            t_set + p_busy * tcfg.r_th, rel=1e-4)
    # per-rack COP: quadratic at each rack's setpoint, NOT the t_inlet
    # constant — the run's CRAC energy must reflect both
    def cop(t):
        return tcfg.cop_a * t * t + tcfg.cop_b * t + tcfg.cop_c
    orc = OracleSim(cfg, np.asarray([0.0, 0.0]),
                    [dag_single(5.0), dag_single(5.0)]).run()
    assert res.cooling_energy == pytest.approx(orc.cool_energy, rel=2e-3)
    assert cop(16.0) < cop(26.0)     # colder supply is less efficient
    np.testing.assert_array_equal(res.setpoints, [16.0, 26.0])


def test_control_plane_matches_oracle():
    """Per-rack setpoints + diurnal ambient + the setpoint controller +
    throttling, all armed at once: the jitted engine must match the numpy
    oracle event-for-event (latencies) and in every thermal integral,
    with the controller landing both implementations on the SAME final
    setpoints."""
    tcfg = ThermalConfig(**HOT, t_setpoint=(16.0, 26.0),
                         ambient_swing=3.0, ambient_period=40.0,
                         ctrl_period=0.5, ctrl_target=55.0, ctrl_band=2.0,
                         ctrl_step=1.0, ctrl_min=14.0, ctrl_max=27.0,
                         t_throttle=58.0, t_release=52.0,
                         throttle_freq=0.5, throttle_power_scale=0.6,
                         carbon_period=60.0, price_period=60.0)
    cfg = SimConfig(n_servers=6, n_cores=2, max_jobs=256, tasks_per_job=1,
                    sched_policy=SchedPolicy.LOAD_BALANCE,
                    sleep_policy=SleepPolicy.SINGLE_TIMER,
                    sleep_state=SrvState.S3, max_events=80_000,
                    thermal=tcfg)
    arr, specs = _workload(n_jobs=150, lam=40.0, mean=0.04)
    res, orc = _run_both(cfg, arr, specs, tau=0.05)
    assert res.n_finished == len(arr) == len(orc.job_finish)
    np.testing.assert_allclose(np.sort(res.latencies),
                               np.sort(orc.latencies()),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(res.temps, orc.temp, rtol=2e-3, atol=5e-2)
    np.testing.assert_array_equal(res.setpoints, orc.t_set)
    assert res.cooling_energy == pytest.approx(orc.cool_energy, rel=2e-3)
    assert res.carbon_g == pytest.approx(orc.carbon_g, rel=2e-3)
    assert res.energy_cost == pytest.approx(orc.cost, rel=2e-3)
    assert res.throttle_seconds == pytest.approx(
        orc.throttle_seconds.sum(), rel=5e-3, abs=1e-3)
    # the controller actually acted (setpoints moved off their initials)
    assert not np.array_equal(res.setpoints, [16.0, 26.0])


def test_setpoint_controller_cools_hot_rack_relaxes_cold():
    """A loaded rack above ctrl_target steps its setpoint DOWN (colder
    supply); an idle rack below target - band steps UP toward ctrl_max
    (cheaper cooling), both clipped into [ctrl_min, ctrl_max]."""
    # idle fixed point = setpoint + 67·0.5 ≈ setpoint + 33.5, busy ≈
    # setpoint + 39: a 58 °C target with a 2 °C band sits between them,
    # so the busy rack must cool its supply and the idle rack relax it
    tcfg = ThermalConfig(enabled=True, r_th=0.5, tau_th=0.2, recirc=0.0,
                         rack_size=1, t_setpoint=22.0,
                         ctrl_period=0.5, ctrl_target=58.0, ctrl_band=2.0,
                         ctrl_step=1.0, ctrl_min=12.0, ctrl_max=26.0)
    cfg = SimConfig(n_servers=2, n_cores=1, max_jobs=16, tasks_per_job=1,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=10_000,
                    thermal=tcfg)
    # server 0 busy at ~+39 °C over supply, server 1 idle at ~+33.5 °C
    res = farm_mod.simulate(cfg, np.asarray([0.0]), [dag_single(6.0)])
    busy = int(np.argmax(res.peak_temps))
    assert res.setpoints[busy] < 22.0
    assert res.setpoints[1 - busy] > 22.0
    assert (res.setpoints >= tcfg.ctrl_min).all()
    assert (res.setpoints <= tcfg.ctrl_max).all()


def test_carbon_aware_deferral_matches_oracle():
    """CARBON_AWARE on a diurnal carbon curve: deferrable jobs arriving
    in the high-intensity half are parked and released at the solved
    down-crossing; engine and oracle agree on who deferred, for how long,
    and on every latency."""
    tcfg = ThermalConfig(**HOT, carbon_base=300.0, carbon_swing=0.6,
                         carbon_period=120.0, defer_threshold=320.0)
    cfg = SimConfig(n_servers=6, n_cores=2, max_jobs=256, tasks_per_job=1,
                    sched_policy=SchedPolicy.CARBON_AWARE,
                    sleep_policy=SleepPolicy.SINGLE_TIMER,
                    sleep_state=SrvState.PKG_C6, max_events=60_000,
                    thermal=tcfg)
    rng = np.random.default_rng(7)
    n = 150
    arr = workload.wiki_like_trace(n, 4.0, period=120.0, swing=0.5, seed=3)
    specs = [dag_single(rng.exponential(0.05), deferrable=(j % 2 == 0),
                        defer_slack=60.0) for j in range(n)]
    res, orc = _run_both(cfg, arr, specs, tau=0.5)
    assert res.n_finished == n == len(orc.job_finish)
    assert res.deferred_jobs == orc.defer_count > 0
    assert res.deferred_seconds == pytest.approx(orc.defer_seconds,
                                                 rel=1e-4)
    assert res.carbon_g_avoided_est == pytest.approx(orc.grams_avoided,
                                                     rel=1e-3)
    assert res.carbon_g_avoided_est > 0.0
    np.testing.assert_allclose(np.sort(res.latencies),
                               np.sort(orc.latencies()),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(res.temps, orc.temp, rtol=2e-3, atol=5e-2)


def test_deferral_deadline_forces_admission():
    """Threshold below the sinusoid trough: the signal NEVER crosses
    down, so a deferrable job with a finite deadline is admitted exactly
    when the deadline expires (latency = slack + service on an idle
    farm), and one with no deadline admits immediately (no release
    candidate => deferral must not deadlock)."""
    tcfg = ThermalConfig(**HOT, carbon_base=300.0, carbon_swing=0.2,
                         carbon_period=600.0,
                         defer_threshold=100.0)     # < 300·(1−0.2)
    cfg = SimConfig(n_servers=2, n_cores=1, max_jobs=16, tasks_per_job=1,
                    sched_policy=SchedPolicy.CARBON_AWARE,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=5_000,
                    thermal=tcfg)
    slack, svc = 3.0, 0.25
    res = farm_mod.simulate(
        cfg, np.asarray([0.0, 0.0]),
        [dag_single(svc, deferrable=True, defer_slack=slack),
         dag_single(svc, deferrable=True)])         # no deadline
    assert res.n_finished == 2
    assert res.deferred_jobs == 1
    lat = np.sort(res.latencies)
    assert lat[0] == pytest.approx(svc, rel=1e-4)          # admitted now
    assert lat[1] == pytest.approx(slack + svc, rel=1e-4)  # at deadline
    orc = OracleSim(cfg, np.asarray([0.0, 0.0]),
                    [dag_single(svc, deferrable=True, defer_slack=slack),
                     dag_single(svc, deferrable=True)]).run()
    np.testing.assert_allclose(lat, np.sort(orc.latencies()),
                               rtol=1e-4, atol=1e-4)


def test_release_train_precedes_coincident_arrival():
    """More deferred jobs due at one instant than arrivals_per_step, with
    a fresh arrival landing at exactly that instant: the engine must
    admit EVERY release chunk before the arrival (the oracle's event
    order) instead of interleaving the arrival between chunks against a
    partial load snapshot."""
    tcfg = ThermalConfig(**HOT, carbon_base=300.0, carbon_swing=0.2,
                         carbon_period=600.0,
                         defer_threshold=100.0)     # always above: deadline
    cfg = SimConfig(n_servers=2, n_cores=1, max_jobs=32, tasks_per_job=1,
                    sched_policy=SchedPolicy.CARBON_AWARE,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=10_000,
                    thermal=tcfg)
    slack = 3.0
    n_def = cfg.arrivals_per_step + 3       # 11 due at t=slack, 2 chunks
    arr = np.concatenate([np.zeros(n_def), [slack]])
    specs = [dag_single(0.5, deferrable=True, defer_slack=slack)
             for _ in range(n_def)] + [dag_single(0.5)]
    res, orc = _run_both(cfg, arr, specs)
    assert res.n_finished == n_def + 1 == len(orc.job_finish)
    assert res.deferred_jobs == orc.defer_count == n_def
    np.testing.assert_allclose(np.sort(res.latencies),
                               np.sort(orc.latencies()),
                               rtol=1e-4, atol=1e-4)
    # the coincident arrival queues BEHIND the full release train
    assert res.latencies[-1] == pytest.approx(orc.latencies()[-1],
                                              rel=1e-4)


def test_deferred_dag_job_stays_parked_until_release():
    """Multi-task (DAG) deferral regression: a parked 2-chain job's
    zero-dep root must NOT be promoted by another job's DAG-edge
    resolution (arr_ptr has moved past the parked job, but it is not
    admitted) — it stays BLOCKED until its release, places on a real
    server, and counts in the deferral telemetry; the release must also
    never double-run rows.  Matches the oracle event-for-event."""
    from repro.core.jobs import dag_chain

    tcfg = ThermalConfig(**HOT, carbon_base=300.0, carbon_swing=0.2,
                         carbon_period=600.0,
                         defer_threshold=100.0)     # always above: deadline
    cfg = SimConfig(n_servers=3, n_cores=1, max_jobs=16, tasks_per_job=2,
                    max_children=2,
                    sched_policy=SchedPolicy.CARBON_AWARE,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=10_000,
                    thermal=tcfg)
    slack = 5.0
    def chain():
        return dag_chain([0.4, 0.4])
    parked = chain()
    parked.deferrable, parked.defer_slack = True, slack
    arr = np.asarray([0.0, 0.1])
    specs = [chain(), parked]     # job 0 undeferrable: its edge resolves
    res = farm_mod.simulate(cfg, arr, specs)   # at t=0.4, while 1 parks
    assert res.n_finished == 2
    assert res.deferred_jobs == 1
    lat = res.latencies
    # the deferred chain waited for its deadline, then ran both tasks
    assert lat[1] == pytest.approx((0.1 + slack) + 0.8 - 0.1, rel=1e-4)
    assert lat[0] == pytest.approx(0.8, rel=1e-4)
    orc = OracleSim(cfg, arr, specs).run()
    assert orc.defer_count == 1
    np.testing.assert_allclose(np.sort(lat), np.sort(orc.latencies()),
                               rtol=1e-4, atol=1e-4)


def test_control_plane_k_sweep_bit_identical():
    """Acceptance: per-rack setpoints + controller + diurnal ambient +
    CARBON_AWARE deferral + throttling produce IDENTICAL final states for
    every events_per_step (the macro-step gating stays conservative under
    every new event source)."""
    import dataclasses as dc

    import jax

    from repro.core import engine
    from repro.core.jobs import build_jobs

    tcfg = ThermalConfig(**HOT, t_setpoint=(16.0, 24.0),
                         ambient_swing=3.0, ambient_period=40.0,
                         ctrl_period=0.5, ctrl_target=55.0,
                         t_throttle=58.0, t_release=52.0,
                         throttle_freq=0.5, throttle_power_scale=0.6,
                         carbon_base=300.0, carbon_swing=0.6,
                         carbon_period=60.0, defer_threshold=330.0)
    cfg0 = SimConfig(n_servers=6, n_cores=2, max_jobs=256, tasks_per_job=1,
                     sched_policy=SchedPolicy.CARBON_AWARE,
                     sleep_policy=SleepPolicy.SINGLE_TIMER,
                     sleep_state=SrvState.PKG_C6, max_events=80_000,
                     thermal=tcfg)
    rng = np.random.default_rng(7)
    n = 120
    arr = workload.wiki_like_trace(n, 4.0, period=60.0, swing=0.5, seed=3)
    specs = [dag_single(rng.exponential(0.05), deferrable=(j % 2 == 0),
                        defer_slack=30.0) for j in range(n)]
    outs = {}
    for k in (1, 8):
        cfg = dc.replace(cfg0, events_per_step=k)
        jt = build_jobs(cfg, arr, specs)
        state, tc = engine.init_state(cfg, jt)
        state = dc.replace(state, farm=dc.replace(
            state.farm, srv_tau=jnp_full(cfg, 0.5)))
        outs[k] = engine.run(state, cfg, tc)
    for (kp, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(outs[1]),
            jax.tree_util.tree_leaves_with_path(outs[8])):
        if jax.tree_util.keystr(kp) == ".steps":
            continue      # macro-step count: K-dependent by definition
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"K=8 vs K=1: leaf {jax.tree_util.keystr(kp)}")
    assert int(outs[1].thermal.defer_count) > 0


def jnp_full(cfg, v):
    import jax.numpy as jnp
    return jnp.full((cfg.n_servers,), v, cfg.time_dtype)


def test_replica_sweep_carries_thermal_stats():
    tcfg = ThermalConfig(**HOT, t_throttle=50.0, t_release=45.0)
    cfg = SimConfig(n_servers=4, n_cores=2, max_jobs=64, tasks_per_job=1,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=20_000,
                    thermal=tcfg)
    n_jobs, R = 60, 3
    rng = np.random.default_rng(0)
    specs = [dag_single(rng.exponential(0.02)) for _ in range(n_jobs)]
    arrs = np.stack([workload.poisson_arrivals(40.0, n_jobs, seed=s)
                     for s in range(R)])
    state_b, tc = montecarlo.batched_state(cfg, arrs, specs)
    out = montecarlo.run_replicas(cfg, state_b, tc)
    stats = montecarlo.replica_stats(out, cfg)
    assert (stats["finished"] == n_jobs).all()
    for key in ("cooling_energy", "carbon_g", "energy_cost", "peak_temp"):
        assert stats[key].shape == (R,)
        assert np.isfinite(stats[key]).all()
    assert (stats["peak_temp"] > tcfg.t_inlet).all()
    # replicas see different workloads -> different thermal outcomes
    assert len(set(np.round(stats["carbon_g"], 6))) > 1
    # solo run agrees with the vmapped replica
    solo = farm_mod.simulate(cfg, arrs[0], specs)
    assert stats["cooling_energy"][0] == pytest.approx(solo.cooling_energy,
                                                       rel=1e-5)
    assert stats["peak_temp"][0] == pytest.approx(solo.peak_temp, rel=1e-5)

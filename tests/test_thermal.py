"""Thermal/cooling & carbon-cost subsystem validation:

  * jitted RC temperatures / cooling energy / carbon / cost match the
    numpy reference integrator (tests/oracle.py) within f32 tolerance,
    across sleep policies and throttling configs
  * steady state: T -> T_inlet + P·r_th (closed-form fixed point)
  * thermal.enabled=False and a coupling-free thermal run produce
    bit-identical dynamics to each other (temperature tracking alone
    must not perturb the simulation)
  * throttling engages via a solved threshold-crossing event and
    stretches in-flight work by the analytic amount
  * THERMAL_AWARE placement matches the oracle and cools the peak
  * telemetry window conservation for the new thermal columns
  * vmapped replica sweeps carry the thermal stats
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.core import farm as farm_mod
from repro.core import montecarlo, telemetry, thermal, topology, workload
from repro.core.jobs import dag_single
from repro.core.types import (INF, SchedPolicy, SimConfig, SleepPolicy,
                              SrvState, TelemetryConfig, ThermalConfig)

from oracle import OracleSim

# hot parameters: a busy server (~84 W at one busy core) targets
# ~22 + 84·0.5 = 64 °C with a 2 s time constant, so temperatures move on
# the same scale as the workload
HOT = dict(enabled=True, r_th=0.5, tau_th=2.0, t_inlet=22.0, recirc=0.2,
           rack_size=3)


def _workload(n_jobs=150, lam=60.0, seed=3, svc_seed=7, mean=0.02):
    rng = np.random.default_rng(svc_seed)
    arr = workload.poisson_arrivals(lam, n_jobs, seed=seed)
    specs = [dag_single(rng.exponential(mean)) for _ in range(n_jobs)]
    return arr, specs


def _run_both(cfg, arr, specs, tau=None):
    res = farm_mod.simulate(cfg, arr, specs, tau=tau)
    orc = OracleSim(cfg, arr, specs, tau=tau).run()
    return res, orc


@pytest.mark.parametrize("policy,tau,throttle", [
    (SleepPolicy.ALWAYS_ON, None, False),
    (SleepPolicy.SINGLE_TIMER, 0.05, False),
    (SleepPolicy.ALWAYS_ON, None, True),
    (SleepPolicy.SINGLE_TIMER, 0.05, True),
])
def test_thermal_matches_numpy_oracle(policy, tau, throttle):
    """Temperatures, cooling energy, carbon, and cost from the jitted
    engine match the sequential numpy integrator within f32 tolerance."""
    tcfg = ThermalConfig(**HOT,
                         t_throttle=50.0 if throttle else INF,
                         t_release=45.0 if throttle else INF,
                         throttle_freq=0.5, throttle_power_scale=0.6,
                         carbon_period=600.0, price_period=600.0)
    cfg = SimConfig(n_servers=6, n_cores=2, max_jobs=256, tasks_per_job=1,
                    sched_policy=SchedPolicy.LOAD_BALANCE,
                    sleep_policy=policy, sleep_state=SrvState.S3,
                    max_events=60_000, thermal=tcfg)
    arr, specs = _workload()
    res, orc = _run_both(cfg, arr, specs, tau=tau)

    assert res.n_finished == len(arr) == len(orc.job_finish)
    np.testing.assert_allclose(np.sort(res.latencies),
                               np.sort(orc.latencies()),
                               rtol=1e-3, atol=1e-4)
    assert res.server_energy == pytest.approx(orc.total_energy(), rel=2e-3)
    np.testing.assert_allclose(res.temps, orc.temp, rtol=2e-3, atol=5e-2)
    np.testing.assert_allclose(res.peak_temps, orc.t_peak,
                               rtol=2e-3, atol=5e-2)
    assert res.cooling_energy == pytest.approx(orc.cool_energy, rel=2e-3)
    assert res.carbon_g == pytest.approx(orc.carbon_g, rel=2e-3)
    assert res.energy_cost == pytest.approx(orc.cost, rel=2e-3)
    if throttle:
        assert res.throttle_seconds > 0.0
        assert res.throttle_seconds == pytest.approx(
            orc.throttle_seconds.sum(), rel=5e-3, abs=1e-3)


def test_steady_state_temperature():
    """With recirculation off, a held power level converges to the RC
    fixed point T_inlet + P·r_th."""
    tcfg = ThermalConfig(enabled=True, r_th=0.5, tau_th=0.05, recirc=0.0)
    cfg = SimConfig(n_servers=2, n_cores=1, max_jobs=16, tasks_per_job=1,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=10_000,
                    thermal=tcfg)
    # one 5 s task on server 0 (100 time constants): both servers reach
    # their fixed points long before it completes
    res = farm_mod.simulate(cfg, np.asarray([0.0]), [dag_single(5.0)])
    sp = cfg.server_power
    p_busy = sp.p_base + sp.p_core_active            # 1 busy core of 1
    p_idle = sp.p_base + sp.p_core_idle
    busy_srv = int(np.argmax(res.temps))
    assert res.peak_temps[busy_srv] == pytest.approx(
        tcfg.t_inlet + p_busy * tcfg.r_th, rel=1e-4)
    assert res.temps[1 - busy_srv] == pytest.approx(
        tcfg.t_inlet + p_idle * tcfg.r_th, rel=1e-4)


def test_tracking_only_thermal_is_bit_identical_to_disabled():
    """Temperature *tracking* (no throttling, no thermal placement) must
    not perturb the simulation at all: every non-thermal state leaf is
    bit-identical to the thermal-disabled run."""
    arr, specs = _workload(n_jobs=120)
    base = SimConfig(n_servers=5, n_cores=2, max_jobs=128, tasks_per_job=1,
                     sleep_policy=SleepPolicy.SINGLE_TIMER,
                     sleep_state=SrvState.PKG_C6, max_events=40_000)
    off = farm_mod.simulate(base, arr, specs, tau=0.05)
    on = farm_mod.simulate(
        dataclasses.replace(base, thermal=ThermalConfig(**HOT)),
        arr, specs, tau=0.05)
    assert off.events == on.events
    np.testing.assert_array_equal(off.latencies, on.latencies)
    np.testing.assert_array_equal(off.energy_per_server,
                                  on.energy_per_server)
    np.testing.assert_array_equal(off.residency, on.residency)
    assert np.isnan(off.peak_temp) and on.peak_temp > HOT["t_inlet"]


def test_throttle_crossing_is_exact():
    """Single busy server, recirc off: the engine must throttle at the
    analytic RC crossing time and the job must finish at the analytically
    stretched completion time (the crossing is an *event*, not a check at
    the next unrelated event)."""
    tf = 0.5
    # crossing_guard=INF: the crossing here starts 28 °C below the
    # threshold with no intervening events, so the guard-band gating must
    # be disabled for the solve to fire from that far away (the default
    # band defers engagement to the next event — of which there are none
    # until the completion itself)
    tcfg = ThermalConfig(enabled=True, r_th=0.5, tau_th=1.0, recirc=0.0,
                         t_throttle=50.0, t_release=40.0, crossing_guard=INF,
                         throttle_freq=tf, throttle_power_scale=1.0)
    cfg = SimConfig(n_servers=1, n_cores=1, max_jobs=16, tasks_per_job=1,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=5_000,
                    thermal=tcfg)
    svc = 4.0
    res = farm_mod.simulate(cfg, np.asarray([0.0]), [dag_single(svc)])
    sp = cfg.server_power
    target = tcfg.t_inlet + (sp.p_base + sp.p_core_active) * tcfg.r_th
    t0 = tcfg.t_inlet
    t_cross = tcfg.tau_th * math.log((target - t0)
                                     / (target - tcfg.t_throttle))
    expect = t_cross + (svc - t_cross) / tf
    assert res.n_finished == 1
    assert res.latencies[0] == pytest.approx(expect, rel=1e-3)
    assert res.throttle_seconds == pytest.approx(
        res.latencies[0] - t_cross, rel=1e-3)
    # power_scale=1.0 keeps the heat on: temperature still tends to the
    # RC target (throttling here slows work, it does not cool), bounded
    # by the fixed point
    assert tcfg.t_throttle < res.peak_temp <= target + 1e-2


def test_tiny_crossing_at_large_t_makes_progress():
    """ulp regression: at t ~ 86400 s (f32 ulp ~ 8 ms) a sub-ulp solved
    crossing dt must not round t_cross back onto t and spin the frozen
    clock to max_events — next_crossing forces at least one representable
    tick of progress.

    Scenario: the server idles at its 55.5 °C fixed point until a job
    arrives at t=86400; the busy target is 61 °C and the threshold sits
    3 mK above the idle temperature, so the solved crossing dt (~0.5 ms)
    is far below ulp(86400)."""
    tcfg = ThermalConfig(enabled=True, r_th=0.5, tau_th=1.0, recirc=0.0,
                         t_throttle=55.503, t_release=55.0,
                         throttle_freq=0.5)
    cfg = SimConfig(n_servers=1, n_cores=1, max_jobs=16, tasks_per_job=1,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=2_000,
                    thermal=tcfg)
    res = farm_mod.simulate(cfg, np.asarray([86400.0]), [dag_single(2.0)])
    assert res.n_finished == 1
    assert res.events < 200                      # no frozen-time spin
    # throttle engaged just after the arrival, not during the long idle
    assert 0.0 < res.throttle_seconds < 10.0


def test_thermal_aware_matches_oracle_and_cools_peak():
    """THERMAL_AWARE places on the coolest eligible server: it matches
    the oracle's scoring and beats ROUND_ROBIN's peak temperature on an
    asymmetric-rack farm (rack of 4 recirculates hotter than rack of 2)."""
    tcfg = ThermalConfig(**{**HOT, "recirc": 0.6, "rack_size": 4})
    cfg = SimConfig(n_servers=6, n_cores=1, max_jobs=256, tasks_per_job=1,
                    sched_policy=SchedPolicy.THERMAL_AWARE,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=60_000,
                    thermal=tcfg)
    arr, specs = _workload(n_jobs=120, lam=25.0, mean=0.08)
    res, orc = _run_both(cfg, arr, specs)
    assert res.n_finished == len(arr)
    np.testing.assert_allclose(np.sort(res.latencies),
                               np.sort(orc.latencies()),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(res.temps, orc.temp, rtol=2e-3, atol=5e-2)

    rr = farm_mod.simulate(
        dataclasses.replace(cfg, sched_policy=SchedPolicy.ROUND_ROBIN),
        arr, specs)
    assert res.peak_temp <= rr.peak_temp + 1e-3


def test_thermal_window_conservation():
    """The thermal telemetry columns integrate exactly: cooling power
    windows sum to the CRAC energy and the carbon/cost windows sum to the
    accumulated totals (both are closed-form interval integrals)."""
    tcfg = ThermalConfig(**HOT, carbon_period=120.0, carbon_swing=0.5,
                         price_period=120.0, price_swing=0.5)
    cfg = SimConfig(n_servers=4, n_cores=2, max_jobs=256, tasks_per_job=1,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=40_000,
                    thermal=tcfg,
                    telemetry=TelemetryConfig(n_windows=64, window_dt=0.2))
    arr, specs = _workload(n_jobs=150, lam=50.0)
    res = farm_mod.simulate(cfg, arr, specs)
    ts = res.telemetry
    joules_cool = np.nansum(ts.cooling_power * ts.occupancy)
    assert joules_cool == pytest.approx(res.cooling_energy, rel=1e-4)
    assert ts.carbon_per_window.sum() == pytest.approx(res.carbon_g,
                                                       rel=1e-4)
    assert ts.cost_per_window.sum() == pytest.approx(res.energy_cost,
                                                     rel=1e-4)
    occ = ts.occupancy > 0
    assert (ts.max_temp[occ] + 1e-3 >= ts.mean_temp[occ]).all()
    # time-averaged carbon intensity stays inside the diurnal band
    ci = ts.carbon_intensity[occ]
    lo = tcfg.carbon_base * (1 - tcfg.carbon_swing) - 1e-3
    hi = tcfg.carbon_base * (1 + tcfg.carbon_swing) + 1e-3
    assert ((ci >= lo) & (ci <= hi)).all()


def test_topology_rack_grouping():
    """rack_of_servers groups by first-hop switch: fat-tree k=4 pods have
    2-server edge racks; the star is one rack; CamCube falls back to
    chunks."""
    ft = topology.fat_tree(4)
    racks = topology.rack_of_servers(ft)
    _, counts = np.unique(racks, return_counts=True)
    assert (counts == 2).all() and len(counts) == 8
    st = topology.star(6)
    assert len(np.unique(topology.rack_of_servers(st))) == 1
    cc = topology.camcube(2, 2, 2)
    assert len(np.unique(topology.rack_of_servers(cc, rack_size=4))) == 2


def test_replica_sweep_carries_thermal_stats():
    tcfg = ThermalConfig(**HOT, t_throttle=50.0, t_release=45.0)
    cfg = SimConfig(n_servers=4, n_cores=2, max_jobs=64, tasks_per_job=1,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=20_000,
                    thermal=tcfg)
    n_jobs, R = 60, 3
    rng = np.random.default_rng(0)
    specs = [dag_single(rng.exponential(0.02)) for _ in range(n_jobs)]
    arrs = np.stack([workload.poisson_arrivals(40.0, n_jobs, seed=s)
                     for s in range(R)])
    state_b, tc = montecarlo.batched_state(cfg, arrs, specs)
    out = montecarlo.run_replicas(cfg, state_b, tc)
    stats = montecarlo.replica_stats(out, cfg)
    assert (stats["finished"] == n_jobs).all()
    for key in ("cooling_energy", "carbon_g", "energy_cost", "peak_temp"):
        assert stats[key].shape == (R,)
        assert np.isfinite(stats[key]).all()
    assert (stats["peak_temp"] > tcfg.t_inlet).all()
    # replicas see different workloads -> different thermal outcomes
    assert len(set(np.round(stats["carbon_g"], 6))) > 1
    # solo run agrees with the vmapped replica
    solo = farm_mod.simulate(cfg, arrs[0], specs)
    assert stats["cooling_energy"][0] == pytest.approx(solo.cooling_energy,
                                                       rel=1e-5)
    assert stats["peak_temp"][0] == pytest.approx(solo.peak_temp, rel=1e-5)

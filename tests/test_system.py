"""End-to-end system behaviour: the framework's layers working together —
simulator policies vs each other, trainer convergence, serving round trip,
and the roofline/fleet bridge."""
import json
import pathlib

import jax
import numpy as np
import pytest

from repro import configs
from repro.core import farm, workload
from repro.core.jobs import dag_single
from repro.core.types import SchedPolicy, SimConfig, SleepPolicy, SrvState
from repro.data.pipeline import DataConfig, get_batch
from repro.models import transformer
from repro.serve.engine import ServeEngine
from repro.train import optim, step as step_lib


def test_training_reduces_loss():
    cfg = configs.get_smoke("llama3_2_1b")
    state = step_lib.init_state(cfg, jax.random.key(0))
    opt = optim.AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=60)
    ts = jax.jit(step_lib.make_train_step(cfg, opt_cfg=opt))
    dc = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8, seed=0)
    losses = []
    for s in range(40):
        state, m = ts(state, get_batch(dc, s))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05
    assert np.isfinite(losses).all()


def test_serving_round_trip():
    cfg = configs.get_smoke("gemma2_9b")          # swa+attn mixed pattern
    params, _ = transformer.make_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, max_batch=2, max_seq=40)
    outs = eng.generate([[1, 2, 3], [7]], max_new=6)
    assert all(len(o.tokens) >= o.prompt_len + 6 for o in outs)
    assert all(0 <= t < cfg.vocab for o in outs for t in o.tokens)


def test_policy_ordering_energy():
    """System-level sanity: at moderate util, WASP <= single-timer(PkgC6)
    <= Active-Idle on energy for the same workload."""
    rng = np.random.default_rng(0)
    n_jobs = 1200
    specs = [dag_single(rng.exponential(0.005)) for _ in range(n_jobs)]

    def run(policy, sched=SchedPolicy.LOAD_BALANCE, tau=None, pools=None):
        cfg = SimConfig(n_servers=8, n_cores=4, max_jobs=2048,
                        tasks_per_job=1, sched_policy=sched,
                        sleep_policy=policy, sleep_state=SrvState.PKG_C6,
                        wasp_t_wakeup=2.0, wasp_t_sleep=0.3,
                        max_events=80_000)
        lam = workload.utilization_to_rate(0.25, 0.005, 8, 4)
        arr = workload.poisson_arrivals(lam, n_jobs, seed=5)
        return farm.simulate(cfg, arr, specs, tau=tau, pools=pools)

    ai = run(SleepPolicy.ALWAYS_ON)
    tm = run(SleepPolicy.SINGLE_TIMER, tau=0.05)
    wasp = run(SleepPolicy.WASP, SchedPolicy.WASP_POOLS, tau=0.5,
               pools=(np.arange(8) >= 2).astype(np.int32))
    # at this rate per-server idle gaps < τ, so the plain timer ~= AI;
    # WASP consolidates work and wins big (the paper's §IV-C point)
    assert tm.server_energy <= ai.server_energy + 1e-3
    assert wasp.server_energy < 0.75 * ai.server_energy
    for r in (ai, tm, wasp):
        assert r.n_finished == n_jobs


def test_dryrun_results_feed_fleet_bridge():
    """The roofline JSONs produced by the dry-run parse and provide the
    fields the fleet-planning bridge consumes."""
    d = pathlib.Path("results/dryrun")
    if not d.exists() or not list(d.glob("*.json")):
        pytest.skip("dry-run results not present")
    cells = [json.loads(p.read_text()) for p in sorted(d.glob("*.json"))]
    ok = [c for c in cells if "error" not in c]
    assert len(ok) >= 32
    for c in ok:
        assert c["step_time_est"] > 0
        assert c["dominant"] in ("t_compute", "t_memory", "t_collective")
        assert 0 <= c["roofline_fraction"] <= 1.5

"""Vectorized hot-loop validation:

  * queue-overflow DAG workloads terminate with correct drop accounting
    (the seed deadlocked: dropped tasks never resolved their DAG edges)
    and match the heapq oracle event-for-event on a deterministic scenario
  * the dense drain/assign/spawn paths produce IDENTICAL final state to
    the seed scalar fori_loop paths (cfg.use_vectorized_hot_loop=False)
  * batched primitives (queue_push_many, pick_servers_for_job,
    spawn_flows_many) agree with their sequential scalar counterparts
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, farm as farm_mod
from repro.core import network as net_mod
from repro.core import scheduler, server, topology
from repro.core.jobs import build_jobs, dag_chain, dag_single
from repro.core.types import (INF, SchedPolicy, SimConfig, SleepPolicy,
                              SrvState, init_farm, init_flows, init_net,
                              init_sched)

from oracle import OracleSim


# --------------------------------------------------------------------------
# dropped-task DAG resolution (the headline bugfix)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("vectorized", [True, False])
def test_queue_overflow_dag_matches_oracle(vectorized):
    """Deterministic single-server overflow: chains of 2 into a 1-slot
    queue.  Service (100s) dwarfs the arrival span (3s) so every queue
    interaction happens while the server is busy and no completion time
    ever ties an arrival time — engine phase ordering and oracle event
    ordering then coincide exactly."""
    n_jobs = 30
    cfg = SimConfig(n_servers=1, n_cores=1, local_q=1, max_jobs=32,
                    tasks_per_job=2, sched_policy=SchedPolicy.LOAD_BALANCE,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=50_000,
                    use_vectorized_hot_loop=vectorized)
    arr = 0.1 * (1 + np.arange(n_jobs))
    specs = [dag_chain([100.0, 100.0]) for _ in range(n_jobs)]

    res = farm_mod.simulate(cfg, arr, specs)
    orc = OracleSim(cfg, arr, specs).run()

    assert res.events < cfg.max_events          # terminates (no deadlock)
    assert res.n_finished == n_jobs == len(orc.job_finish)
    # jobs 2..29 drop both tasks; job0's child drops behind queued r1
    assert res.dropped == orc.dropped == 2 * (n_jobs - 2) + 1
    np.testing.assert_allclose(np.sort(res.latencies),
                               np.sort(orc.latencies()),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("vectorized", [True, False])
def test_overflow_burst_terminates_with_accounting(vectorized):
    """Bursty multi-server overflow (the seed's deadlock shape): all jobs
    must reach a finite job_finish well before max_events and drops must
    be counted."""
    n_jobs = 30
    cfg = SimConfig(n_servers=2, n_cores=1, local_q=2, max_jobs=32,
                    tasks_per_job=3, sched_policy=SchedPolicy.LOAD_BALANCE,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=50_000,
                    use_vectorized_hot_loop=vectorized)
    arr = np.linspace(0.0, 0.029, n_jobs)
    rng = np.random.default_rng(0)
    specs = [dag_chain(rng.uniform(0.5, 1.0, size=3)) for _ in range(n_jobs)]
    res = farm_mod.simulate(cfg, arr, specs)
    assert res.events < 5_000
    assert res.n_finished == n_jobs            # every job_finish stamped
    assert res.dropped > 0
    assert np.isfinite(res.latencies).all()


# --------------------------------------------------------------------------
# vectorized == scalar (property over whole simulations)
# --------------------------------------------------------------------------

def _final_states_equal(cfg, arr, specs, topo=None, tau=None):
    jt = build_jobs(cfg, np.asarray(arr), specs)
    outs = []
    for vec in (True, False):
        c = dataclasses.replace(cfg, use_vectorized_hot_loop=vec)
        state, tc = engine.init_state(c, jt, topo)
        if tau is not None:
            state = dataclasses.replace(
                state, farm=dataclasses.replace(
                    state.farm,
                    srv_tau=jnp.broadcast_to(
                        jnp.asarray(tau, c.time_dtype), (c.n_servers,))))
        outs.append(engine.run(state, c, tc))
    sv, ss = outs
    leaves_v = jax.tree.leaves(sv)
    leaves_s = jax.tree.leaves(ss)
    paths = [".".join(str(p) for p in kp)
             for kp, _ in jax.tree_util.tree_leaves_with_path(sv)]
    for name, lv, ls in zip(paths, leaves_v, leaves_s):
        np.testing.assert_allclose(
            np.asarray(lv, np.float64), np.asarray(ls, np.float64),
            rtol=1e-6, atol=1e-6, err_msg=f"state leaf {name} diverged")
    return sv


def test_vectorized_matches_scalar_overflow_dag():
    n_jobs = 25
    cfg = SimConfig(n_servers=2, n_cores=1, local_q=2, max_jobs=32,
                    tasks_per_job=3, sched_policy=SchedPolicy.LOAD_BALANCE,
                    sleep_policy=SleepPolicy.SINGLE_TIMER,
                    sleep_state=SrvState.S3, max_events=50_000)
    rng = np.random.default_rng(3)
    arr = np.sort(rng.uniform(0, 0.2, n_jobs))
    specs = [dag_chain(rng.uniform(0.2, 0.6, size=3)) for _ in range(n_jobs)]
    _final_states_equal(cfg, arr, specs, tau=0.05)


def test_vectorized_matches_scalar_round_robin_overflow():
    n_jobs = 40
    cfg = SimConfig(n_servers=3, n_cores=1, local_q=1, max_jobs=64,
                    tasks_per_job=1, sched_policy=SchedPolicy.ROUND_ROBIN,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=50_000)
    rng = np.random.default_rng(5)
    arr = np.sort(rng.uniform(0, 0.5, n_jobs))
    specs = [dag_single(rng.uniform(0.3, 0.8)) for _ in range(n_jobs)]
    _final_states_equal(cfg, arr, specs)


@pytest.mark.parametrize("sched", [SchedPolicy.ROUND_ROBIN,
                                   SchedPolicy.NETWORK_AWARE])
def test_vectorized_matches_scalar_network(sched):
    """ROUND_ROBIN splits each chain across servers so every job routes a
    flow (the batched-spawn path); NETWORK_AWARE covers the wake-cost
    assignment path (its shared-snapshot argmin colocates chains, so it
    spawns none)."""
    n_jobs = 40
    topo = topology.fat_tree(4, link_cap=1.25e9)
    cfg = SimConfig(n_servers=16, n_cores=2, max_jobs=64, tasks_per_job=2,
                    max_children=2, max_flows=128, local_q=8,
                    sched_policy=sched,
                    sleep_policy=SleepPolicy.SINGLE_TIMER,
                    sleep_state=SrvState.S3, has_network=True,
                    max_events=60_000)
    rng = np.random.default_rng(7)
    arr = np.sort(rng.uniform(0, 2.0, n_jobs))
    specs = [dag_chain(rng.uniform(0.01, 0.05, size=2), edge_bytes=100e6)
             for _ in range(n_jobs)]
    final = _final_states_equal(cfg, arr, specs, topo=topo, tau=0.1)
    # ports only leave LPI while links carry flows, so ACTIVE residency
    # proves flows actually routed (not just idle chassis power)
    port_active = float(np.asarray(final.net.port_residency)[..., 0].sum())
    if sched == SchedPolicy.ROUND_ROBIN:
        assert port_active > 0.0
    assert int(final.jobs.tasks_done.sum()) == 2 * n_jobs


# --------------------------------------------------------------------------
# batched primitives vs their scalar counterparts
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_queue_push_many_matches_sequential(seed):
    """Task-major push: the batched multi-push must hand out the same
    accept/drop decisions, per-server occupancy, and FIFO stamps as K
    sequential scalar pushes."""
    cfg = SimConfig(n_servers=4, n_cores=2, local_q=3, max_jobs=16)
    rng = np.random.default_rng(seed)
    farm = init_farm(cfg)
    # pre-fill some queues
    pre = rng.integers(0, cfg.local_q + 1, cfg.n_servers)
    farm = dataclasses.replace(farm, q_len=jnp.asarray(pre, jnp.int32))
    K = 8
    tids = jnp.asarray(rng.integers(0, 64, K), jnp.int32)
    srvs = jnp.asarray(rng.integers(0, cfg.n_servers, K), jnp.int32)
    valid = jnp.asarray(rng.random(K) < 0.8)

    f_seq = farm
    oks, seqs = [], []
    for i in range(K):
        def push(f):
            return server.queue_push(f, cfg, srvs[i], tids[i])
        f2, ok, sq = jax.lax.cond(
            valid[i], push,
            lambda f: (f, jnp.asarray(False), jnp.zeros((), jnp.int32)),
            f_seq)
        f_seq, oks, seqs = f2, oks + [ok], seqs + [sq]
    f_bat, ok_bat, seq_bat = server.queue_push_many(farm, cfg, srvs, tids,
                                                    valid)

    np.testing.assert_array_equal(np.asarray(f_bat.q_len),
                                  np.asarray(f_seq.q_len))
    assert int(f_bat.q_seq) == int(f_seq.q_seq)
    assert int(f_bat.dropped) == int(f_seq.dropped)
    np.testing.assert_array_equal(np.asarray(ok_bat),
                                  np.asarray(jnp.stack(oks)))
    # accepted pushes carry identical FIFO stamps
    ok_np = np.asarray(ok_bat)
    np.testing.assert_array_equal(np.asarray(seq_bat)[ok_np],
                                  np.asarray(jnp.stack(seqs))[ok_np])


def test_round_robin_full_falls_back_to_least_loaded():
    """Seed bug: with every enabled server full, ROUND_ROBIN returned
    rr_ptr's server blindly (a guaranteed later drop).  It must fall back
    to the least-loaded enabled server like the score policies."""
    cfg = SimConfig(n_servers=3, n_cores=2, local_q=2, max_jobs=8,
                    sched_policy=SchedPolicy.ROUND_ROBIN)
    farm = init_farm(cfg)
    # all queues full; server 2 has idle cores (least load), rr_ptr -> 0
    busy = jnp.asarray([[1.0, 1.0], [1.0, INF], [INF, INF]])
    farm = dataclasses.replace(
        farm, q_len=jnp.full((3,), cfg.local_q, jnp.int32),
        core_busy_until=jnp.asarray(busy, jnp.float32))
    sched = init_sched(cfg)
    srv, rr = scheduler.pick_server(farm, cfg, sched)
    assert int(srv) == 2
    assert int(rr) == 0                        # (srv + 1) % N
    # batched assignment agrees
    srvs, _ = scheduler.pick_servers_for_job(
        farm, cfg, sched, jnp.ones((4,), bool))
    assert (np.asarray(srvs) == 2).all()


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_pick_servers_for_job_matches_sequential_rr(seed):
    cfg = SimConfig(n_servers=5, n_cores=1, local_q=2, max_jobs=8,
                    tasks_per_job=6, sched_policy=SchedPolicy.ROUND_ROBIN)
    rng = np.random.default_rng(seed)
    farm = init_farm(cfg)
    farm = dataclasses.replace(
        farm,
        q_len=jnp.asarray(rng.integers(0, cfg.local_q + 1, 5), jnp.int32),
        srv_enabled=jnp.asarray(rng.random(5) < 0.7))
    sched = dataclasses.replace(
        init_sched(cfg), rr_ptr=jnp.asarray(rng.integers(0, 5), jnp.int32))
    valid = jnp.asarray(rng.random(cfg.tasks_per_job) < 0.8)

    seq, rr = [], sched
    for i in range(cfg.tasks_per_job):
        srv, nxt = scheduler.pick_server(farm, cfg, rr)
        if bool(valid[i]):
            seq.append(int(srv))
            rr = dataclasses.replace(rr, rr_ptr=nxt)
    srvs, rr_new = scheduler.pick_servers_for_job(farm, cfg, sched, valid)
    got = [int(s) for s, v in zip(np.asarray(srvs), np.asarray(valid)) if v]
    assert got == seq
    assert int(rr_new) == int(rr.rr_ptr)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_spawn_flows_many_matches_sequential(seed):
    topo = topology.fat_tree(4, link_cap=1.0e9)
    cfg = SimConfig(n_servers=16, n_cores=2, max_flows=6, has_network=True,
                    max_jobs=8)
    tc = net_mod.topo_consts(topo)
    rng = np.random.default_rng(seed)
    E = 10                                     # forces slot exhaustion
    need = jnp.asarray(rng.random(E) < 0.7)
    src = jnp.asarray(rng.integers(0, 16, E), jnp.int32)
    dst = jnp.asarray(rng.integers(0, 16, E), jnp.int32)
    nbytes = jnp.asarray(rng.uniform(1e6, 1e8, E), jnp.float32)
    child = jnp.asarray(rng.integers(0, 16, E), jnp.int32)
    now = jnp.float32(1.0)

    flows0 = init_flows(cfg)
    net0 = init_net(topo.n_switches, topo.n_ports, topo.n_links,
                    topo.n_linecards, cfg)
    # some switches asleep: exercises first-toucher wake-cost semantics
    net0 = dataclasses.replace(
        net0, sw_awake=jnp.asarray(rng.random(topo.n_switches) < 0.5))

    f_seq, n_seq = flows0, net0
    for i in range(E):
        if bool(need[i]):
            f_seq, n_seq, _ = net_mod.spawn_flow(
                f_seq, n_seq, tc, cfg, src[i], dst[i], nbytes[i],
                child[i], now)
    f_bat, n_bat, ok = net_mod.spawn_flows_many(
        flows0, net0, tc, cfg, need, src, dst, nbytes, child, now)

    for field in ("src", "dst", "rem", "rate", "extra", "done_at",
                  "child", "active"):
        np.testing.assert_allclose(
            np.asarray(getattr(f_bat, field), np.float64),
            np.asarray(getattr(f_seq, field), np.float64),
            rtol=1e-6, atol=0, err_msg=f"FlowTable.{field}")
    np.testing.assert_array_equal(np.asarray(n_bat.sw_awake),
                                  np.asarray(n_seq.sw_awake))
    assert int(ok.sum()) == int(f_seq.active.sum())

"""Per-kernel allclose validation against the pure-jnp oracles in
kernels/ref.py, swept over shapes/dtypes (interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssm_scan import ssm_scan
from repro.kernels.dcsim_step import dcsim_advance, INF


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KV,Sq,Skv,hd,causal,window,cap", [
    (2, 4, 2, 256, 256, 64, True, 0, 0.0),
    (1, 4, 4, 128, 128, 128, True, 0, 50.0),     # softcap (gemma2)
    (2, 2, 1, 256, 256, 64, True, 64, 0.0),      # sliding window
    (1, 8, 2, 384, 384, 64, True, 0, 0.0),       # non-multiple of block
    (1, 2, 2, 128, 256, 32, False, 0, 0.0),      # cross attention
])
def test_flash_attention_matches_ref(B, H, KV, Sq, Skv, hd, causal, window,
                                     cap, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, KV, Skv, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, KV, Skv, hd), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, softcap=cap,
                          interpret=True)
    exp = ref.mha_reference(q, k, v, causal=causal, window=window,
                            softcap=cap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.float32(out), np.float32(exp), atol=tol,
                               rtol=tol)


def test_flash_attention_matches_model_attend():
    """The kernel and the model's streaming attend agree (same oracle)."""
    from repro.models.layers import attend
    ks = jax.random.split(jax.random.key(1), 3)
    B, H, KV, S, hd = 2, 4, 2, 192, 64
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    a = attend(q, k, v, causal=True, chunk=64)
    f = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=True,
                        interpret=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.float32(a), np.float32(f), atol=2e-5,
                               rtol=2e-5)


# --------------------------------------------------------------------------
# ssm scan
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,Dss,N,block_d,chunk_t", [
    (2, 64, 256, 16, 128, 16),
    (1, 128, 512, 8, 256, 32),
    (3, 32, 128, 16, 128, 8),
])
def test_ssm_scan_matches_ref(B, S, Dss, N, block_d, chunk_t, dtype):
    ks = jax.random.split(jax.random.key(2), 4)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, Dss))) * 0.1
    Bm = jax.random.normal(ks[1], (B, S, N))
    Cm = jax.random.normal(ks[2], (B, S, N))
    x = jax.random.normal(ks[3], (B, S, Dss))
    A = -jnp.exp(jax.random.normal(jax.random.key(5), (Dss, N)) * 0.3)
    dt, Bm, Cm, x = (a.astype(dtype) for a in (dt, Bm, Cm, x))
    y = ssm_scan(dt, Bm, Cm, x, A, block_d=block_d, chunk_t=chunk_t,
                 interpret=True)
    y_ref, _ = ref.ssm_scan_reference(dt, Bm, Cm, x, A)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.float32(y), np.float32(y_ref), atol=tol,
                               rtol=tol)


# --------------------------------------------------------------------------
# dcsim advance
# --------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(n=st.integers(3, 300), c=st.integers(1, 4), seed=st.integers(0, 999))
def test_dcsim_advance_matches_ref(n, c, seed):
    rng = np.random.default_rng(seed)
    t = np.float32(rng.uniform(0, 10))
    t_next = np.float32(t + rng.uniform(0, 1))
    busy = np.where(rng.random((n, c)) < 0.5,
                    rng.uniform(t, t + 2, (n, c)).astype(np.float32),
                    np.float32(INF))
    state = rng.integers(0, 6, n).astype(np.int32)
    energy = rng.uniform(0, 100, n).astype(np.float32)
    bsec = rng.uniform(0, 10, n).astype(np.float32)
    ptab = jnp.asarray([65.0, 65.0, 15.0, 9.0, 0.0, 145.0], jnp.float32)

    got = dcsim_advance(jnp.asarray(busy), jnp.asarray(state),
                        jnp.asarray(energy), jnp.asarray(bsec),
                        t, t_next, ptab, 13.0, 2.0, interpret=True)
    exp = ref.dcsim_advance_reference(
        jnp.asarray(busy), jnp.asarray(state), jnp.asarray(energy),
        jnp.asarray(bsec), jnp.asarray(t), jnp.asarray(t_next), ptab,
        13.0, 2.0)
    for g, e in zip(got, exp):
        np.testing.assert_allclose(np.float32(g), np.float32(e),
                                   rtol=1e-5, atol=1e-5)

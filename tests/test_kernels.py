"""Per-kernel allclose validation against the pure-jnp oracles in
kernels/ref.py, swept over shapes/dtypes (interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    # optional dev dependency (pyproject [dev]); without it the
    # property-based sweeps fall back to fixed parametrized examples
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                       # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssm_scan import ssm_scan
from repro.kernels.dcsim_step import dcsim_advance, INF
from repro.kernels.telemetry_bin import telemetry_accum


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KV,Sq,Skv,hd,causal,window,cap", [
    (2, 4, 2, 256, 256, 64, True, 0, 0.0),
    (1, 4, 4, 128, 128, 128, True, 0, 50.0),     # softcap (gemma2)
    (2, 2, 1, 256, 256, 64, True, 64, 0.0),      # sliding window
    (1, 8, 2, 384, 384, 64, True, 0, 0.0),       # non-multiple of block
    (1, 2, 2, 128, 256, 32, False, 0, 0.0),      # cross attention
])
def test_flash_attention_matches_ref(B, H, KV, Sq, Skv, hd, causal, window,
                                     cap, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, KV, Skv, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, KV, Skv, hd), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, softcap=cap,
                          interpret=True)
    exp = ref.mha_reference(q, k, v, causal=causal, window=window,
                            softcap=cap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.float32(out), np.float32(exp), atol=tol,
                               rtol=tol)


def test_flash_attention_matches_model_attend():
    """The kernel and the model's streaming attend agree (same oracle)."""
    from repro.models.layers import attend
    ks = jax.random.split(jax.random.key(1), 3)
    B, H, KV, S, hd = 2, 4, 2, 192, 64
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    a = attend(q, k, v, causal=True, chunk=64)
    f = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=True,
                        interpret=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.float32(a), np.float32(f), atol=2e-5,
                               rtol=2e-5)


# --------------------------------------------------------------------------
# ssm scan
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,Dss,N,block_d,chunk_t", [
    (2, 64, 256, 16, 128, 16),
    (1, 128, 512, 8, 256, 32),
    (3, 32, 128, 16, 128, 8),
])
def test_ssm_scan_matches_ref(B, S, Dss, N, block_d, chunk_t, dtype):
    ks = jax.random.split(jax.random.key(2), 4)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, Dss))) * 0.1
    Bm = jax.random.normal(ks[1], (B, S, N))
    Cm = jax.random.normal(ks[2], (B, S, N))
    x = jax.random.normal(ks[3], (B, S, Dss))
    A = -jnp.exp(jax.random.normal(jax.random.key(5), (Dss, N)) * 0.3)
    dt, Bm, Cm, x = (a.astype(dtype) for a in (dt, Bm, Cm, x))
    y = ssm_scan(dt, Bm, Cm, x, A, block_d=block_d, chunk_t=chunk_t,
                 interpret=True)
    y_ref, _ = ref.ssm_scan_reference(dt, Bm, Cm, x, A)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.float32(y), np.float32(y_ref), atol=tol,
                               rtol=tol)


# --------------------------------------------------------------------------
# dcsim advance
# --------------------------------------------------------------------------

def _check_dcsim_advance(n, c, seed):
    rng = np.random.default_rng(seed)
    t = np.float32(rng.uniform(0, 10))
    t_next = np.float32(t + rng.uniform(0, 1))
    busy = np.where(rng.random((n, c)) < 0.5,
                    rng.uniform(t, t + 2, (n, c)).astype(np.float32),
                    np.float32(INF))
    state = rng.integers(0, 6, n).astype(np.int32)
    energy = rng.uniform(0, 100, n).astype(np.float32)
    bsec = rng.uniform(0, 10, n).astype(np.float32)
    wake = np.where(state == 5, rng.uniform(t, t + 3, n),
                    np.float32(INF)).astype(np.float32)
    isince = rng.uniform(0, t, n).astype(np.float32)
    tau = np.where(rng.random(n) < 0.5, rng.uniform(0.1, 2.0, n),
                   np.float32(INF)).astype(np.float32)
    ptab = jnp.asarray([65.0, 65.0, 15.0, 9.0, 0.0, 145.0], jnp.float32)
    # thermally throttled servers accrue scaled active-core power
    throttled = (rng.random(n) < 0.3).astype(np.int32)
    scale = 0.6

    got = dcsim_advance(jnp.asarray(busy), jnp.asarray(state),
                        jnp.asarray(energy), jnp.asarray(bsec),
                        t, t_next, ptab, 13.0, 2.0,
                        jnp.asarray(wake), jnp.asarray(isince),
                        jnp.asarray(tau), jnp.asarray(throttled),
                        throttle_power_scale=scale, interpret=True)
    exp = ref.dcsim_advance_reference(
        jnp.asarray(busy), jnp.asarray(state), jnp.asarray(energy),
        jnp.asarray(bsec), jnp.asarray(t), jnp.asarray(t_next), ptab,
        13.0, 2.0, jnp.asarray(wake), jnp.asarray(isince),
        jnp.asarray(tau), jnp.asarray(throttled),
        throttle_power_scale=scale)
    for g, e in zip(got, exp):
        np.testing.assert_allclose(np.float32(g), np.float32(e),
                                   rtol=1e-5, atol=1e-5)


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(3, 300), c=st.integers(1, 4),
           seed=st.integers(0, 999))
    def test_dcsim_advance_matches_ref(n, c, seed):
        _check_dcsim_advance(n, c, seed)
else:
    @pytest.mark.parametrize("n,c,seed", [
        (3, 1, 0), (17, 2, 5), (120, 3, 7), (256, 4, 11), (300, 1, 42),
    ])
    def test_dcsim_advance_matches_ref(n, c, seed):
        _check_dcsim_advance(n, c, seed)


# --------------------------------------------------------------------------
# telemetry accumulation
# --------------------------------------------------------------------------

@pytest.mark.parametrize("J,M,B,W,block", [
    (64, 64, 32, 16, 64),        # single block
    (200, 700, 64, 32, 256),     # uneven streams, padding
    (1024, 100, 128, 8, 512),    # job stream longer than task stream
])
def test_telemetry_accum_matches_ref(J, M, B, W, block):
    K = 12
    rng = np.random.default_rng(B + J)
    jv = jnp.asarray(rng.uniform(1e-6, 50.0, J), jnp.float32)
    jw = jnp.asarray(rng.random(J) < 0.4, jnp.float32)
    tv = jnp.asarray(rng.uniform(1e-6, 50.0, M), jnp.float32)
    tw = jnp.asarray(rng.random(M) < 0.6, jnp.float32)
    jh = jnp.asarray(rng.uniform(0, 5, B), jnp.float32)
    th = jnp.asarray(rng.uniform(0, 5, B), jnp.float32)
    win = jnp.asarray(rng.uniform(0, 1, (W, K)), jnp.float32)
    widx = jnp.asarray(rng.integers(0, W), jnp.int32)
    wvals = jnp.asarray(rng.uniform(0, 1, K), jnp.float32)
    lo, hi = 1e-5, 1e3

    got = telemetry_accum(jv, jw, tv, tw, jh, th, win, widx, wvals,
                          lo, hi, block=block, interpret=True)
    exp = ref.telemetry_accum_reference(jv, jw, tv, tw, jh, th, win,
                                        widx, wvals, lo, hi)
    for g, e in zip(got, exp):
        np.testing.assert_allclose(np.float32(g), np.float32(e),
                                   rtol=1e-5, atol=1e-5)


def test_telemetry_hist_mass_and_range():
    """Every unit of weight lands in exactly one bin; out-of-range values
    clamp into the edge bins."""
    B, K, W = 16, 12, 4
    vals = jnp.asarray([1e-9, 1e-5, 0.5, 1e3, 1e7], jnp.float32)
    wts = jnp.ones_like(vals)
    z = jnp.zeros((1,), jnp.float32)
    jh, th, _ = ref.telemetry_accum_reference(
        vals, wts, z, z, jnp.zeros((B,), jnp.float32),
        jnp.zeros((B,), jnp.float32), jnp.zeros((W, K), jnp.float32),
        jnp.asarray(0, jnp.int32), jnp.zeros((K,), jnp.float32),
        1e-5, 1e3)
    assert float(jh.sum()) == pytest.approx(5.0)
    assert float(jh[0]) >= 2.0          # 1e-9 and 1e-5 clamp to bin 0
    assert float(jh[-1]) >= 2.0         # 1e3 and 1e7 clamp to bin B-1

"""Event-coalesced macro-stepping + task-major queue properties:

  * for events_per_step in {1, 4, 16} the final state is IDENTICAL leaf
    by leaf — the cheap-core gating is conservative, so macro-stepping
    only changes how many event times one jitted step retires, never the
    dynamics — across load-balance, round-robin + network flows, and
    thermal-aware + throttling configs
  * the same runs match the sequential heapq oracle (latencies, energy,
    drop/flow accounting), so the coalesced path is validated against an
    independent implementation, not just against K=1
  * the fused-kernel advance (cfg.use_kernel, interpret mode off-TPU)
    reproduces the jnp advance path bit-for-bit inside the engine
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import engine, topology, workload
from repro.core.jobs import build_jobs, dag_chain, dag_single
from repro.core.types import (INF, SchedPolicy, SimConfig, SleepPolicy,
                              SrvState, ThermalConfig)

from oracle import OracleSim

KS = (1, 4, 16)


def _run_engine(cfg, arr, specs, topo=None, tau=None):
    jt = build_jobs(cfg, np.asarray(arr), specs)
    state, tc = engine.init_state(cfg, jt, topo)
    if tau is not None:
        state = dataclasses.replace(
            state, farm=dataclasses.replace(
                state.farm,
                srv_tau=jax.numpy.broadcast_to(
                    jax.numpy.asarray(tau, cfg.time_dtype),
                    (cfg.n_servers,))))
    return engine.run(state, cfg, tc)


def _assert_states_equal(ref, other, context):
    paths = [".".join(str(p) for p in kp)
             for kp, _ in jax.tree_util.tree_leaves_with_path(ref)]
    for name, lv, ls in zip(paths, jax.tree.leaves(ref),
                            jax.tree.leaves(other)):
        if name == ".steps":
            continue      # macro-step count: K-dependent by definition
        np.testing.assert_array_equal(
            np.asarray(lv), np.asarray(ls),
            err_msg=f"{context}: state leaf {name} diverged")


def _sweep_ks(cfg, arr, specs, topo=None, tau=None):
    outs = {k: _run_engine(dataclasses.replace(cfg, events_per_step=k),
                           arr, specs, topo, tau) for k in KS}
    for k in KS[1:]:
        _assert_states_equal(outs[KS[0]], outs[k],
                             f"events_per_step={k} vs 1")
    return outs[KS[0]]


def test_macro_step_load_balance_with_sleep():
    n_jobs = 150
    cfg = SimConfig(n_servers=6, n_cores=2, max_jobs=256, tasks_per_job=1,
                    sched_policy=SchedPolicy.LOAD_BALANCE,
                    sleep_policy=SleepPolicy.SINGLE_TIMER,
                    sleep_state=SrvState.S3, max_events=50_000)
    rng = np.random.default_rng(3)
    arr = workload.poisson_arrivals(100.0, n_jobs, seed=2)
    specs = [dag_single(rng.exponential(0.02)) for _ in range(n_jobs)]
    final = _sweep_ks(cfg, arr, specs, tau=0.05)

    orc = OracleSim(cfg, arr, specs, tau=0.05).run()
    fin = np.asarray(final.jobs.job_finish)
    lat = np.sort((fin - np.asarray(final.jobs.arrival))[fin < INF / 2])
    np.testing.assert_allclose(lat, np.sort(orc.latencies()),
                               rtol=1e-4, atol=1e-4)
    assert float(np.asarray(final.farm.energy).sum()) == pytest.approx(
        orc.total_energy(), rel=2e-3)


def test_macro_step_network_flows_round_robin():
    """ROUND_ROBIN splits 2-task chains across servers so every job
    routes a flow: the gate must hand flow completions and spawning
    completions to the full step, and still match the fluid oracle."""
    n_jobs = 30
    cfg = SimConfig(n_servers=6, n_cores=2, max_jobs=64, tasks_per_job=2,
                    max_children=2, max_flows=64, local_q=32,
                    sched_policy=SchedPolicy.ROUND_ROBIN,
                    sleep_policy=SleepPolicy.ALWAYS_ON,
                    has_network=True, comm_model=0, max_events=60_000)
    topo = topology.star(cfg.n_servers, link_cap=1.0e8)
    rng = np.random.default_rng(2)
    arr = workload.poisson_arrivals(25.0, n_jobs, seed=2)
    specs = [dag_chain(rng.uniform(0.01, 0.04, size=2),
                       edge_bytes=float(rng.uniform(4e6, 8e6)))
             for _ in range(n_jobs)]
    final = _sweep_ks(cfg, arr, specs, topo=topo)

    orc = OracleSim(cfg, arr, specs, topo=topo).run()
    fin = np.asarray(final.jobs.job_finish)
    lat = np.sort((fin - np.asarray(final.jobs.arrival))[fin < INF / 2])
    assert len(lat) == n_jobs == len(orc.job_finish)
    np.testing.assert_allclose(lat, np.sort(orc.latencies()),
                               rtol=1e-4, atol=1e-4)
    # flows actually routed (port ACTIVE residency is only accrued while
    # links carry traffic)
    assert float(np.asarray(final.net.port_residency)[..., 0].sum()) > 0


def test_macro_step_thermal_aware_throttling():
    """THERMAL_AWARE placement + engaged throttling: crossings stop the
    chew (they are full-step events), the latch/stretch stays exact, and
    all three K values match the numpy thermal oracle."""
    tcfg = ThermalConfig(enabled=True, r_th=0.5, tau_th=2.0, t_inlet=22.0,
                         recirc=0.2, rack_size=3, t_throttle=50.0,
                         t_release=45.0, throttle_freq=0.5,
                         throttle_power_scale=0.6)
    cfg = SimConfig(n_servers=6, n_cores=1, max_jobs=256, tasks_per_job=1,
                    sched_policy=SchedPolicy.THERMAL_AWARE,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=60_000,
                    thermal=tcfg)
    rng = np.random.default_rng(7)
    arr = workload.poisson_arrivals(25.0, 120, seed=3)
    specs = [dag_single(rng.exponential(0.08)) for _ in range(120)]
    final = _sweep_ks(cfg, arr, specs)

    orc = OracleSim(cfg, arr, specs).run()
    fin = np.asarray(final.jobs.job_finish)
    lat = np.sort((fin - np.asarray(final.jobs.arrival))[fin < INF / 2])
    assert len(lat) == len(arr) == len(orc.job_finish)
    np.testing.assert_allclose(lat, np.sort(orc.latencies()),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(final.thermal.t_srv), orc.temp,
                               rtol=2e-3, atol=5e-2)
    assert float(np.asarray(final.thermal.throttle_seconds).sum()) > 0
    assert float(np.asarray(final.thermal.throttle_seconds).sum()) == \
        pytest.approx(orc.throttle_seconds.sum(), rel=5e-3, abs=1e-3)


def test_macro_step_queue_contention_fifo():
    """More queued tasks than free cores: the task-major FIFO rank must
    start tasks in enqueue order under every K (single 1-core server, so
    any ordering slip changes latencies)."""
    n_jobs = 20
    cfg = SimConfig(n_servers=1, n_cores=1, local_q=64, max_jobs=32,
                    tasks_per_job=1, sched_policy=SchedPolicy.LOAD_BALANCE,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=20_000)
    arr = 0.01 * np.arange(n_jobs)               # all queue behind job 0
    specs = [dag_single(0.5) for _ in range(n_jobs)]
    final = _sweep_ks(cfg, arr, specs)
    orc = OracleSim(cfg, arr, specs).run()
    fin = np.asarray(final.jobs.job_finish)
    lat = np.sort((fin - np.asarray(final.jobs.arrival))[fin < INF / 2])
    np.testing.assert_allclose(lat, np.sort(orc.latencies()),
                               rtol=1e-4, atol=1e-4)


def test_macro_step_congested_queue_argsort_fallback():
    """More than COMPACT_Q (128) tasks queued farm-wide: try_start must
    take the full lexicographic-argsort rank path and still start in
    FIFO order (2 one-core servers, 200 near-simultaneous jobs)."""
    n_jobs = 200
    cfg = SimConfig(n_servers=2, n_cores=1, local_q=256, max_jobs=256,
                    tasks_per_job=1, sched_policy=SchedPolicy.LOAD_BALANCE,
                    sleep_policy=SleepPolicy.ALWAYS_ON, max_events=20_000)
    arr = 0.001 * np.arange(n_jobs)
    specs = [dag_single(1.0) for _ in range(n_jobs)]
    final = _sweep_ks(cfg, arr, specs)
    orc = OracleSim(cfg, arr, specs).run()
    fin = np.asarray(final.jobs.job_finish)
    ok = fin < INF / 2
    assert int(ok.sum()) == n_jobs == len(orc.job_finish)
    lat = np.sort((fin - np.asarray(final.jobs.arrival))[ok])
    np.testing.assert_allclose(lat, np.sort(orc.latencies()),
                               rtol=1e-4, atol=1e-4)


def test_macro_step_dag_immediate_edges_coalesce():
    """ROADMAP item: with a network configured, a completing task whose
    DAG edges all resolve IMMEDIATELY (zero-byte edges here, split across
    servers by ROUND_ROBIN so colocating is not what saves them) must not
    stop the cheap-core chew — final states are bit-identical for K in
    {1, 8} and match the oracle, while flows never spawn (nothing routes
    for a zero-byte edge)."""
    n_jobs = 40
    cfg0 = SimConfig(n_servers=4, n_cores=2, max_jobs=64, tasks_per_job=3,
                     max_children=2, max_flows=64, local_q=32,
                     sched_policy=SchedPolicy.ROUND_ROBIN,
                     sleep_policy=SleepPolicy.ALWAYS_ON,
                     has_network=True, comm_model=0, max_events=60_000)
    topo = topology.star(cfg0.n_servers, link_cap=1.0e8)
    rng = np.random.default_rng(4)
    arr = workload.poisson_arrivals(30.0, n_jobs, seed=6)
    specs = [dag_chain(rng.uniform(0.01, 0.04, size=3), edge_bytes=0.0)
             for _ in range(n_jobs)]
    outs = {k: _run_engine(dataclasses.replace(cfg0, events_per_step=k),
                           arr, specs, topo=topo) for k in (1, 8)}
    _assert_states_equal(outs[1], outs[8], "dag-immediate K=8 vs K=1")
    final = outs[1]
    orc = OracleSim(cfg0, arr, specs, topo=topo).run()
    fin = np.asarray(final.jobs.job_finish)
    lat = np.sort((fin - np.asarray(final.jobs.arrival))[fin < INF / 2])
    assert len(lat) == n_jobs == len(orc.job_finish)
    np.testing.assert_allclose(lat, np.sort(orc.latencies()),
                               rtol=1e-4, atol=1e-4)
    # nothing routed: the chains resolved entirely through the immediate
    # (in-core) edge path
    assert not bool(np.asarray(final.flows.active).any())
    assert int(final.flows.flows_dropped) == 0


@pytest.mark.parametrize("events_per_step", [1, 8])
def test_use_kernel_advance_matches_jnp(events_per_step):
    """cfg.use_kernel routes the advance through the fused Pallas kernel
    (interpret mode off-TPU): the final state must match the jnp path
    exactly, including with thermal throttling (the kernel models the
    throttle power scaling)."""
    tcfg = ThermalConfig(enabled=True, r_th=0.5, tau_th=2.0, recirc=0.0,
                         t_throttle=50.0, t_release=45.0,
                         throttle_power_scale=0.6)
    cfg = SimConfig(n_servers=4, n_cores=2, max_jobs=32, tasks_per_job=1,
                    sleep_policy=SleepPolicy.SINGLE_TIMER,
                    sleep_state=SrvState.S3, max_events=20_000,
                    events_per_step=events_per_step, thermal=tcfg)
    rng = np.random.default_rng(5)
    arr = workload.poisson_arrivals(30.0, 25, seed=5)
    specs = [dag_single(rng.exponential(0.05)) for _ in range(25)]
    outs = []
    for uk in (False, True):
        c = dataclasses.replace(cfg, use_kernel=uk)
        outs.append(_run_engine(c, arr, specs, tau=0.05))
    _assert_states_equal(outs[0], outs[1], "use_kernel")

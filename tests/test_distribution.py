"""Distribution substrate: sharding rule engine, data determinism,
checkpoint atomicity/resume, optimizer math."""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.ckpt.checkpoint import Checkpointer
from repro.data.pipeline import DataConfig, get_batch
from repro.sharding import partition
from repro.train import optim, step as step_lib


# --------------------------------------------------------------------------
# sharding rules
# --------------------------------------------------------------------------

class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 4, "model": 8}


def test_resolve_spec_basic():
    rules = {"vocab": ("model",), "embed": ("data",), "ff": ("model",)}
    ps = partition.resolve_spec(("vocab", "embed"), (1600, 512), FakeMesh(),
                                rules)
    assert ps == P("model", "data")


def test_resolve_spec_divisibility_fallback():
    rules = {"vocab": ("model",), "embed": ("data",)}
    ps = partition.resolve_spec(("vocab", "embed"), (1601, 512), FakeMesh(),
                                rules)
    assert ps == P(None, "data")


def test_resolve_spec_single_use_rail():
    rules = {"a": ("model",), "b": ("model",)}
    ps = partition.resolve_spec(("a", "b"), (64, 64), FakeMesh(), rules)
    assert ps == P("model")          # second "model" use falls to None


def test_batch_pspec_fallback_for_tiny_batch():
    assert partition.batch_pspec(FakeMesh(), 1) == P()       # 1 % 4 != 0
    assert partition.batch_pspec(FakeMesh(), 8) == P("data")


def test_state_shardings_cover_all_leaves():
    cfg = configs.get_smoke("llama3_2_1b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh, shapes = step_lib.state_shardings(cfg, mesh)
    n_sh = len(jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec")))
    n_shapes = len(jax.tree.leaves(shapes))
    assert n_sh == n_shapes


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------

def test_data_deterministic_and_shifted():
    dc = DataConfig(vocab=128, seq_len=32, global_batch=4, seed=7)
    a = get_batch(dc, step=5)
    b = get_batch(dc, step=5)
    c = get_batch(dc, step=6)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])
    assert (np.asarray(a["labels"][:, -1]) == -1).all()


def test_data_shards_disjoint_streams():
    dc = DataConfig(vocab=128, seq_len=16, global_batch=8, seed=0,
                    n_shards=2)
    s0 = get_batch(dc, 0, shard=0)
    s1 = get_batch(dc, 0, shard=1)
    assert s0["tokens"].shape == (4, 16)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------

def test_ckpt_roundtrip_and_gc(tmp_path):
    cfg = configs.get_smoke("smollm_360m")
    state = step_lib.init_state(cfg, jax.random.key(0))
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3):
        ck.save(state, s)
    assert sorted(ck.all_steps()) == [2, 3]          # GC keeps last 2
    restored, step = ck.restore(state)
    assert step == 3
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), state, restored)


def test_ckpt_atomic_no_partial(tmp_path):
    """tmp dirs never count as checkpoints."""
    ck = Checkpointer(tmp_path)
    (tmp_path / "tmp.99").mkdir()
    assert ck.latest_step() is None


def test_resume_replays_identically(tmp_path):
    """train k steps, checkpoint, train k more — must equal 2k straight."""
    cfg = dataclasses.replace(configs.get_smoke("llama3_2_1b"))
    dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4, seed=3)
    ts = jax.jit(step_lib.make_train_step(cfg))

    state = step_lib.init_state(cfg, jax.random.key(1))
    for s in range(6):
        state, _ = ts(state, get_batch(dc, s))
    straight = state

    state = step_lib.init_state(cfg, jax.random.key(1))
    ck = Checkpointer(tmp_path)
    for s in range(3):
        state, _ = ts(state, get_batch(dc, s))
    ck.save(state, 3)
    resumed, start = ck.restore(state)
    for s in range(start, 6):
        resumed, _ = ts(resumed, get_batch(dc, s))

    d = jax.tree.map(lambda a, b: float(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)).max()),
        straight["params"], resumed["params"])
    assert max(jax.tree.leaves(d)) < 1e-5


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------

def test_adamw_matches_reference_update():
    cfg = optim.AdamWConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8,
                            weight_decay=0.0, grad_clip=0.0,
                            warmup_steps=0, total_steps=10**9)
    p = {"w": jnp.ones((3, 3)) * 2.0}
    g = {"w": jnp.ones((3, 3)) * 0.5}
    m = optim.init_moments(p)
    new_p, new_m, stats = optim.adamw_update(cfg, p, g, m, jnp.zeros((),
                                                                     jnp.int32))
    # step 1 bias-corrected adam with constant grad: update == lr * sign-ish
    mhat = 0.1 * 0.5 / (1 - 0.9)
    vhat = 0.01 * 0.25 / (1 - 0.99)
    expect = 2.0 - 1e-2 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), expect, rtol=2e-5)


def test_grad_clip_caps_update_norm():
    cfg = optim.AdamWConfig(grad_clip=1.0, warmup_steps=0)
    p = {"w": jnp.zeros((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    m = optim.init_moments(p)
    _, _, stats = optim.adamw_update(cfg, p, g, m, jnp.zeros((), jnp.int32))
    assert float(stats["grad_norm"]) == pytest.approx(200.0)


def test_lr_schedule_warmup_and_decay():
    cfg = optim.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                            min_lr_ratio=0.1)
    lr0 = float(optim.lr_at(cfg, jnp.asarray(0)))
    lr5 = float(optim.lr_at(cfg, jnp.asarray(5)))
    lr10 = float(optim.lr_at(cfg, jnp.asarray(10)))
    lr110 = float(optim.lr_at(cfg, jnp.asarray(110)))
    assert lr0 == 0.0 and 0 < lr5 < lr10 <= 1.0
    assert lr110 == pytest.approx(0.1, abs=1e-3)


# --------------------------------------------------------------------------
# multi-device SPMD equivalence (subprocess with 8 host devices)
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_sharded_equals_single_device(tmp_path):
    script = r"""
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.models import transformer
from repro.sharding import partition

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = configs.get_smoke("gemma2_9b")
params, specs = transformer.make_params(cfg, jax.random.key(0))
tokens = jax.random.randint(jax.random.key(1), (4, 16), 0, cfg.vocab)
ref = jax.jit(lambda p, t: transformer.forward(cfg, p, t, mode="train")[0])(params, tokens)
psh = partition.tree_shardings(specs, params, mesh)
params_s = jax.device_put(params, psh)
tok_s = jax.device_put(tokens, NamedSharding(mesh, P("data")))
from repro.sharding import compat as mesh_compat
with mesh_compat.set_mesh(mesh):
    out = jax.jit(lambda p, t: transformer.forward(
        cfg, p, t, mode="train", mesh=mesh)[0])(params_s, tok_s)
err = np.abs(np.float32(ref) - np.float32(out)).max()
assert err < 1e-1, err
print("SPMD-EQUAL", err)
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, cwd="/root/repo",
                       timeout=420)
    assert "SPMD-EQUAL" in r.stdout, r.stdout + r.stderr


def test_serve_rules_weights_stationary():
    """Decode ruleset: no FSDP contraction dim; experts 2-D sharded."""
    rules = partition.serve_rules(FakeMesh())
    assert rules["embed"] is None
    ps = partition.resolve_spec(("expert", "embed", "e_ff"),
                                (64, 512, 1408), FakeMesh(), rules)
    assert ps == P("model", None, "data")

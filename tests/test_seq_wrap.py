"""FIFO-stamp int32 wrap semantics (server.py).

``ServerFarm.q_seq`` / ``JobTable.enqueue_seq`` are monotone int32
counters; the seed compared raw stamps, which silently inverts FIFO order
once the counter passes 2^31.  The pinned semantics are two-fold:

  * comparisons are WRAP-SAFE: ranks come from the int32 difference to
    the farm's current counter (``stamp - q_seq`` / pairwise diffs), which
    is exact whenever live stamps span < 2^31 pushes — guaranteed because
    a task enqueues at most once, so total stamps <= the task-table width;
  * the host-side guard: ``build_jobs`` refuses task tables at/over 2^31
    rows, the one config that could break the span precondition (tied to
    max_events only indirectly: the stamp count is bounded by the table,
    not the event budget).

Both try_start rank paths (the dense argsort rank and the COMPACT_Q
pairwise batch) are exercised with a q_seq parked just under the wrap
boundary so the stamps straddle 2^31 - 1 -> -2^31.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import server
from repro.core.jobs import build_jobs, dag_single
from repro.core.types import (SimConfig, SleepPolicy, TaskStatus, init_farm,
                              replace)

IMAX = np.iinfo(np.int32).max


def _wrapped_queue(cfg, n_tasks):
    """A farm whose q_seq sits 2 pushes before the wrap, with n_tasks
    tasks pushed onto server 0 in id order (stamps straddle the wrap)."""
    farm = init_farm(cfg)
    farm = replace(farm, q_seq=jnp.asarray(IMAX - 1, jnp.int32))
    jt = build_jobs(cfg, np.zeros(n_tasks),
                    [dag_single(1.0) for _ in range(n_tasks)])
    jt = replace(jt, server=jt.server.at[:n_tasks].set(0),
                 status=jt.status.at[:n_tasks].set(TaskStatus.READY))
    tids = jnp.arange(n_tasks, dtype=jnp.int32)
    farm, ok, seq = server.queue_push_many(
        farm, cfg, jnp.zeros(n_tasks, jnp.int32), tids,
        jnp.ones(n_tasks, bool))
    assert bool(ok.all())
    # stamps wrapped negative past the boundary
    assert int(seq[0]) == IMAX - 1 and int(seq[-1]) < 0
    jt = replace(jt, status=jt.status.at[:n_tasks].set(TaskStatus.QUEUED),
                 enqueue_seq=jt.enqueue_seq.at[:n_tasks].set(seq))
    return farm, jt


@pytest.mark.parametrize("max_jobs", [16, 256])
def test_fifo_order_survives_seq_wrap(max_jobs):
    """One single-core server, 4 queued tasks with stamps straddling the
    int32 wrap: the FIRST-pushed task must start, under both the dense
    argsort rank (small table) and the COMPACT_Q pairwise rank (table
    wider than the compact batch)."""
    cfg = SimConfig(n_servers=1, n_cores=1, local_q=8, max_jobs=max_jobs,
                    tasks_per_job=1, sleep_policy=SleepPolicy.ALWAYS_ON)
    farm, jt = _wrapped_queue(cfg, 4)
    farm2, jt2 = server.try_start(farm, cfg, jt,
                                  jnp.zeros((), cfg.time_dtype))
    status = np.asarray(jt2.status[:4])
    assert status[0] == TaskStatus.RUNNING          # first pushed runs
    assert (status[1:] == TaskStatus.QUEUED).all()  # raw compare would
    assert int(farm2.q_len[0]) == 3                 # start task 2 instead


def test_queued_rank_wrap_safe_direct():
    cfg = SimConfig(n_servers=1, n_cores=4, local_q=8, max_jobs=16,
                    tasks_per_job=1, sleep_policy=SleepPolicy.ALWAYS_ON)
    farm, jt = _wrapped_queue(cfg, 4)
    queued = jt.status == TaskStatus.QUEUED
    rank = np.asarray(server.queued_rank(jt, cfg, queued, farm.q_seq))
    np.testing.assert_array_equal(rank[:4], [0, 1, 2, 3])


def test_build_jobs_guards_int32_task_table():
    cfg = SimConfig(max_jobs=2 ** 27, tasks_per_job=16)   # 2^31 tasks
    with pytest.raises(ValueError, match="overflows int32"):
        build_jobs(cfg, np.empty(0), [])

"""Replica-parallel Monte-Carlo sweeps: the vmapped batch must agree with
individual runs, and the fault-model helpers must be sane."""
import numpy as np
import pytest

from repro.core import farm as farm_mod, montecarlo, topology, \
    workload
from repro.core.jobs import dag_chain, dag_single
from repro.core.types import SchedPolicy, SimConfig, SleepPolicy


def _cfg():
    return SimConfig(n_servers=4, n_cores=2, local_q=64, max_jobs=128,
                     tasks_per_job=1, sleep_policy=SleepPolicy.ALWAYS_ON,
                     max_events=10_000)


def test_vmapped_replicas_match_individual_runs():
    cfg = _cfg()
    n_jobs, R = 80, 3
    rng = np.random.default_rng(0)
    specs = [dag_single(rng.exponential(0.01)) for _ in range(n_jobs)]
    arrs = np.stack([workload.poisson_arrivals(150.0, n_jobs, seed=s)
                     for s in range(R)])

    state_b, tc = montecarlo.batched_state(cfg, arrs, specs)
    out = montecarlo.run_replicas(cfg, state_b, tc)
    stats = montecarlo.replica_stats(out, cfg)

    for r in range(R):
        solo = farm_mod.simulate(cfg, arrs[r], specs)
        assert stats["finished"][r] == solo.n_finished == n_jobs
        assert stats["mean_latency"][r] == pytest.approx(
            solo.mean_latency, rel=1e-4)
        assert stats["energy"][r] == pytest.approx(solo.server_energy,
                                                   rel=1e-3)


def test_tau_sweep_via_replicas():
    """A τ sweep as a replica batch (the Fig-5 pattern, one vmap)."""
    cfg = SimConfig(n_servers=4, n_cores=2, local_q=64, max_jobs=128,
                    tasks_per_job=1,
                    sleep_policy=SleepPolicy.SINGLE_TIMER,
                    max_events=10_000)
    n_jobs, taus = 60, np.asarray([0.01, 0.1, 1.0])
    rng = np.random.default_rng(1)
    specs = [dag_single(rng.exponential(0.02)) for _ in range(n_jobs)]
    arrs = np.stack([workload.poisson_arrivals(30.0, n_jobs, seed=7)] * 3)
    state_b, tc = montecarlo.batched_state(cfg, arrs, specs, taus=taus)
    out = montecarlo.run_replicas(cfg, state_b, tc)
    stats = montecarlo.replica_stats(out, cfg)
    assert (stats["finished"] == n_jobs).all()
    assert len(set(np.round(stats["energy"], 3))) > 1   # τ actually matters


def test_network_mode_replicas_match_individual_runs():
    """batched_state must thread topo through to init_state — network
    replica sweeps used to get tc=None and never route a single flow."""
    topo = topology.fat_tree(4, link_cap=1.25e9)
    # ROUND_ROBIN splits each 2-task chain across servers, so the sweep
    # really routes flows (score policies colocate and would spawn none)
    cfg = SimConfig(n_servers=16, n_cores=2, local_q=16, max_jobs=64,
                    tasks_per_job=2, max_children=2, max_flows=128,
                    sched_policy=SchedPolicy.ROUND_ROBIN,
                    sleep_policy=SleepPolicy.ALWAYS_ON,
                    has_network=True, max_events=20_000)
    n_jobs, R = 40, 2
    rng = np.random.default_rng(2)
    specs = [dag_chain(rng.uniform(0.01, 0.04, size=2), edge_bytes=50e6)
             for _ in range(n_jobs)]
    arrs = np.stack([workload.poisson_arrivals(25.0, n_jobs, seed=s)
                     for s in range(R)])

    state_b, tc = montecarlo.batched_state(cfg, arrs, specs, topo=topo)
    assert tc is not None
    out = montecarlo.run_replicas(cfg, state_b, tc)
    stats = montecarlo.replica_stats(out, cfg)

    for r in range(R):
        solo = farm_mod.simulate(cfg, arrs[r], specs, topo=topo)
        assert stats["finished"][r] == solo.n_finished == n_jobs
        assert stats["mean_latency"][r] == pytest.approx(
            solo.mean_latency, rel=1e-4)
        assert stats["energy"][r] == pytest.approx(solo.server_energy,
                                                   rel=1e-3)
    # flows actually routed: ports only leave LPI while links carry flows
    assert float(np.asarray(out.net.port_residency)[..., 0].sum()) > 0.0


def test_batched_state_requires_topo_in_network_mode():
    cfg = SimConfig(n_servers=4, n_cores=1, max_jobs=8, tasks_per_job=2,
                    has_network=True)
    arrs = np.zeros((1, 2))
    specs = [dag_chain([0.01, 0.01], edge_bytes=1e6)] * 2
    with pytest.raises(ValueError, match="topo"):
        montecarlo.batched_state(cfg, arrs, specs)


def test_failure_model_and_young_daly():
    fails = montecarlo.poisson_failure_times(mtbf=1000.0, horizon=500.0,
                                             n_nodes=100, seed=0)
    # rate = 0.1/s over 500s -> ~50 failures
    assert 20 < len(fails) < 100
    assert (np.diff(fails) > 0).all()
    assert montecarlo.young_daly_interval(3600.0, 50.0) == pytest.approx(
        600.0)


@pytest.mark.slow
def test_replicas_shard_map_over_devices():
    """Replica batch distributed over an 8-device mesh (subprocess) matches
    the single-device vmap — the axis that scales sweeps to 512 chips."""
    import os
    import subprocess
    import sys
    script = r"""
import sys; sys.path.insert(0, "src")
import jax, numpy as np
from repro.core import montecarlo, workload
from repro.core.jobs import dag_single
from repro.core.types import SimConfig, SleepPolicy
cfg = SimConfig(n_servers=4, n_cores=2, local_q=64, max_jobs=128,
                tasks_per_job=1, sleep_policy=SleepPolicy.ALWAYS_ON,
                max_events=8000)
n_jobs, R = 60, 8
rng = np.random.default_rng(0)
specs = [dag_single(rng.exponential(0.01)) for _ in range(n_jobs)]
arrs = np.stack([workload.poisson_arrivals(120.0, n_jobs, seed=s)
                 for s in range(R)])
state_b, tc = montecarlo.batched_state(cfg, arrs, specs)
mesh = jax.make_mesh((8,), ("replicas",))
out = montecarlo.run_replicas(cfg, state_b, tc, mesh=mesh)
ref = montecarlo.run_replicas(cfg, state_b, tc)
s1 = montecarlo.replica_stats(out, cfg)
s2 = montecarlo.replica_stats(ref, cfg)
assert (s1["finished"] == n_jobs).all()
assert np.allclose(s1["mean_latency"], s2["mean_latency"], rtol=1e-5)
print("REPLICAS-MATCH")
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, cwd="/root/repo",
                       timeout=420)
    assert "REPLICAS-MATCH" in r.stdout, r.stdout + r.stderr

"""Model assembly: parameters, caches, and the forward pass for every
assigned architecture (decoder-only dense/MoE/hybrid/SSM, plus the
whisper encoder-decoder and chameleon early-fusion variants).

Layer stacking uses ``lax.scan`` over *periods* of the block pattern — a
period is one repetition of ``cfg.block_pattern`` (e.g. (swa, attn) for
gemma2) and every pattern position has its parameters stacked over
``n_periods``.  Scanning keeps the HLO size O(period) instead of O(layers),
which is what makes 94-layer × 512-device SPMD compiles tractable.

Every parameter/cache tensor has a parallel *logical spec* — a tuple of
logical axis names per dim — consumed by ``repro.sharding.partition`` to
produce mesh ``PartitionSpec``s with divisibility fallbacks.
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from . import layers, moe, ssm
from .config import ModelConfig


def _bspec(mesh_axes):
    bax = mesh_axes[:-1]
    return bax[0] if len(bax) == 1 else tuple(bax)


def constrain_acts(x, mesh, mesh_axes):
    """Pin activations to batch-sharded (B over pod/data, rest replicated).

    XLA's gather partitioner cannot partition the embedding lookup (batch-
    sharded indices × vocab-sharded table); it replicates the result, and
    without a constraint the *batch-replicated* layout propagates through
    the whole network (observed: 538 GB/device temp at llama-1b scale)."""
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, P(_bspec(mesh_axes), *([None] * (x.ndim - 1))))

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
          "float16": jnp.float16}


def cdtype(cfg):
    return DTYPES[cfg.compute_dtype]


def pdtype(cfg):
    return DTYPES[cfg.param_dtype]


# ==========================================================================
# parameter initialization (+ logical specs)
# ==========================================================================

def _norm(key, shape, scale):
    return (jax.random.normal(key, shape, jnp.float32) * scale)


def _dense(key, fan_in, shape, dtype):
    return _norm(key, shape, 1.0 / math.sqrt(fan_in)).astype(dtype)


def _attn_params(cfg, key, cross=False):
    D, Qd, KVd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    dt = pdtype(cfg)
    ks = jax.random.split(key, 8)
    p = {"wq": _dense(ks[0], D, (D, Qd), dt),
         "wk": _dense(ks[1], D, (D, KVd), dt),
         "wv": _dense(ks[2], D, (D, KVd), dt),
         "wo": _dense(ks[3], Qd, (Qd, D), dt)}
    s = {"wq": ("embed", "heads"), "wk": ("embed", "kv"),
         "wv": ("embed", "kv"), "wo": ("heads", "embed")}
    if cfg.attn_bias and not cross:
        p |= {"bq": jnp.zeros((Qd,), dt), "bk": jnp.zeros((KVd,), dt),
              "bv": jnp.zeros((KVd,), dt)}
        s |= {"bq": ("heads",), "bk": ("kv",), "bv": ("kv",)}
    if cfg.qk_norm and not cross:
        p |= {"q_norm": jnp.zeros((cfg.head_dim,), jnp.float32),
              "k_norm": jnp.zeros((cfg.head_dim,), jnp.float32)}
        s |= {"q_norm": (None,), "k_norm": (None,)}
    return p, s


def _mlp_params(cfg, key, d_ff=None):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    dt = pdtype(cfg)
    ks = jax.random.split(key, 3)
    if cfg.act in ("silu", "geglu"):
        p = {"wg": _dense(ks[0], D, (D, F), dt),
             "wu": _dense(ks[1], D, (D, F), dt),
             "wd": _dense(ks[2], F, (F, D), dt)}
        s = {"wg": ("embed", "ff"), "wu": ("embed", "ff"),
             "wd": ("ff", "embed")}
    else:
        p = {"wu": _dense(ks[1], D, (D, F), dt),
             "wd": _dense(ks[2], F, (F, D), dt)}
        s = {"wu": ("embed", "ff"), "wd": ("ff", "embed")}
    return p, s


def _moe_params(cfg, key):
    D, E, F = cfg.d_model, cfg.n_experts, cfg.d_expert
    dt = pdtype(cfg)
    ks = jax.random.split(key, 5)
    p = {"router": _dense(ks[0], D, (D, E), jnp.float32),
         "wg": _dense(ks[1], D, (E, D, F), dt),
         "wu": _dense(ks[2], D, (E, D, F), dt),
         "wd": _dense(ks[3], F, (E, F, D), dt)}
    s = {"router": ("embed", None),
         "wg": ("expert", "embed", "e_ff"),
         "wu": ("expert", "embed", "e_ff"),
         "wd": ("expert", "e_ff", "embed")}
    if cfg.n_shared_experts:
        sp, ss = _mlp_params(cfg, ks[4],
                             d_ff=cfg.n_shared_experts * cfg.d_expert)
        p["shared"], s["shared"] = sp, ss
    return p, s


def _ssm_params(cfg, key):
    D, Dss, N, K = cfg.d_model, cfg.d_ssm, cfg.ssm_state, cfg.ssm_conv
    dt = pdtype(cfg)
    ks = jax.random.split(key, 6)
    a = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None], (Dss, 1))
    p = {"in_proj": _dense(ks[0], D, (D, 2 * Dss), dt),
         "conv_w": _dense(ks[1], K, (K, Dss), dt),
         "conv_b": jnp.zeros((Dss,), dt),
         "dt_w": jnp.ones((Dss,), jnp.float32),
         "dt_b": jnp.full((Dss,), -4.6, jnp.float32),   # softplus ~ 0.01
         "w_B": _dense(ks[2], Dss, (Dss, N), dt),
         "w_C": _dense(ks[3], Dss, (Dss, N), dt),
         "A_log": jnp.log(a),
         "d_skip": jnp.ones((Dss,), jnp.float32),
         "out_proj": _dense(ks[4], Dss, (Dss, D), dt)}
    s = {"in_proj": ("embed", "ssm"), "conv_w": (None, "ssm"),
         "conv_b": ("ssm",), "dt_w": ("ssm",), "dt_b": ("ssm",),
         "w_B": ("ssm", None), "w_C": ("ssm", None), "A_log": ("ssm", None),
         "d_skip": ("ssm",), "out_proj": ("ssm", "embed")}
    return p, s


def _mlstm_params(cfg, key):
    D, Qd, H = cfg.d_model, cfg.q_dim, cfg.n_heads
    dt = pdtype(cfg)
    ks = jax.random.split(key, 7)
    p = {"wq": _dense(ks[0], D, (D, Qd), dt),
         "wk": _dense(ks[1], D, (D, Qd), dt),
         "wv": _dense(ks[2], D, (D, Qd), dt),
         "wi": _dense(ks[3], D, (D, H), jnp.float32),
         "wf": _dense(ks[4], D, (D, H), jnp.float32),
         "wo_gate": _dense(ks[5], D, (D, Qd), dt),
         "out_proj": _dense(ks[6], Qd, (Qd, D), dt)}
    s = {"wq": ("embed", "heads"), "wk": ("embed", "heads"),
         "wv": ("embed", "heads"), "wi": ("embed", None),
         "wf": ("embed", None), "wo_gate": ("embed", "heads"),
         "out_proj": ("heads", "embed")}
    return p, s


def _slstm_params(cfg, key):
    D, H = cfg.d_model, cfg.n_heads
    dh = D // H
    dt = pdtype(cfg)
    ks = jax.random.split(key, 3)
    p = {"W": _dense(ks[0], D, (D, 4 * D), dt),
         "b": jnp.zeros((4 * D,), jnp.float32),
         "R": _dense(ks[1], dh, (H, dh, 4 * dh), jnp.float32),
         "out_proj": _dense(ks[2], D, (D, D), dt)}
    s = {"W": ("embed", None), "b": (None,), "R": (None, None, None),
         "out_proj": (None, "embed")}
    return p, s


_MIXERS = {"attn": _attn_params, "swa": _attn_params, "enc": _attn_params,
           "mamba": _ssm_params, "mlstm": _mlstm_params,
           "slstm": _slstm_params}


def _block_params(cfg, kind, key, *, is_encoder=False):
    D = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {"ln1": jnp.zeros((D,), jnp.float32)}
    s = {"ln1": (None,)}
    if kind == "hymba":
        ap, asp = _attn_params(cfg, ks[0])
        mp, msp = _ssm_params(cfg, ks[4])
        p["mixer"] = {"attn": ap, "ssm": mp}
        s["mixer"] = {"attn": asp, "ssm": msp}
    else:
        p["mixer"], s["mixer"] = _MIXERS[kind](cfg, ks[0])
    if cfg.cross_attn and not is_encoder:
        p["ln_x"] = jnp.zeros((D,), jnp.float32)
        s["ln_x"] = (None,)
        p["cross"], s["cross"] = _attn_params(cfg, ks[1], cross=True)
        # encoder-side K/V projections for cross attention
        dt = pdtype(cfg)
        p["cross"]["wk"] = _dense(ks[2], D, (D, cfg.q_dim), dt)
        p["cross"]["wv"] = _dense(ks[3], D, (D, cfg.q_dim), dt)
        s["cross"]["wk"] = ("embed", "heads")
        s["cross"]["wv"] = ("embed", "heads")
    has_ffn = cfg.d_ff > 0 or cfg.is_moe
    if has_ffn:
        p["ln2"] = jnp.zeros((D,), jnp.float32)
        s["ln2"] = (None,)
        if cfg.is_moe and not is_encoder:
            p["ffn"], s["ffn"] = _moe_params(cfg, ks[1] if not cfg.cross_attn
                                             else ks[4])
        else:
            p["ffn"], s["ffn"] = _mlp_params(cfg, ks[1])
    return p, s


def _stack(cfg, kind, key, n, **kw):
    keys = jax.random.split(key, n)
    p0, s0 = _block_params(cfg, kind, keys[0], **kw)
    stacked = jax.vmap(lambda k: _block_params(cfg, kind, k, **kw)[0])(keys)
    specs = jax.tree.map(lambda sp: (None,) + tuple(sp), s0,
                         is_leaf=lambda x: isinstance(x, tuple))
    return stacked, specs


def make_params(cfg: ModelConfig, key, max_seq: int = 0):
    """Returns (params, specs) — specs mirror params with logical-axis
    tuples per dim."""
    dt = pdtype(cfg)
    ks = jax.random.split(key, 8 + len(cfg.block_pattern))
    p: dict[str, Any] = {}
    s: dict[str, Any] = {}
    p["embed"] = (_norm(ks[0], (cfg.vocab, cfg.d_model), 0.02)).astype(dt)
    s["embed"] = ("vocab", "embed")
    lp, lsp = [], []
    for i, kind in enumerate(cfg.block_pattern):
        bp, bs = _stack(cfg, kind, ks[1 + i], cfg.n_periods)
        lp.append(bp)
        lsp.append(bs)
    p["layers"], s["layers"] = tuple(lp), tuple(lsp)
    p["final_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
    s["final_norm"] = (None,)
    if not cfg.tie_embeddings:
        p["lm_head"] = _dense(ks[-1], cfg.d_model,
                              (cfg.d_model, cfg.vocab), dt)
        s["lm_head"] = ("embed", "vocab")
    if cfg.pos == "learned":
        assert max_seq > 0, "learned positions need max_seq at init"
        p["dec_pos"] = _norm(ks[-2], (max_seq, cfg.d_model), 0.02).astype(dt)
        s["dec_pos"] = (None, "embed")
    if cfg.is_enc_dec:
        ep, es = _stack(cfg, "enc", ks[-3], cfg.enc_layers, is_encoder=True)
        p["enc"] = {"layers": ep,
                    "final_norm": jnp.zeros((cfg.d_model,), jnp.float32)}
        s["enc"] = {"layers": es, "final_norm": (None,)}
    return p, s


# ==========================================================================
# caches
# ==========================================================================

def cache_len_for(cfg, kind, S):
    if kind in ("swa", "hymba") and cfg.sliding_window:
        return min(cfg.sliding_window, S)
    return S


def init_cache(cfg: ModelConfig, B: int, S: int, dtype=None):
    """Decoder state for serve_step: per pattern position, stacked over
    periods.  Returns (cache, specs)."""
    dt = dtype or cdtype(cfg)
    P = cfg.n_periods
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    caches, specs = [], []
    for kind in cfg.block_pattern:
        c, sp = {}, {}
        if kind in ("attn", "swa", "hymba"):
            W = cache_len_for(cfg, kind, S)
            full = kind == "attn"
            c["k"] = jnp.zeros((P, B, W, kv, hd), dt)
            c["v"] = jnp.zeros((P, B, W, kv, hd), dt)
            c["pos_ids"] = jnp.full((P, B, W), -1, jnp.int32)
            seq_ax = "kv_seq" if full else None
            sp["k"] = (None, "batch", seq_ax, "kv_heads", None)
            sp["v"] = (None, "batch", seq_ax, "kv_heads", None)
            sp["pos_ids"] = (None, "batch", seq_ax)
        if kind in ("hymba", "mamba"):
            st = ssm.ssm_init_state(cfg, B, dt)
            c["ssm"] = jax.tree.map(lambda a: jnp.tile(a[None], (P,) + (1,) *
                                                       a.ndim), st)
            sp["ssm"] = {"conv": (None, "batch", None, "ssm"),
                         "h": (None, "batch", "ssm", None)}
        if kind == "mlstm":
            st = ssm.mlstm_init_state(cfg, B, dt)
            c.update({k: jnp.tile(v[None], (P,) + (1,) * v.ndim)
                      for k, v in st.items()})
            sp.update({"C": (None, "batch", None, None, None),
                       "n": (None, "batch", None, None),
                       "m": (None, "batch", None)})
        if kind == "slstm":
            st = ssm.slstm_init_state(cfg, B, dt)
            c.update({k: jnp.tile(v[None], (P, 1, 1)) for k, v in st.items()})
            sp.update({k: (None, "batch", None) for k in st})
        if cfg.cross_attn:
            c["cross_k"] = jnp.zeros((P, B, cfg.enc_seq, cfg.n_heads, hd), dt)
            c["cross_v"] = jnp.zeros((P, B, cfg.enc_seq, cfg.n_heads, hd), dt)
            sp["cross_k"] = (None, "batch", None, None, None)
            sp["cross_v"] = (None, "batch", None, None, None)
        caches.append(c)
        specs.append(sp)
    return tuple(caches), tuple(specs)


# ==========================================================================
# forward pass
# ==========================================================================

def _apply_block(cfg, kind, p, x, *, mode, cache, pos, enc_out, mesh,
                 mesh_axes):
    aux = jnp.zeros((), jnp.float32)
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    new_cache = {}
    if kind in ("attn", "swa", "enc"):
        mix, kv_cache = layers.attention_block(
            p["mixer"], h, cfg, kind=kind, mode=mode, cache=cache, pos=pos,
            mesh=mesh, mesh_axes=mesh_axes)
        if kv_cache:
            new_cache.update(kv_cache)
    elif kind == "hymba":
        a_cache = {k: cache[k] for k in ("k", "v", "pos_ids")} \
            if cache else None
        mix_a, kv_cache = layers.attention_block(
            p["mixer"]["attn"], h, cfg, kind="hymba", mode=mode,
            cache=a_cache, pos=pos, mesh=mesh, mesh_axes=mesh_axes)
        mix_s, s_state = ssm.mamba_mixer(
            p["mixer"]["ssm"], h, cfg, mode=mode,
            state=cache.get("ssm") if cache else None)
        mix = 0.5 * (mix_a + mix_s)
        if kv_cache:
            new_cache.update(kv_cache)
        if s_state:
            new_cache["ssm"] = s_state
    elif kind == "mamba":
        mix, s_state = ssm.mamba_mixer(p["mixer"], h, cfg, mode=mode,
                                       state=cache.get("ssm") if cache
                                       else None)
        if s_state:
            new_cache["ssm"] = s_state
    elif kind == "mlstm":
        st = {k: cache[k] for k in ("C", "n", "m")} if cache else None
        mix, st2 = ssm.mlstm_mixer(p["mixer"], h, cfg, mode=mode, state=st)
        if st2:
            new_cache.update(st2)
    elif kind == "slstm":
        st = {k: cache[k] for k in ("h", "c", "n", "m")} if cache else None
        mix, st2 = ssm.slstm_mixer(p["mixer"], h, cfg, mode=mode, state=st)
        if st2:
            new_cache.update(st2)
    else:
        raise ValueError(kind)
    x = x + mix

    if cfg.cross_attn and kind != "enc":
        hx = layers.rms_norm(x, p["ln_x"], cfg.norm_eps)
        if mode == "decode":
            ek, ev = cache["cross_k"], cache["cross_v"]
        else:
            B, Se, D = enc_out.shape
            ek = (enc_out @ p["cross"]["wk"]).reshape(
                B, Se, cfg.n_heads, cfg.head_dim)
            ev = (enc_out @ p["cross"]["wv"]).reshape(
                B, Se, cfg.n_heads, cfg.head_dim)
        x = x + layers.cross_attention(p["cross"], hx, ek, ev, cfg)
        if mode == "prefill":
            new_cache["cross_k"], new_cache["cross_v"] = ek, ev
        elif mode == "decode":
            new_cache["cross_k"], new_cache["cross_v"] = \
                cache["cross_k"], cache["cross_v"]

    if "ffn" in p:
        h2 = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.is_moe and kind != "enc":
            f, aux_moe, _ = moe.moe_block(p["ffn"], h2, cfg, mesh, mesh_axes)
            aux = aux + aux_moe
        else:
            f = layers.mlp(p["ffn"], h2, cfg.act)
        x = x + f
    return x, new_cache, aux


def _scan_blocks(cfg, params_layers, x, *, mode, caches, pos, enc_out,
                 mesh, mesh_axes, is_encoder=False):
    """Scan over periods; returns (x, new_caches, aux)."""
    pattern = ("enc",) * 1 if is_encoder else cfg.block_pattern
    if is_encoder:
        params_layers = (params_layers,)

    def body(carry, xs):
        x, aux = carry
        x = constrain_acts(x, mesh, mesh_axes)
        ps, cs = xs
        new_cs = []
        for i, kind in enumerate(pattern):
            x, nc, a = _apply_block(
                cfg, kind, ps[i], x, mode=mode,
                cache=cs[i] if cs is not None else None, pos=pos,
                enc_out=enc_out, mesh=mesh, mesh_axes=mesh_axes)
            new_cs.append(nc if nc else cs[i] if cs is not None else {})
            aux = aux + a
        return (x, aux), tuple(new_cs)

    if mode == "train" and cfg.remat != "nothing":
        policy = None
        if cfg.remat == "dots":
            policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        body = jax.checkpoint(body, policy=policy, prevent_cse=False)

    n = cfg.enc_layers if is_encoder else cfg.n_periods
    xs = (params_layers, caches if caches is not None
          else tuple({} for _ in pattern))
    if caches is None:
        xs = (params_layers, None)

    if cfg.scan_layers:
        aux0 = jnp.zeros((), jnp.float32)
        if caches is None:
            (x, aux), _ = jax.lax.scan(
                lambda c, ps: (body(c, (ps, None))[0], None),
                (x, aux0), params_layers)
            new_caches = None
        else:
            (x, aux), new_caches = jax.lax.scan(
                body, (x, aux0), (params_layers, caches))
    else:
        aux = jnp.zeros((), jnp.float32)
        ncs = []
        for li in range(n):
            ps = jax.tree.map(lambda a: a[li], params_layers)
            cs = jax.tree.map(lambda a: a[li], caches) \
                if caches is not None else None
            (x, aux), nc = body((x, aux), (ps, cs))
            ncs.append(nc)
        new_caches = jax.tree.map(lambda *a: jnp.stack(a), *ncs) \
            if caches is not None else None
    return x, new_caches, aux


def encode(cfg, params, frames, mesh=None, mesh_axes=("data", "model")):
    """Whisper encoder over stubbed frame embeddings (B, enc_seq, D)."""
    x = frames.astype(cdtype(cfg))
    x = x + layers.sinusoidal_pos(x.shape[1], cfg.d_model, x.dtype)[None]
    x, _, _ = _scan_blocks(cfg, params["enc"]["layers"], x, mode="train",
                           caches=None, pos=0, enc_out=None, mesh=mesh,
                           mesh_axes=mesh_axes, is_encoder=True)
    return layers.rms_norm(x, params["enc"]["final_norm"], cfg.norm_eps)


def forward(cfg: ModelConfig, params, tokens, *, mode="train", cache=None,
            pos=0, frames=None, mesh=None, mesh_axes=("data", "model"),
            skip_head=False):
    """tokens (B, S) int32.  Returns (logits, new_cache, aux); with
    skip_head=True returns the final hidden states instead of logits (the
    chunked-xent path applies the head itself)."""
    dt = cdtype(cfg)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    x = constrain_acts(x, mesh, mesh_axes)
    if cfg.family in ("audio",) or cfg.name.startswith("gemma"):
        x = x * math.sqrt(cfg.d_model)
    if cfg.pos == "learned":
        S = tokens.shape[1]
        if mode == "decode":
            ptab = jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos, 1, 0)
        else:
            ptab = params["dec_pos"][:S]
        x = x + ptab[None].astype(dt)

    enc_out = None
    if cfg.is_enc_dec and mode != "decode":
        enc_out = encode(cfg, params, frames, mesh, mesh_axes)

    x, new_cache, aux = _scan_blocks(
        cfg, params["layers"], x, mode=mode, caches=cache, pos=pos,
        enc_out=enc_out, mesh=mesh, mesh_axes=mesh_axes)

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if skip_head:
        return x, new_cache, aux
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(dt)
    logits = layers.softcap(logits, cfg.final_softcap)
    return logits, new_cache, aux


def lm_loss_chunked(cfg, x, head, labels, aux, aux_coef=0.01, z_coef=1e-4,
                    final_softcap=0.0):
    """Fused chunked cross-entropy: the (B, S, V) logits tensor is never
    fully materialized — each S-chunk does one (B,c,D)@(D,V) matmul and
    immediately reduces to (B,c) statistics.  Cuts the xent HBM traffic by
    ~the number of elementwise passes XLA makes over full logits (~10×) and
    the peak activation by S/c.  Python loop (not scan) so probe modules
    count every chunk."""
    B, S, D = x.shape
    n = max(1, cfg.xent_chunk)
    c = -(-S // n)
    mask_all = (labels >= 0)
    nll_sum = 0.0
    z_sum = 0.0
    for i in range(n):
        xs = x[:, i * c:(i + 1) * c]
        lb = labels[:, i * c:i * c + xs.shape[1]]
        lg = xs @ head
        lg = layers.softcap(lg, final_softcap)
        m = jnp.max(lg, axis=-1).astype(jnp.float32)
        ex = jnp.exp(lg.astype(jnp.float32) - m[..., None])
        lse = jnp.log(jnp.sum(ex, axis=-1)) + m
        onehot = (jnp.arange(lg.shape[-1])[None, None, :]
                  == jnp.maximum(lb, 0)[..., None])
        gold = jnp.sum(jnp.where(onehot, lg.astype(jnp.float32), 0.0), -1)
        msk = (lb >= 0).astype(jnp.float32)
        nll_sum = nll_sum + ((lse - gold) * msk).sum()
        z_sum = z_sum + ((lse * msk) ** 2).sum()
    denom = jnp.maximum(mask_all.sum().astype(jnp.float32), 1.0)
    loss = nll_sum / denom
    zloss = z_coef * z_sum / denom
    return loss + zloss + aux_coef * aux, {"nll": loss, "aux": aux}


def lm_loss(cfg, logits, labels, aux, aux_coef=0.01, z_coef=1e-4):
    """Masked token cross-entropy (labels < 0 are padding).

    The gold logit is extracted with a masked reduction over the vocab dim
    rather than take_along_axis: the vocab dim is "model"-sharded and a
    gather across it would make SPMD all-gather the (B,S,V) logits; the
    (iota == label) reduce stays local with only a (B,S)-sized all-reduce.
    Keeps logits in bf16 until the reductions (f32 accumulation inside)."""
    mask = (labels >= 0).astype(jnp.float32)
    lbl = jnp.maximum(labels, 0)
    V = logits.shape[-1]
    m = jnp.max(logits, axis=-1).astype(jnp.float32)
    ex = jnp.exp(logits.astype(jnp.float32) - m[..., None])
    lse = jnp.log(jnp.sum(ex, axis=-1)) + m
    onehot = (jnp.arange(V)[None, None, :] == lbl[..., None])
    gold = jnp.sum(jnp.where(onehot, logits.astype(jnp.float32), 0.0),
                   axis=-1)
    nll = (lse - gold) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = nll.sum() / denom
    zloss = z_coef * ((lse * mask) ** 2).sum() / denom
    return loss + zloss + aux_coef * aux, {"nll": loss, "aux": aux}

"""State-space / recurrent mixers: Mamba-style selective SSM (hymba),
and the two xLSTM blocks (mLSTM matrix memory, sLSTM scalar memory).

Training/prefill forms:
  * mamba  — linear time-variant SSM, lax.scan over time (the associative
    -scan variant is a hillclimb lever; see kernels/ssm_scan.py for the
    Pallas chunked version).
  * mlstm  — stabilized parallel (quadratic) form with query chunking, the
    xLSTM paper's training formulation.
  * slstm  — true recurrence (scan over time; not parallelizable — that is
    why xLSTM alternates it with mLSTM blocks).

Decode forms are all O(1)-state single steps, which is what makes the
long_500k shape feasible for the ssm/hybrid architectures.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import NEG_INF


# --------------------------------------------------------------------------
# Mamba-style selective SSM
# --------------------------------------------------------------------------

def ssm_init_state(cfg, B, dtype):
    Dss, N, K = cfg.d_ssm, cfg.ssm_state, cfg.ssm_conv
    return {"conv": jnp.zeros((B, K - 1, Dss), dtype),
            "h": jnp.zeros((B, Dss, N), jnp.float32)}


def _ssm_proj(p, x, cfg):
    xz = x @ p["in_proj"]
    return jnp.split(xz, 2, axis=-1)                    # x_in, z


def _causal_conv(x, w, prev=None):
    """Depthwise causal conv.  x (B, S, Dss), w (K, Dss); prev (B, K-1, Dss)
    left context for decode."""
    K = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    return out, xp[:, -(K - 1):]


def _ssm_coeffs(p, xc, cfg):
    dt = jax.nn.softplus(xc * p["dt_w"] + p["dt_b"]).astype(jnp.float32)
    Bm = (xc @ p["w_B"]).astype(jnp.float32)            # (..., N)
    Cm = (xc @ p["w_C"]).astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))        # (Dss, N)
    return dt, Bm, Cm, A


def mamba_mixer(p, x, cfg, mode="train", state=None):
    """x (B, S, D) -> (out, new_state)."""
    B, S, D = x.shape
    x_in, z = _ssm_proj(p, x, cfg)
    prev = state["conv"] if mode == "decode" else None
    xc, conv_tail = _causal_conv(x_in, p["conv_w"], prev)
    xc = jax.nn.silu(xc + p["conv_b"])
    dt, Bm, Cm, A = _ssm_coeffs(p, xc, cfg)
    xf = xc.astype(jnp.float32)

    if mode == "decode":                                # S == 1 single step
        h = state["h"]
        da = jnp.exp(dt[:, 0, :, None] * A[None])       # (B, Dss, N)
        h = da * h + (dt[:, 0] * xf[:, 0])[..., None] * Bm[:, 0][:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0])[:, None, :]
        new_state = {"conv": conv_tail, "h": h}
    else:
        def step(h, inp):
            dt_t, B_t, C_t, x_t = inp                   # (B,Dss),(B,N),(B,N),(B,Dss)
            da = jnp.exp(dt_t[..., None] * A[None])     # (B, Dss, N)
            h = da * h + (dt_t * x_t)[..., None] * B_t[:, None, :]
            y = jnp.einsum("bdn,bn->bd", h, C_t)
            return h, y

        h0 = jnp.zeros((B, cfg.d_ssm, cfg.ssm_state), jnp.float32)
        xs = (dt.transpose(1, 0, 2), Bm.transpose(1, 0, 2),
              Cm.transpose(1, 0, 2), xf.transpose(1, 0, 2))
        h, ys = jax.lax.scan(step, h0, xs)
        y = ys.transpose(1, 0, 2)                       # (B, S, Dss)
        new_state = {"conv": conv_tail, "h": h} if mode == "prefill" else None

    y = y + xf * p["d_skip"].astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ p["out_proj"], new_state


# --------------------------------------------------------------------------
# mLSTM — matrix memory with exponential gating (xLSTM)
# --------------------------------------------------------------------------

def mlstm_init_state(cfg, B, dtype):
    H, hd = cfg.n_heads, cfg.head_dim
    return {"C": jnp.zeros((B, H, hd, hd), jnp.float32),
            "n": jnp.zeros((B, H, hd), jnp.float32),
            "m": jnp.full((B, H), 0.0, jnp.float32)}


def _mlstm_qkvg(p, x, cfg):
    B, S, D = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, H, hd) / math.sqrt(hd)
    v = (x @ p["wv"]).reshape(B, S, H, hd)
    i_t = (x @ p["wi"]).astype(jnp.float32)             # (B, S, H)
    f_t = (x @ p["wf"]).astype(jnp.float32)
    o_t = jax.nn.sigmoid(x @ p["wo_gate"]).reshape(B, S, H, hd)
    return q, k, v, i_t, f_t, o_t


def mlstm_mixer(p, x, cfg, mode="train", state=None, chunk=None):
    B, S, D = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    if chunk is None:
        chunk = cfg.attn_chunk or S
    q, k, v, i_t, f_t, o_t = _mlstm_qkvg(p, x, cfg)
    logf = jax.nn.log_sigmoid(f_t)                      # (B, S, H)

    if mode == "decode":
        C, n, m = state["C"], state["n"], state["m"]
        lf, it = logf[:, 0], i_t[:, 0]                  # (B, H)
        m_new = jnp.maximum(lf + m, it)
        fp = jnp.exp(lf + m - m_new)[..., None]         # (B, H, 1)
        ip = jnp.exp(it - m_new)[..., None]
        k0 = k[:, 0].astype(jnp.float32)                # (B, H, hd)
        v0 = v[:, 0].astype(jnp.float32)
        C = fp[..., None] * C + ip[..., None] * jnp.einsum(
            "bhd,bhe->bhde", v0, k0)
        n = fp * n + ip * k0
        qh = q[:, 0].astype(jnp.float32)                # (B, H, hd)
        num = jnp.einsum("bhde,bhe->bhd", C, qh)
        # stabilized state: C̃ = e^{-m} C, so the |n·q| >= 1 floor becomes
        # e^{-m} in the scaled system (matches the parallel form exactly)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qh)),
                          jnp.exp(-m_new))[..., None]
        h = (num / den)[:, None].reshape(B, 1, H, hd)
        out = (h * o_t).reshape(B, 1, H * hd).astype(x.dtype)
        new_state = {"C": C, "n": n, "m": m_new}
        return out @ p["out_proj"], new_state

    # parallel (quadratic) stabilized form, chunked over queries
    cum = jnp.cumsum(logf, axis=1)                       # (B, S, H)
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    n_chunks = max(1, -(-S // chunk))
    pad = n_chunks * chunk - S
    if pad:
        qf = jnp.pad(qf, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cum_q = jnp.pad(cum, ((0, 0), (0, pad), (0, 0)))
    else:
        cum_q = cum
    qc = qf.reshape(B, n_chunks, chunk, H, hd).transpose(1, 0, 3, 2, 4)
    cq = cum_q.reshape(B, n_chunks, chunk, H).transpose(1, 0, 3, 2)

    t_idx = jnp.arange(S)

    cum_keys = cum.transpose(0, 2, 1)[:, :, None, :]     # (B, H, 1, S)
    i_keys = i_t.transpose(0, 2, 1)[:, :, None, :]       # (B, H, 1, S)

    def one_chunk(ci, qi, cqi):
        # D̃[t, s] = cum_f[t] - cum_f[s] + ĩ[s]   for s <= t
        qpos = ci * chunk + jnp.arange(chunk)
        dmat = cqi[..., None] - cum_keys + i_keys        # (B, H, chunk, S)
        mask = t_idx[None, None, None, :] <= qpos[None, None, :, None]
        dmat = jnp.where(mask, dmat, NEG_INF)
        mrow = jnp.maximum(jnp.max(dmat, axis=-1), 0.0)  # stabilizer
        w = jnp.exp(dmat - mrow[..., None])
        s = jnp.einsum("bhqd,bshd->bhqs", qi, kf) * w
        den = jnp.maximum(jnp.abs(s.sum(-1)), jnp.exp(-mrow))[..., None]
        return jnp.einsum("bhqs,bshd->bhqd", s, vf) / den

    if n_chunks == 1:
        out = one_chunk(0, qc[0], cq[0])[None]
    else:
        out = jax.lax.map(lambda a: one_chunk(*a),
                          (jnp.arange(n_chunks), qc, cq))
    out = out.transpose(1, 0, 3, 2, 4).reshape(B, n_chunks * chunk, H, hd)
    out = (out[:, :S] * o_t.astype(jnp.float32)).reshape(B, S, H * hd)
    new_state = None
    if mode == "prefill":                                # build final state
        new_state = _mlstm_state_from_seq(kf, vf, i_t, logf, cum, B, H, hd)
    return out.astype(x.dtype) @ p["out_proj"], new_state


def _mlstm_state_from_seq(kf, vf, i_t, logf, cum, B, H, hd):
    """Final (C, n, m) after consuming the whole sequence — O(S) einsum."""
    S = kf.shape[1]
    tot = cum[:, -1]                                     # (B, H)
    w_log = tot[:, None, :] - cum + i_t                  # (B, S, H)
    m = jnp.maximum(jnp.max(w_log, axis=1), 0.0)         # (B, H)
    w = jnp.exp(w_log - m[:, None, :])                   # (B, S, H)
    C = jnp.einsum("bsh,bshd,bshe->bhde", w, vf, kf)
    n = jnp.einsum("bsh,bshd->bhd", w, kf)
    return {"C": C, "n": n, "m": m}


# --------------------------------------------------------------------------
# sLSTM — scalar memory, true recurrence
# --------------------------------------------------------------------------

def slstm_init_state(cfg, B, dtype):
    D = cfg.d_model
    z = jnp.zeros((B, D), jnp.float32)
    return {"h": z, "c": z, "n": z, "m": z}


def slstm_mixer(p, x, cfg, mode="train", state=None):
    """Block-diagonal recurrent sLSTM.  x (B, S, D)."""
    B, S, D = x.shape
    H = cfg.n_heads
    dh = D // H
    xw = (x @ p["W"]).astype(jnp.float32) + p["b"].astype(jnp.float32)

    R = p["R"].astype(jnp.float32)                       # (H, dh, 4*dh)

    def step(carry, xw_t):
        h, c, n, m = carry
        hh = h.reshape(B, H, dh)
        rec = jnp.einsum("bhd,hde->bhe", hh, R).reshape(B, 4 * D)
        g = xw_t + rec
        zt, it, ft, ot = jnp.split(g, 4, axis=-1)
        zt = jnp.tanh(zt)
        m_new = jnp.maximum(ft + m, it)                  # exp gating, f̃ pre-act
        ip = jnp.exp(it - m_new)
        fp = jnp.exp(ft + m - m_new)
        c = fp * c + ip * zt
        n = fp * n + ip
        h = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1.0)
        return (h, c, n, m_new), h

    if mode == "decode":
        carry = (state["h"], state["c"], state["n"], state["m"])
        carry, h = step(carry, xw[:, 0])
        out = h[:, None, :]
        new_state = dict(zip(("h", "c", "n", "m"), carry))
    else:
        init = tuple(jnp.zeros((B, D), jnp.float32) for _ in range(4))
        carry, hs = jax.lax.scan(step, init, xw.transpose(1, 0, 2))
        out = hs.transpose(1, 0, 2)
        new_state = dict(zip(("h", "c", "n", "m"), carry)) \
            if mode == "prefill" else None
    return out.astype(x.dtype) @ p["out_proj"], new_state

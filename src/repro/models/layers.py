"""Neural-net layer primitives shared by every assigned architecture.

Everything is a pure function of (params, inputs); parameters are plain
pytrees created in ``transformer.make_params``.  Attention is written in a
query-chunked streaming form so that no S×S score tensor is ever fully
materialized — at 32k context a dense score tensor would be ~17 GB/device,
far beyond VMEM/HBM budgets, while a 512-query chunk stays in the tens of MB.
This jnp path is also the correctness oracle for the Pallas flash kernel
(kernels/flash_attention.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# --------------------------------------------------------------------------
# norms / activations / positional encodings
# --------------------------------------------------------------------------

def rms_norm(x, weight, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def act_fn(name):
    return {"silu": jax.nn.silu, "gelu": functools.partial(
        jax.nn.gelu, approximate=True)}[name]


def softcap(x, cap):
    return jnp.tanh(x / cap) * cap if cap else x


def rope_freqs(positions, head_dim, theta):
    """positions (...,) int -> (..., head_dim/2) angles."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    return positions[..., None].astype(jnp.float32) * inv


def apply_rope(x, positions, theta):
    """x (..., S, H, hd), positions (..., S)."""
    ang = rope_freqs(positions, x.shape[-1], theta)      # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def sinusoidal_pos(seq, dim, dtype=jnp.float32):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    half = dim // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = pos * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# --------------------------------------------------------------------------
# dense MLP (gated SiLU or plain GELU)
# --------------------------------------------------------------------------

def mlp(p, x, act="silu"):
    if act == "silu":                                    # gated SiLU
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])
    elif act == "geglu":                                 # gated GELU (gemma)
        h = act_fn("gelu")(x @ p["wg"]) * (x @ p["wu"])
    else:                                                # plain GELU
        h = act_fn(act)(x @ p["wu"])
    return h @ p["wd"]


# --------------------------------------------------------------------------
# attention — streaming query-chunked implementation
# --------------------------------------------------------------------------

def _qk_norm(q, k, p, eps):
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], eps)
        k = rms_norm(k, p["k_norm"], eps)
    return q, k


def qkv_proj(p, x, cfg):
    B, S, D = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.attn_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    q, k = _qk_norm(q, k, p, cfg.norm_eps)
    return q, k, v


def attend(q, k, v, *, causal, q_offset=0, window=0, attn_softcap=0.0,
           chunk=512, kv_positions=None, bf16_scores=False):
    """Streaming GQA attention.

    q (B, Sq, H, hd); k/v (B, Skv, KV, hd) with H % KV == 0 — the group
    broadcast happens inside the einsum (never materialized: a repeated KV
    cache would cost H/KV× the cache bytes).  Scores accumulate in f32 via
    ``preferred_element_type`` while K/V stay in their storage dtype (an f32
    copy of a 32k cache would double decode HBM).

    ``q_offset`` is the absolute position of q[:, 0] relative to k[:, 0]
    (prefill: 0; decode: cache length).  ``window`` > 0 restricts each query
    to the last ``window`` keys (sliding-window attention).
    ``kv_positions`` (B, Skv) overrides key absolute positions (ring caches).
    """
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    if not chunk:
        chunk = Sq
    scale = 1.0 / math.sqrt(hd)
    if kv_positions is None:
        kv_pos = jnp.broadcast_to(jnp.arange(Skv)[None, :], (B, Skv))
    else:
        kv_pos = kv_positions

    n_chunks = max(1, -(-Sq // chunk))
    pad = n_chunks * chunk - Sq
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else q
    # (n_chunks, B, KV, G, chunk, hd)
    qc = qp.reshape(B, n_chunks, chunk, KV, G, hd).transpose(1, 0, 3, 4, 2, 5)

    def _mask_bias(ci):
        """Single additive (B,1,1,chunk,Skv) bias — one select instead of a
        chain of boolean selects over the f32 score tensor."""
        qpos = q_offset + ci * chunk + jnp.arange(chunk)        # (chunk,)
        m = (kv_pos >= 0)[:, None, None, None, :]               # ring valid
        if causal:
            m &= kv_pos[:, None, None, None, :] <= qpos[None, None, None,
                                                        :, None]
        if window:
            m &= kv_pos[:, None, None, None, :] > qpos[None, None, None,
                                                       :, None] - window
        return m

    def one_chunk(ci, qi):
        s = jnp.einsum("bkgqd,bskd->bkgqs", qi, k,
                       preferred_element_type=jnp.float32) * scale
        if attn_softcap:
            s = softcap(s, attn_softcap)
        m = _mask_bias(ci)
        if bf16_scores:
            # halve score-tensor HBM traffic: bf16 scores/probs, f32 stats
            # (the Pallas flash kernel subsumes this entirely on TPU)
            sb = jnp.where(m, s, NEG_INF).astype(jnp.bfloat16)
            mx = jnp.max(sb, axis=-1, keepdims=True)
            p = jnp.exp(sb - mx)
            l = jnp.sum(p, axis=-1, keepdims=True,
                        dtype=jnp.float32)
            out = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v.dtype), v,
                             preferred_element_type=jnp.float32)
            return out / l.astype(jnp.float32)
        s = jnp.where(m, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v.dtype), v,
                          preferred_element_type=jnp.float32)

    if n_chunks == 1:
        out = one_chunk(0, qc[0])[None]
    else:
        out = jax.lax.map(lambda args: one_chunk(*args),
                          (jnp.arange(n_chunks), qc))
    # (n_chunks, B, KV, G, chunk, hd) -> (B, Sq, H, hd)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, n_chunks * chunk, H, hd)
    return out[:, :Sq].astype(q.dtype)


def attention_block(p, x, cfg, *, kind, mode, cache=None, pos=0,
                    mesh=None, mesh_axes=("data", "model")):
    """Self-attention mixer.  kind in {attn, swa, enc}; mode in {train,
    prefill, decode}.  Returns (out, new_cache).

    Caches hold *rotated* keys plus the absolute position of each slot
    (``pos_ids``; -1 = empty).  Sliding-window caches are rings of size W
    written at ``pos % W``; full caches are written at ``pos``.
    """
    B, S, D = x.shape
    window = cfg.sliding_window if kind in ("swa", "hymba") else 0
    q, k, v = qkv_proj(p, x, cfg)

    if cfg.skip_attention and mode != "decode":
        # roofline ablation probe: projections kept, the S×S score/softmax
        # subgraph removed — its byte/FLOP share is measured by difference
        G = cfg.n_heads // cfg.n_kv_heads
        out = jnp.repeat(v, G, axis=2).astype(q.dtype)
        out = out.reshape(B, S, cfg.n_heads * cfg.head_dim)
        return out @ p["wo"], None

    if mode == "decode":
        if cfg.pos == "rope":
            q = apply_rope(q, jnp.full((B, S), pos), cfg.rope_theta)
            k = apply_rope(k, jnp.full((B, S), pos), cfg.rope_theta)
        cache_k, cache_v, slot_pos = cache["k"], cache["v"], cache["pos_ids"]
        W = cache_k.shape[1]
        slot = jnp.asarray(pos) % W if window else jnp.asarray(pos)
        # flash-decode layout: the long cache is sequence-sharded over
        # "model"; replicate the (tiny) q/k/v over "model" so the cache
        # update and the score/softmax/value contractions stay S-local and
        # only (B,H)-sized softmax stats and the (B,1,H,hd) partial output
        # cross the ICI.  Without these constraints SPMD reshards
        # (all-gathers) the multi-GB cache every decoded token.
        seq_shard = (mesh is not None and not window
                     and "model" in mesh.axis_names
                     and mesh.shape["model"] > 1)
        if seq_shard:
            from jax.sharding import PartitionSpec as _P
            bax = mesh_axes[:-1]
            bspec = bax[0] if len(bax) == 1 else tuple(bax)
            rep = _P(bspec, None, None, None)
            q, k, v = (jax.lax.with_sharding_constraint(t, rep)
                       for t in (q, k, v))
            seq = _P(bspec, "model", None, None)
            cache_k = jax.lax.with_sharding_constraint(cache_k, seq)
            cache_v = jax.lax.with_sharding_constraint(cache_v, seq)
            slot_pos = jax.lax.with_sharding_constraint(
                slot_pos, _P(bspec, "model"))
        # elementwise select instead of dynamic_update_slice: a DUS into the
        # sequence dim would force SPMD to rematerialize the (sharded) cache
        # every step; where(iota==slot, ...) partitions cleanly.
        sel = (jnp.arange(W) == slot)[None, :, None, None]
        cache_k = jnp.where(sel, k.astype(cache_k.dtype), cache_k)
        cache_v = jnp.where(sel, v.astype(cache_v.dtype), cache_v)
        slot_pos = jnp.where(sel[..., 0, 0],
                             jnp.asarray(pos, slot_pos.dtype), slot_pos)
        out = attend(q, cache_k, cache_v, causal=True, q_offset=pos,
                     window=window,
                     attn_softcap=cfg.attn_softcap, kv_positions=slot_pos,
                     chunk=cfg.attn_chunk,
                     bf16_scores=cfg.attn_bf16_scores)
        if seq_shard:
            # stop the wo-matmul's head sharding from propagating back into
            # the S-sharded cache via the value contraction
            out = jax.lax.with_sharding_constraint(out, rep)
        new_cache = {"k": cache_k, "v": cache_v, "pos_ids": slot_pos}
    else:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        if cfg.pos == "rope":
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        out = attend(q, k, v, causal=kind != "enc", window=window,
                     attn_softcap=cfg.attn_softcap, chunk=cfg.attn_chunk,
                     bf16_scores=cfg.attn_bf16_scores)
        if mode == "prefill" and cache is not None:
            W = cache["k"].shape[1]
            if W <= S:                                  # keep the last W,
                ks, vs, ps = k[:, -W:], v[:, -W:], positions[:, -W:]
                if S % W:                               # ring-aligned so that
                    shift = S % W                       # slot == pos % W
                    ks = jnp.roll(ks, shift, axis=1)
                    vs = jnp.roll(vs, shift, axis=1)
                    ps = jnp.roll(ps, shift, axis=1)
            else:                                       # right-pad to W
                padk = ((0, 0), (0, W - S), (0, 0), (0, 0))
                ks, vs = jnp.pad(k, padk), jnp.pad(v, padk)
                ps = jnp.pad(positions, ((0, 0), (0, W - S)),
                             constant_values=-1)
            new_cache = {"k": ks.astype(cache["k"].dtype),
                         "v": vs.astype(cache["v"].dtype),
                         "pos_ids": ps.astype(jnp.int32)}
        else:
            new_cache = None

    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim)
    return out @ p["wo"], new_cache


def cross_attention(p, x, enc_k, enc_v, cfg):
    """Decoder->encoder cross attention (whisper).  enc_k/v are already
    projected per layer: (B, Senc, H, hd)."""
    B, S, D = x.shape
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
    out = attend(q, enc_k, enc_v, causal=False)
    return out.reshape(B, S, -1) @ p["wo"]

"""Mixture-of-Experts layer (qwen3-moe, moonshot) — GShard-style routing
with token dropping at a capacity factor, adapted to the TPU mesh.

Layout
------
tokens  (B, S, D)   B sharded over the batch axes ("pod","data"), D replicated
experts (E, D, F)   E sharded over "model"  (expert parallelism == TP axis)

The classic GShard one-hot dispatch/combine einsums materialize a
(B, S, E, C) mask — at our scale that is tens of TB, so they survive only as
a small-shape oracle (``moe_einsum``) used by the tests.  The production path
(``moe_scatter``):

  1. route: top-k experts per token (softmax over the chosen k, f32)
  2. position-in-expert via a *chunked* one-hot running cumsum (bounded
     memory), capacity C = ceil(S·k·cf / E)
  3. inverse index (B, E, C) -> token slot, built with a cheap int32 scatter
  4. dispatch = batched gather (local, zero FLOPs, zero collectives)
  5. slice E onto "model" (free — E was locally replicated)
  6. expert FFN einsums (fully local: E on "model", B on batch axes)
  7. combine under ``shard_map``: every model shard scatter-gathers only its
     own experts' outputs and a single psum over "model" reduces partial
     token outputs — exactly one activation-sized all-reduce per MoE layer,
     the same collective cost as Megatron-style dense TP.

Aux losses: switch-style load-balance loss and router z-loss.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import layers


def capacity(cfg, S: int) -> int:
    import math
    return max(1, math.ceil(S * cfg.top_k * cfg.capacity_factor
                            / cfg.n_experts))


def route(p, x, cfg):
    """Returns (topi (B,S,k) int32, gates (B,S,k) f32, aux_loss f32)."""
    logits = (x.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(logits, cfg.top_k)
    gates = jax.nn.softmax(topv, axis=-1)              # renormalized over k
    # switch load-balance loss: E * mean(f_e * p_e)
    ohot = jax.nn.one_hot(topi[..., 0], cfg.n_experts, dtype=jnp.float32)
    frac = ohot.mean(axis=(0, 1))
    mean_p = probs.mean(axis=(0, 1))
    lb = cfg.n_experts * jnp.sum(frac * mean_p)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return topi.astype(jnp.int32), gates, lb + cfg.router_zloss * z


def _positions_in_expert(topi, cfg, chunk: int = 4096):
    """topi (B, S, k) -> pos (B, S, k): the running index of each (token,
    choice) within its expert, computed with a chunked cumsum so the one-hot
    tensor never exceeds (B, chunk, E)."""
    B, S, k = topi.shape
    E = cfg.n_experts
    ek = topi.reshape(B, S * k)
    T = S * k
    c = min(chunk, T)
    n = -(-T // c)
    pad = n * c - T
    ekp = jnp.pad(ek, ((0, 0), (0, pad)), constant_values=0) if pad else ek
    ekc = ekp.reshape(B, n, c).transpose(1, 0, 2)      # (n, B, c)

    def step(counts, ids):
        oh = jax.nn.one_hot(ids, E, dtype=jnp.int32)   # (B, c, E)
        within = jnp.cumsum(oh, axis=1) - oh + counts[:, None, :]
        pos = jnp.take_along_axis(within, ids[..., None], axis=-1)[..., 0]
        return counts + oh.sum(axis=1), pos

    _, pos = jax.lax.scan(step, jnp.zeros((B, E), jnp.int32), ekc)
    pos = pos.transpose(1, 0, 2).reshape(B, n * c)[:, :T]
    return pos.reshape(B, S, k)


def _dispatch(x, topi, pos, keep, C, cfg):
    """Batched-gather dispatch -> (B, E, C, D); empty slots are zero."""
    B, S, D = x.shape
    E = cfg.n_experts
    # inverse map: (B, E, C) -> source token (sentinel S = zero row)
    slot_e = topi.reshape(B, -1)                                   # (B, S*k)
    slot_c = jnp.where(keep, pos, C).reshape(B, -1)                # overflow->C
    src = jnp.broadcast_to(jnp.arange(S)[:, None], (S, cfg.top_k)
                           ).reshape(1, -1)
    inv = jnp.full((B, E, C + 1), S, jnp.int32)
    b_ix = jnp.broadcast_to(jnp.arange(B)[:, None], slot_e.shape)
    inv = inv.at[b_ix, slot_e, slot_c].set(
        jnp.broadcast_to(src, slot_e.shape), mode="drop")
    inv = inv[:, :, :C]                                            # (B, E, C)
    x_pad = jnp.concatenate([x, jnp.zeros((B, 1, D), x.dtype)], axis=1)
    out = jnp.take_along_axis(
        x_pad, inv.reshape(B, E * C)[..., None], axis=1)
    return out.reshape(B, E, C, D), inv


def _expert_ffn(p, h, cfg):
    """h (B, E, C, D) -> (B, E, C, D); E sharded on "model"."""
    dt = h.dtype
    g = jnp.einsum("becd,edf->becf", h, p["wg"].astype(dt))
    u = jnp.einsum("becd,edf->becf", h, p["wu"].astype(dt))
    a = jax.nn.silu(g) * u
    return jnp.einsum("becf,efd->becd", a, p["wd"].astype(dt))


def _combine_local(expert_out, topi, pos, keep, gates, e_base, E_loc, S):
    """Per-shard combine: sum each token's local-expert outputs."""
    B = expert_out.shape[0]
    D = expert_out.shape[-1]
    out = jnp.zeros((B, S, D), expert_out.dtype)
    for j in range(topi.shape[-1]):                    # static k loop
        e = topi[..., j]                               # (B, S)
        sel = (e >= e_base) & (e < e_base + E_loc) & keep[..., j]
        el = jnp.clip(e - e_base, 0, E_loc - 1)
        cj = jnp.clip(pos[..., j], 0, expert_out.shape[2] - 1)
        flat = el * expert_out.shape[2] + cj           # (B, S)
        eo = expert_out.reshape(B, -1, D)
        vals = jnp.take_along_axis(eo, flat[..., None], axis=1)
        w = (gates[..., j] * sel).astype(expert_out.dtype)
        out = out + vals * w[..., None]
    return out


def moe_scatter(p, x, cfg, mesh=None, mesh_axes=("data", "model")):
    """Production MoE path.  mesh_axes = (batch axes ..., model axis)."""
    B, S, D = x.shape
    E = cfg.n_experts
    C = capacity(cfg, S)
    bax, model_ax = mesh_axes[:-1], mesh_axes[-1]
    bspec = bax[0] if len(bax) == 1 else tuple(bax)

    topi, gates, aux = route(p, x, cfg)
    pos = _positions_in_expert(topi, cfg)
    keep = pos < C
    dropped = jnp.sum(~keep & (gates > 0))

    h, _ = _dispatch(x, topi, pos, keep, C, cfg)        # (B, E, C, D)
    sharded = (mesh is not None and model_ax in mesh.axis_names
               and mesh.shape[model_ax] > 1
               and E % mesh.shape[model_ax] == 0)
    if sharded:
        h = jax.lax.with_sharding_constraint(
            h, P(bspec, model_ax, None, None))
    h = _expert_ffn(p, h, cfg)

    if sharded:
        E_loc = E // mesh.shape[model_ax]

        def combine(eo, ti, po, ke, ga):
            e_base = jax.lax.axis_index(model_ax) * E_loc
            out = _combine_local(eo, ti, po, ke, ga, e_base, E_loc, S)
            return jax.lax.psum(out, model_ax)

        from ..sharding.compat import shard_map
        out = shard_map(
            combine, mesh=mesh,
            in_specs=(P(bspec, model_ax, None, None), P(bspec), P(bspec),
                      P(bspec), P(bspec)),
            out_specs=P(bspec), check_vma=False,
        )(h, topi, pos, keep, gates)
    else:                                               # single-device / tests
        out = _combine_local(h, topi, pos, keep, gates, 0, E, S)

    if cfg.n_shared_experts:
        out = out + layers.mlp(p["shared"], x, "silu")
    return out.astype(x.dtype), aux, dropped


# --------------------------------------------------------------------------
# small-shape oracle: classic GShard one-hot einsum dispatch/combine
# --------------------------------------------------------------------------

def moe_einsum(p, x, cfg):
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = capacity(cfg, S)
    topi, gates, aux = route(p, x, cfg)
    pos = _positions_in_expert(topi, cfg)
    keep = pos < C
    oh_e = jax.nn.one_hot(topi, E, dtype=jnp.float32)            # (B,S,k,E)
    oh_c = jax.nn.one_hot(jnp.where(keep, pos, C), C,
                          dtype=jnp.float32)                     # (B,S,k,C)
    disp = jnp.einsum("bske,bskc->bsec", oh_e, oh_c)             # bool-ish
    comb = jnp.einsum("bske,bskc,bsk->bsec", oh_e, oh_c, gates)
    h = jnp.einsum("bsec,bsd->becd", disp.astype(x.dtype), x)
    h = _expert_ffn(p, h, cfg)
    out = jnp.einsum("bsec,becd->bsd", comb.astype(x.dtype), h)
    if cfg.n_shared_experts:
        out = out + layers.mlp(p["shared"], x, "silu")
    dropped = jnp.sum(~keep & (gates > 0))
    return out.astype(x.dtype), aux, dropped


def moe_block(p, x, cfg, mesh=None, mesh_axes=("data", "model")):
    if cfg.moe_impl == "einsum":
        return moe_einsum(p, x, cfg)
    return moe_scatter(p, x, cfg, mesh, mesh_axes)

"""Model configuration for the LM substrate.

One frozen dataclass drives every assigned architecture: dense GQA
transformers, MoE (GShard-style routed experts), gemma2-style local/global
alternation with logit softcaps, hybrid attention+SSM (hymba), xLSTM
(sLSTM/mLSTM alternation), early-fusion VLM (chameleon) and encoder-decoder
audio (whisper).  The configuration is hashable so it can be a jit-static
argument.

Block kinds (``block_pattern`` — the scanned super-block is one period of the
pattern; ``n_layers`` must be divisible by ``len(block_pattern)``):

  attn    full (causal for decoders, bidirectional for encoders) attention
  swa     sliding-window attention (``sliding_window`` tokens)
  hymba   parallel attention + Mamba-style SSM heads, outputs fused
  mamba   pure Mamba-style selective SSM mixer
  mlstm   xLSTM matrix-memory block (parallelizable linear attention form)
  slstm   xLSTM scalar-memory block (recurrent gating)
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Tuple

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES"]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0              # per-expert FFN hidden size
    n_shared_experts: int = 0      # moonshot/deepseek-style always-on experts
    capacity_factor: float = 1.25
    router_zloss: float = 1e-3
    moe_impl: str = "scatter"      # scatter | einsum (oracle) | dense

    # --- attention flavor ----------------------------------------------------
    attn_bias: bool = False        # qwen1.5 QKV bias
    qk_norm: bool = False          # qwen3 / chameleon
    attn_softcap: float = 0.0      # gemma2 attention logit softcap
    final_softcap: float = 0.0     # gemma2 final logit softcap
    sliding_window: int = 0        # used by 'swa' blocks
    block_pattern: Tuple[str, ...] = ("attn",)

    # --- SSM (hymba / mamba) -------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 1

    # --- encoder-decoder (whisper) -------------------------------------------
    enc_layers: int = 0
    enc_seq: int = 1500            # stubbed conv frontend output length
    cross_attn: bool = False
    frontend: str = "none"         # none | audio_frames | vq_tokens

    # --- misc -----------------------------------------------------------------
    act: str = "silu"              # silu | gelu
    pos: str = "rope"              # rope | learned
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # --- runtime knobs (hillclimb levers; do not change math) -----------------
    remat: str = "full"            # nothing | dots | full
    microbatches: int = 1
    use_flash: bool = False        # Pallas kernels (TPU); jnp ref otherwise
    scan_layers: bool = True
    fsdp_embed: bool = True        # shard d_model dim of params over "data"
    attn_chunk: int = 512          # query-chunk size (0 = no chunking)
    xent_chunk: int = 0            # seq chunks for fused xent (0 = off)
    attn_bf16_scores: bool = False  # bf16 score/prob tensors (f32 stats)
    skip_attention: bool = False   # roofline probe: mixer ablated, used to
    #                                measure attention's exact byte/flop
    #                                share by difference (never for training)
    serve_weights_stationary: bool = False  # decode: 2D weight sharding,
    #                                 no per-step FSDP gathers (hillclimb)

    # ------------------------------------------------------------------------
    @property
    def period(self) -> int:
        return len(self.block_pattern)

    @property
    def n_periods(self) -> int:
        assert self.n_layers % self.period == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"pattern period {self.period}")
        return self.n_layers // self.period

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_enc_dec(self) -> bool:
        return self.enc_layers > 0

    @property
    def d_ssm(self) -> int:
        return self.ssm_expand * self.d_model

    def reduced(self, **overrides) -> "ModelConfig":
        """A smoke-test scale sibling: same family/pattern, tiny dims."""
        small = dict(
            n_layers=2 * self.period,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) or 2,
            head_dim=16,
            d_ff=128,
            vocab=256,
            enc_layers=2 if self.is_enc_dec else 0,
            enc_seq=16 if self.is_enc_dec else self.enc_seq,
            sliding_window=min(self.sliding_window, 8) if self.sliding_window
            else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            n_experts=8 if self.is_moe else 0,
            top_k=2 if self.is_moe else 0,
            d_expert=32 if self.is_moe else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            microbatches=1,
            remat="nothing",
            name=self.name + "-smoke",
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)

    # rough parameter counts (for roofline MODEL_FLOPS = 6·N·D) -------------
    def param_count(self, active_only: bool = False) -> int:
        D, V = self.d_model, self.vocab
        embed = V * D * (1 if self.tie_embeddings else 2)
        attn = D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D
        if self.attn_bias:
            attn += self.q_dim + 2 * self.kv_dim
        per_layer = {}
        for kind in set(self.block_pattern):
            p = 0
            if kind in ("attn", "swa"):
                p = attn
            elif kind == "hymba":
                p = attn + self._ssm_params()
            elif kind == "mamba":
                p = self._ssm_params()
            elif kind == "mlstm":
                p = D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D \
                    + 2 * D * self.n_heads
            elif kind == "slstm":
                p = 4 * D * D + 4 * D
            per_layer[kind] = p + 2 * D          # norms
        mixer = sum(per_layer[k] for k in self.block_pattern) * self.n_periods
        if self.is_moe:
            e = self.top_k if active_only else self.n_experts
            ffn = (e + self.n_shared_experts) * 3 * D * self.d_expert \
                + D * self.n_experts            # router
        else:
            ffn = 3 * D * self.d_ff if self.act == "silu" else 2 * D * self.d_ff
        ffn_total = ffn * self.n_layers
        enc = 0
        if self.is_enc_dec:
            enc = self.enc_layers * (attn + 3 * D * self.d_ff + 4 * D)
            mixer += self.n_layers * attn        # decoder cross-attention
        return embed + mixer + ffn_total + enc + D

    def _ssm_params(self) -> int:
        Ds, S = self.d_ssm, self.ssm_state
        return (self.d_model * 2 * Ds          # in_proj (x, z)
                + Ds * self.ssm_conv           # depthwise conv
                + Ds * (2 * S + 1)             # B, C, dt projections (simpl.)
                + Ds * S                       # A
                + Ds * self.d_model)           # out_proj


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def sub_quadratic(cfg: ModelConfig) -> bool:
    """True when every block's cost is bounded in seq_len (SWA / SSM)."""
    return all(k in ("swa", "hymba", "mamba", "mlstm", "slstm")
               for k in cfg.block_pattern) and not cfg.is_enc_dec


def supported_shapes(cfg: ModelConfig):
    """The assigned-shape subset this architecture runs (skips recorded in
    DESIGN.md §Arch-applicability): long_500k needs sub-quadratic mixers."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if sub_quadratic(cfg):
        names.append("long_500k")
    return [SHAPES[n] for n in names]

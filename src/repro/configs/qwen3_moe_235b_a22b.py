"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4) expert
d_ff=1536 vocab=151936, MoE 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B
family scaling; hf].  QK-norm, no attention bias, rope 1e6."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=0,
    vocab=151936,
    n_experts=128,
    top_k=8,
    d_expert=1536,
    qk_norm=True,
    rope_theta=1e6,
    remat="full",
    microbatches=8,
)

SMOKE = CONFIG.reduced()

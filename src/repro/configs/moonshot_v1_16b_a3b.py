"""moonshot-v1-16b-a3b [moe] — 48L d_model=2048 16H (kv=16) expert
d_ff=1408 vocab=163840, MoE 64 experts top-6 + 2 shared experts
(kimi/moonlight, deepseek-style).  [hf:moonshotai/Moonlight-16B-A3B; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=0,
    vocab=163840,
    n_experts=64,
    top_k=6,
    d_expert=1408,
    n_shared_experts=2,
    rope_theta=5e4,
    remat="full",
    microbatches=4,
)

SMOKE = CONFIG.reduced()

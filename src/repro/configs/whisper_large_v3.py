"""whisper-large-v3 [audio] — enc-dec, 32+32L d_model=1280 20H (kv=20)
d_ff=5120 vocab=51866; conv frontend is a STUB (``input_specs`` provides
precomputed frame embeddings (B, enc_seq, d_model)); sinusoidal encoder
positions, learned decoder positions (extended to the assigned sequence
lengths — adaptation noted in DESIGN.md), plain GELU MLPs, cross-attention
in every decoder layer.  [arXiv:2212.04356; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab=51866,
    enc_layers=32,
    enc_seq=1500,
    cross_attn=True,
    frontend="audio_frames",
    act="gelu",
    pos="learned",
    remat="dots",
    microbatches=2,
)

SMOKE = CONFIG.reduced()

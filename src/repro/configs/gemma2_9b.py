"""gemma2-9b [dense] — 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000; alternating local(4k sliding window)/global attention,
attention + final logit softcaps, gated-GELU, tied embeddings, embeddings
scaled by sqrt(d_model).  [arXiv:2408.00118; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab=256000,
    block_pattern=("swa", "attn"),
    sliding_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    act="geglu",
    tie_embeddings=True,
    rope_theta=1e4,
    remat="full",
    microbatches=2,
)

SMOKE = CONFIG.reduced(sliding_window=8)

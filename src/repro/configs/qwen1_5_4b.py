"""qwen1.5-4b [dense] — 40L d_model=2560 20H (kv=20) d_ff=6912
vocab=151936, QKV bias.  [hf:Qwen/Qwen1.5-0.5B family scaling; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab=151936,
    attn_bias=True,
    rope_theta=1e6,
    remat="full",
    microbatches=2,
)

SMOKE = CONFIG.reduced(attn_bias=True)

"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the full-scale ModelConfig; ``get_smoke(name)``
the reduced same-family sibling used by CPU smoke tests.
"""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "qwen3_moe_235b_a22b",
    "moonshot_v1_16b_a3b",
    "qwen1_5_4b",
    "smollm_360m",
    "gemma2_9b",
    "llama3_2_1b",
    "hymba_1_5b",
    "xlstm_350m",
    "chameleon_34b",
    "whisper_large_v3",
]

ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}
ALIASES.update({
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "qwen1.5-4b": "qwen1_5_4b",
    "smollm-360m": "smollm_360m",
    "gemma2-9b": "gemma2_9b",
    "llama3.2-1b": "llama3_2_1b",
    "hymba-1.5b": "hymba_1_5b",
    "xlstm-350m": "xlstm_350m",
    "chameleon-34b": "chameleon_34b",
    "whisper-large-v3": "whisper_large_v3",
})


def _module(name: str):
    key = ALIASES.get(name, name)
    return importlib.import_module(f"repro.configs.{key}")


def get_config(name: str):
    return _module(name).CONFIG


def get_smoke(name: str):
    return _module(name).SMOKE


def list_archs():
    return list(ARCH_IDS)

"""xlstm-350m [ssm] — 24L d_model=1024 4H d_ff=0 vocab=50304; alternating
mLSTM (matrix memory, parallelizable) and sLSTM (scalar memory, true
recurrence) blocks; no FFN sublayer (d_ff=0 — projections live inside the
mixers).  [arXiv:2405.04517; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab=50304,
    block_pattern=("mlstm", "slstm"),
    tie_embeddings=True,
    remat="dots",
    microbatches=1,
)

SMOKE = CONFIG.reduced(d_ff=0, head_dim=16)

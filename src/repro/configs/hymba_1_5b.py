"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16; parallel attention + Mamba heads in every block
(outputs fused), sliding-window attention so the global state lives in the
SSM — this is what makes long_500k decoding O(1)/token.
[arXiv:2411.13676; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    block_pattern=("hymba",),
    sliding_window=1024,
    ssm_state=16,
    ssm_expand=2,
    rope_theta=1e4,
    remat="dots",
    microbatches=1,
)

SMOKE = CONFIG.reduced(n_heads=4, n_kv_heads=2, ssm_expand=2)

"""chameleon-34b [vlm] — 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536; early-fusion: VQ image tokens are ordinary ids in the shared
vocab (the VQ-VAE tokenizer is the stubbed frontend — ``input_specs``
emits token ids + a modality mask), QK-norm for training stability.
[arXiv:2405.09818; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab=65536,
    qk_norm=True,
    frontend="vq_tokens",
    rope_theta=1e4,
    remat="full",
    microbatches=4,
)

SMOKE = CONFIG.reduced(qk_norm=True)

"""Batched serving engine: prefill + decode with a slot-based KV pool.

Small but real: requests are admitted into fixed batch slots, prefilled
(padded to the slot width), then decoded step-synchronously with greedy or
temperature sampling; finished slots free for the next admission wave
(continuous batching at step granularity).  This is the substrate for the
decode_* dry-run shapes and the serving example.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer
from ..models.config import ModelConfig
from ..train import step as step_lib


@dataclasses.dataclass
class GenResult:
    tokens: List[int]
    prompt_len: int
    steps: int


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 max_seq: int = 256, mesh=None):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.mesh = mesh
        self._prefill = jax.jit(step_lib.make_prefill(cfg, mesh))
        self._decode = jax.jit(step_lib.make_serve_step(cfg, mesh))

    def generate(self, prompts: List[List[int]], max_new: int = 32,
                 temperature: float = 0.0, eos: Optional[int] = None,
                 seed: int = 0) -> List[GenResult]:
        """Generate for up to max_batch prompts (batched, left-aligned)."""
        assert len(prompts) <= self.max_batch
        B = len(prompts)
        plen = max(len(p) for p in prompts)
        toks = np.zeros((B, plen), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p                 # right-pad with 0
        cache, _ = transformer.init_cache(self.cfg, B, self.max_seq)
        logits, cache = self._prefill(self.params, jnp.asarray(toks), cache)

        key = jax.random.key(seed)
        out = [list(p) for p in prompts]
        alive = np.ones(B, bool)
        last = self._sample(logits, temperature, key)
        for i in range(B):
            out[i].append(int(last[i]))
        pos = plen
        steps = 0
        while alive.any() and pos < self.max_seq and steps < max_new - 1:
            logits, cache = self._decode(self.params, cache,
                                         last[:, None], pos)
            key, sub = jax.random.split(key)
            last = self._sample(logits, temperature, sub)
            for i in range(B):
                if alive[i]:
                    t = int(last[i])
                    out[i].append(t)
                    if eos is not None and t == eos:
                        alive[i] = False
            pos += 1
            steps += 1
        return [GenResult(tokens=o, prompt_len=len(p), steps=steps + 1)
                for o, p in zip(out, prompts)]

    @staticmethod
    def _sample(logits, temperature, key):
        if temperature <= 0:
            return np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        g = jax.random.categorical(key, logits / temperature, axis=-1)
        return np.asarray(g, np.int32)

import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: prove every (architecture × input shape × mesh) cell
lowers AND compiles under SPMD, then extract memory / cost / collective
statistics for the roofline analysis.

The two lines above must run before any jax import — jax locks the device
count at first init.  Do NOT replicate them in conftest.py: tests and
benchmarks are supposed to see one real CPU device.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
        --shape train_4k --mesh pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out results/dryrun
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.sharding import compat as mesh_compat
from repro.models import transformer
from repro.models.config import SHAPES, ModelConfig, supported_shapes
from repro.roofline import analysis
from repro.sharding import partition
from repro.train import step as step_lib
from repro.launch.mesh import make_production_mesh


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; zero device allocation)
# --------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape_name: str, mesh):
    """Abstract model inputs for one (arch, shape) cell.

    train:   {"tokens","labels"} (B, S) int32 (+ "frames" for enc-dec)
    decode:  (token (B,1), pos ()) plus the KV/recurrent cache.
    """
    shp = SHAPES[shape_name]
    B, S = shp.global_batch, shp.seq_len
    bspec = partition.batch_pspec(mesh, B)
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32,
                               sharding=NamedSharding(mesh, bspec))
    if shp.kind == "train":
        out = {"tokens": tok, "labels": tok}
        if cfg.is_enc_dec:
            out["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16,
                sharding=NamedSharding(mesh, bspec))
        if cfg.frontend == "vq_tokens":
            out["modality_mask"] = tok
        return out
    if shp.kind == "prefill":
        out = {"tokens": tok}
        if cfg.is_enc_dec:
            out["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16,
                sharding=NamedSharding(mesh, bspec))
        return out
    if shp.kind == "decode":
        one = jax.ShapeDtypeStruct((B, 1), jnp.int32,
                                   sharding=NamedSharding(mesh, bspec))
        return {"token": one,
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    raise ValueError(shp.kind)


def cache_specs(cfg: ModelConfig, shape_name: str, mesh):
    shp = SHAPES[shape_name]
    B, S = shp.global_batch, shp.seq_len

    def build():
        c, _ = transformer.init_cache(cfg, B, S)
        return c
    shapes = jax.eval_shape(build)
    box = {}

    def build2():
        c, sp = transformer.init_cache(cfg, B, S)
        box["sp"] = sp
        return c
    jax.eval_shape(build2)
    shardings = partition.tree_shardings(box["sp"], shapes, mesh)
    return shapes, shardings


# --------------------------------------------------------------------------
# one cell
# --------------------------------------------------------------------------

def _lower_one(cfg, shp, mesh):
    max_seq = shp.seq_len if cfg.pos == "learned" else 0
    rules = None
    if shp.kind == "decode" and cfg.serve_weights_stationary:
        rules = partition.serve_rules(mesh)
    state_sh, state_shapes = step_lib.state_shardings(cfg, mesh, max_seq,
                                                      rules)
    ins = input_specs(cfg, shp.name, mesh)
    if shp.kind == "train":
        fn = step_lib.make_train_step(cfg, mesh)
        batch_sh = {k: v.sharding for k, v in ins.items()
                    if k != "modality_mask"}
        batch = {k: v for k, v in ins.items() if k != "modality_mask"}
        jfn = jax.jit(fn, in_shardings=(state_sh, batch_sh),
                      out_shardings=None, donate_argnums=(0,))
        with mesh_compat.set_mesh(mesh):
            return jfn.lower(state_shapes, batch)
    cshapes, csh = cache_specs(cfg, shp.name, mesh)
    logit_sh = NamedSharding(
        mesh, partition.batch_pspec(mesh, SHAPES[shp.name].global_batch))
    if shp.kind == "prefill":
        fn = step_lib.make_prefill(cfg, mesh)
        frames = ins.get("frames")
        jfn = jax.jit(fn, in_shardings=(
            state_sh["params"], ins["tokens"].sharding, csh)
            + ((frames.sharding,) if frames is not None else ()),
            out_shardings=(logit_sh, csh), donate_argnums=(2,))
        args = (state_shapes["params"], ins["tokens"], cshapes) \
            + ((frames,) if frames is not None else ())
        with mesh_compat.set_mesh(mesh):
            return jfn.lower(*args)
    fn = step_lib.make_serve_step(cfg, mesh)
    jfn = jax.jit(fn, in_shardings=(state_sh["params"], csh,
                                    ins["token"].sharding, None),
                  out_shardings=(logit_sh, csh), donate_argnums=(1,))
    with mesh_compat.set_mesh(mesh):
        return jfn.lower(state_shapes["params"], cshapes, ins["token"],
                         jax.ShapeDtypeStruct((), jnp.int32))


def lower_cell(arch: str, shape_name: str, mesh, *, overrides=None,
               probe=True):
    """Lower + compile one (arch, shape, mesh) cell.

    Three compiles: the full scanned module (sharding/memory proof) and two
    unrolled depth probes (1 and 2 pattern-periods) whose cost_analysis is
    depth-extrapolated — XLA counts while-loop bodies once, so the scanned
    module's numbers cannot be used directly (see roofline/analysis.py).
    """
    import dataclasses
    cfg = configs.get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shp = SHAPES[shape_name]

    t0 = time.time()
    lowered = _lower_one(cfg, shp, mesh)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    probes = None
    if probe:
        pstats = []
        for k in (1, 2):
            pcfg = dataclasses.replace(
                cfg, n_layers=k * cfg.period,
                enc_layers=k if cfg.is_enc_dec else 0,
                scan_layers=False, microbatches=1, attn_chunk=0)
            pl = _lower_one(pcfg, shp, mesh)
            pstats.append(analysis.raw_stats(pl.compile()))
        probes = tuple(pstats)

    return analysis.collect(cfg, shp, mesh, lowered, compiled,
                            t_lower=t_lower, t_compile=t_compile,
                            probes=probes)


def run_cells(archs, shapes, meshes, out_dir=None, overrides=None,
              tag=""):
    results = []
    for mesh_name in meshes:
        mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
        for arch in archs:
            cfg = configs.get_config(arch)
            names = [s.name for s in supported_shapes(cfg)]
            for shape_name in shapes:
                if shape_name not in names:
                    print(f"SKIP {arch} {shape_name} ({mesh_name}): "
                          "full-attention arch, long-context infeasible "
                          "(DESIGN.md §Arch-applicability)")
                    continue
                key = f"{arch}|{shape_name}|{mesh_name}"
                try:
                    st = lower_cell(arch, shape_name, mesh,
                                    overrides=overrides)
                    st["cell"] = key
                    st["tag"] = tag
                    results.append(st)
                    print(f"OK   {key}: compile={st['t_compile']:.1f}s "
                          f"flops={st['flops']:.3e} "
                          f"bytes={st['bytes_accessed']:.3e} "
                          f"coll={st['collective_bytes']:.3e} "
                          f"mem/dev={st['bytes_per_device']/1e9:.2f}GB")
                except Exception as e:
                    print(f"FAIL {key}: {e}")
                    traceback.print_exc()
                    results.append({"cell": key, "error": str(e),
                                    "tag": tag})
                if out_dir:
                    import pathlib
                    p = pathlib.Path(out_dir)
                    p.mkdir(parents=True, exist_ok=True)
                    fname = key.replace("|", "_").replace(".", "_")
                    if tag:
                        fname += f"_{tag}"
                    (p / f"{fname}.json").write_text(
                        json.dumps(results[-1], indent=1, default=str))
                sys.stdout.flush()
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (hillclimb lever)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        import ast
        try:
            v = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            pass
        overrides[k] = v

    archs = configs.list_archs() if args.all or not args.arch \
        else [args.arch]
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    results = run_cells(archs, shapes, meshes, out_dir=args.out,
                        overrides=overrides or None, tag=args.tag)
    ok = sum(1 for r in results if "error" not in r)
    print(f"\n{ok}/{len(results)} cells compiled")
    return 0 if ok == len(results) else 1


if __name__ == "__main__":
    sys.exit(main())

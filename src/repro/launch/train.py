"""End-to-end training driver with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --smoke --steps 50 --batch 8 --seq 128

Fault-tolerance posture (exercised by tests/test_distribution.py):
  * atomic+async checkpoints every --ckpt-every steps (Checkpointer)
  * SIGTERM/SIGINT -> final checkpoint, clean exit (preemption survival)
  * resume: --resume picks up the latest step; the data pipeline is a pure
    function of step, so batches replay exactly (skip-ahead, no data state)
  * checkpoint cadence can be derived from a fleet MTBF via Young/Daly
    (--mtbf / --ckpt-cost) instead of a fixed interval
  * step watchdog: a step exceeding --step-timeout-s aborts with a
    checkpoint (straggler/hang mitigation — on a real fleet the launcher
    restarts the job on healthy nodes)
"""
from __future__ import annotations

import argparse
import dataclasses
import signal
import sys
import time

import jax
import numpy as np

from repro import configs
from repro.ckpt.checkpoint import Checkpointer
from repro.core.montecarlo import young_daly_interval
from repro.data.pipeline import DataConfig, get_batch
from repro.launch.mesh import make_local_mesh
from repro.sharding import compat as mesh_compat, partition
from repro.train import optim, step as step_lib


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mtbf", type=float, default=0.0,
                    help="fleet MTBF seconds -> Young/Daly cadence")
    ap.add_argument("--ckpt-cost", type=float, default=5.0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--step-timeout-s", type=float, default=0.0)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get_config(args.arch)
    cfg = dataclasses.replace(cfg, microbatches=1)
    mesh = make_local_mesh(args.data, args.model)
    multi = mesh.devices.size > 1

    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                    global_batch=args.batch, seed=args.seed)
    opt_cfg = optim.AdamWConfig(lr=args.lr, total_steps=args.steps)
    train_step = step_lib.make_train_step(cfg, mesh if multi else None,
                                          opt_cfg)

    state = step_lib.init_state(cfg, jax.random.key(args.seed))
    shardings = None
    if multi:
        shardings, _ = step_lib.state_shardings(cfg, mesh)
        state = jax.device_put(state, shardings)

    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if ckpt and args.resume and ckpt.latest_step() is not None:
        state, start = ckpt.restore(state, shardings=shardings)
        print(f"resumed from step {start}")

    every = args.ckpt_every
    if args.mtbf > 0:
        # steps-per-checkpoint from Young/Daly given measured step time
        every = max(1, int(young_daly_interval(args.mtbf, args.ckpt_cost)))
        print(f"Young/Daly cadence: checkpoint every ~{every}s of compute")

    stop = {"flag": False}

    def on_term(signum, frame):
        stop["flag"] = True
    signal.signal(signal.SIGTERM, on_term)
    signal.signal(signal.SIGINT, on_term)

    jit_step = jax.jit(train_step, donate_argnums=(0,))
    losses = []
    ctx = mesh_compat.set_mesh(mesh) if multi else None
    if ctx:
        ctx.__enter__()
    try:
        for step in range(start, args.steps):
            batch = get_batch(dc, step)
            t0 = time.time()
            state, metrics = jit_step(state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            if args.step_timeout_s and dt > args.step_timeout_s:
                print(f"WATCHDOG: step {step} took {dt:.1f}s "
                      f"> {args.step_timeout_s}s; checkpoint + abort")
                if ckpt:
                    ckpt.save(state, step + 1, blocking=True)
                return 42
            losses.append(loss)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms")
            if ckpt and (step + 1) % every == 0:
                ckpt.save(state, step + 1, blocking=False)
            if stop["flag"]:
                print(f"SIGTERM at step {step}: checkpointing and exiting")
                if ckpt:
                    ckpt.save(state, step + 1, blocking=True)
                return 0
        if ckpt:
            ckpt.save(state, args.steps, blocking=True)
    finally:
        if ctx:
            ctx.__exit__(None, None, None)
        if ckpt:
            ckpt.wait()

    if len(losses) >= 20:
        a = float(np.mean(losses[:5]))
        b = float(np.mean(losses[-5:]))
        print(f"loss first5={a:.4f} last5={b:.4f} "
              f"({'DECREASED' if b < a else 'no decrease'})")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state.  The single-pod mesh
is 16×16 = 256 chips (TPU v5e pod slice); multi-pod adds a leading "pod"
axis (2×16×16 = 512 chips).  Batch is sharded over ("pod","data"), tensor/
expert parallelism over "model".
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many real devices exist (tests/examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"))

"""Fault-tolerant checkpointing.

Design goals (1000+-node posture, DESIGN.md §4):
  * atomic   — write to ``<dir>/tmp.<step>`` then rename; a crash mid-write
    can never corrupt the latest checkpoint;
  * mesh-agnostic restore — leaves are saved as full logical arrays (one
    .npy per leaf, keyed by its pytree path), so a job can restart on a
    different mesh/pod count and re-shard on load (device_put against the
    new shardings);
  * async    — ``save(..., blocking=False)`` snapshots to host memory
    synchronously (cheap) and writes in a background thread so the train
    loop is not stalled by the filesystem;
  * manifest — step, leaf index and shapes in ``manifest.json`` for
    inspection and integrity checking.
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

_SAFE = re.compile(r"[^A-Za-z0-9_.-]+")

# numpy's .npy format round-trips custom dtypes (bfloat16, fp8) as raw void
# records it cannot cast later; store them as a same-width uint view and
# restore through ml_dtypes using the manifest's dtype string.
_VIEW = {"bfloat16": (np.uint16, ml_dtypes.bfloat16),
         "float8_e4m3fn": (np.uint8, ml_dtypes.float8_e4m3fn),
         "float8_e5m2": (np.uint8, ml_dtypes.float8_e5m2)}


def _leaf_name(path) -> str:
    return _SAFE.sub("_", jax.tree_util.keystr(path)).strip("_") or "leaf"


def _snapshot(tree):
    """Device -> host copy (gathers sharded arrays to full logical value)."""
    return jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ---- save ---------------------------------------------------------
    def save(self, state: Any, step: int, blocking: bool = True):
        host = _snapshot(state)
        if self._thread is not None:
            self._thread.join()                 # one in-flight write max
            self._thread = None
        if blocking:
            self._write(host, step)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(host, step), daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, host_state, step: int):
        tmp = self.dir / f"tmp.{step}"
        final = self.dir / f"step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        leaves, treedef = jax.tree_util.tree_flatten_with_path(host_state)
        manifest = {"step": step, "leaves": []}
        for i, (path, leaf) in enumerate(leaves):
            name = f"{i:04d}_{_leaf_name(path)}"
            arr = np.asarray(leaf)
            if arr.dtype.name in _VIEW:
                arr = arr.view(_VIEW[arr.dtype.name][0])
            np.save(tmp / f"{name}.npy", arr, allow_pickle=False)
            manifest["leaves"].append(
                {"name": name, "path": jax.tree_util.keystr(path),
                 "shape": list(np.shape(leaf)),
                 "dtype": str(np.asarray(leaf).dtype)})
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)                  # atomic publish
        self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # ---- restore ------------------------------------------------------
    def all_steps(self):
        return [int(p.name.split("_")[1]) for p in self.dir.glob("step_*")]

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return max(steps) if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None):
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: optional matching pytree of
        NamedShardings for the *current* mesh — this is what makes restarts
        elastic across mesh shapes."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:010d}"
        manifest = json.loads((d / "manifest.json").read_text())
        leaves, treedef = jax.tree_util.tree_flatten(like)
        assert len(leaves) == len(manifest["leaves"]), \
            (len(leaves), len(manifest["leaves"]))
        loaded = []
        for m in manifest["leaves"]:
            arr = np.load(d / f"{m['name']}.npy")
            if m["dtype"] in _VIEW:
                arr = arr.view(_VIEW[m["dtype"]][1])
            loaded.append(arr)
        for got, want in zip(loaded, leaves):
            assert tuple(got.shape) == tuple(want.shape), \
                f"shape mismatch: {got.shape} vs {want.shape}"
        tree = jax.tree_util.tree_unflatten(treedef, loaded)
        tree = jax.tree.map(
            lambda a, w: np.asarray(a).astype(w.dtype), tree, like)
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        return tree, step

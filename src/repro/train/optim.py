"""AdamW from scratch (no optax), pytree-native.

Moments are kept in fp32 regardless of param dtype; the update is computed
in fp32 and cast back.  Moment tensors inherit the parameter sharding
(`tree_shardings` is applied to the whole TrainState), which is what makes
optimizer state FSDP-sharded for the ≥100B configs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def init_moments(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params)}


def lr_at(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def global_norm(grads):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))


def adamw_update(cfg: AdamWConfig, params, grads, moments, step):
    """Returns (new_params, new_moments, stats)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip else 1.0
    lr = lr_at(cfg, step)
    t = step.astype(jnp.float32) + 1.0
    c1 = 1.0 - cfg.b1 ** t
    c2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        u = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        if p.ndim >= 2 and cfg.weight_decay:          # decay matrices only
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(moments["m"])
    flat_v = tdef.flatten_up_to(moments["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v}, {"grad_norm": gnorm, "lr": lr}

"""Distributed train / serve step builders.

``make_train_step`` returns a pure (state, batch) -> (state, metrics)
function with gradient accumulation over microbatches (lax.scan) and the
AdamW update; ``make_serve_step`` returns the one-token decode step used by
the decode_* / long_* dry-run shapes.  Both are built per (cfg, mesh) and
meant to be wrapped in jax.jit with shardings from ``state_shardings``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models import transformer
from ..models.config import ModelConfig
from ..sharding import partition
from . import optim


def mesh_axes_of(mesh):
    return partition.batch_axes(mesh) + ("model",) if mesh is not None \
        else ("data", "model")


# --------------------------------------------------------------------------
# state
# --------------------------------------------------------------------------

def init_state(cfg: ModelConfig, key, max_seq: int = 0):
    params, _ = transformer.make_params(cfg, key, max_seq)
    return {"params": params, "opt": optim.init_moments(params),
            "step": jnp.zeros((), jnp.int32)}


def state_shapes_and_specs(cfg: ModelConfig, max_seq: int = 0):
    """(state_shapes, state_logical_specs) without allocating anything."""
    pshapes, specs = _params_shapes_specs(cfg, max_seq)
    state_shapes = {"params": pshapes,
                    "opt": {"m": jax.tree.map(
                        lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32),
                        pshapes),
                        "v": jax.tree.map(
                        lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32),
                        pshapes)},
                    "step": jax.ShapeDtypeStruct((), jnp.int32)}
    state_specs = {"params": specs,
                   "opt": {"m": specs, "v": specs},
                   "step": ()}
    return state_shapes, state_specs


@functools.lru_cache(maxsize=64)
def _params_shapes_specs(cfg: ModelConfig, max_seq: int):
    """Trace make_params abstractly (no allocation); the logical specs are
    static python data captured via a side channel during tracing."""
    box = {}

    def build(k):
        p, s = transformer.make_params(cfg, k, max_seq)
        box["specs"] = s
        return p

    shapes = jax.eval_shape(build, jax.random.key(0))
    return shapes, box["specs"]


def state_shardings(cfg: ModelConfig, mesh, max_seq: int = 0, rules=None):
    shapes, specs = state_shapes_and_specs(cfg, max_seq)
    sh = partition.tree_shardings(specs, shapes, mesh, rules)
    return sh, shapes


# --------------------------------------------------------------------------
# train step
# --------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, mesh=None,
                    opt_cfg: optim.AdamWConfig = optim.AdamWConfig()):
    axes = mesh_axes_of(mesh)

    def loss_fn(params, tokens, labels, frames):
        if cfg.xent_chunk:
            x, _, aux = transformer.forward(
                cfg, params, tokens, mode="train", frames=frames,
                mesh=mesh, mesh_axes=axes, skip_head=True)
            head = params["embed"].T if cfg.tie_embeddings \
                else params["lm_head"]
            loss, parts = transformer.lm_loss_chunked(
                cfg, x, head.astype(x.dtype), labels, aux,
                final_softcap=cfg.final_softcap)
        else:
            logits, _, aux = transformer.forward(
                cfg, params, tokens, mode="train", frames=frames,
                mesh=mesh, mesh_axes=axes)
            loss, parts = transformer.lm_loss(cfg, logits, labels, aux)
        return loss, parts

    def train_step(state, batch):
        params = state["params"]
        mb = cfg.microbatches
        tokens, labels = batch["tokens"], batch["labels"]
        frames = batch.get("frames")
        B = tokens.shape[0]
        assert B % mb == 0, (B, mb)

        if mb == 1:
            (loss, parts), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, tokens, labels, frames)
        else:
            has_frames = frames is not None
            r = lambda x: x.reshape(mb, B // mb, *x.shape[1:])

            def micro(acc, xs):
                tk, lb = xs[0], xs[1]
                fr = xs[2] if has_frames else None
                (l, pts), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, tk, lb, fr)
                acc_g, acc_l = acc
                acc_g = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), acc_g, g)
                return (acc_g, acc_l + l), pts

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            xs = (r(tokens), r(labels)) + ((r(frames),) if has_frames
                                           else ())
            (grads, loss), parts = jax.lax.scan(micro, (zero_g, 0.0), xs)
            grads = jax.tree.map(lambda g: g / mb, grads)
            loss = loss / mb
            parts = jax.tree.map(lambda x: x.mean(), parts)

        new_params, new_opt, stats = optim.adamw_update(
            opt_cfg, params, grads, state["opt"], state["step"])
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = {"loss": loss, **stats,
                   **{k: v for k, v in parts.items()}}
        return new_state, metrics

    return train_step


# --------------------------------------------------------------------------
# serve steps
# --------------------------------------------------------------------------

def make_prefill(cfg: ModelConfig, mesh=None):
    axes = mesh_axes_of(mesh)

    def prefill(params, tokens, cache, frames=None):
        logits, new_cache, _ = transformer.forward(
            cfg, params, tokens, mode="prefill", cache=cache,
            frames=frames, mesh=mesh, mesh_axes=axes)
        return logits[:, -1], new_cache

    return prefill


def make_serve_step(cfg: ModelConfig, mesh=None):
    """One-token decode: (params, cache, token (B,1), pos ()) ->
    (logits (B, vocab), new_cache)."""
    axes = mesh_axes_of(mesh)

    def serve_step(params, cache, token, pos):
        logits, new_cache, _ = transformer.forward(
            cfg, params, token, mode="decode", cache=cache, pos=pos,
            mesh=mesh, mesh_axes=axes)
        return logits[:, 0], new_cache

    return serve_step

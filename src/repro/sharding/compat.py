"""JAX mesh-context API drift shims.

``jax.set_mesh`` (newer releases) / ``jax.sharding.use_mesh`` (0.4.35+) /
``with mesh:`` (classic Mesh context manager) all install an ambient mesh
for NamedSharding resolution; resolve whichever this jax provides.
"""
from __future__ import annotations

import jax


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh."""
    fn = getattr(jax, "set_mesh", None) \
        or getattr(jax.sharding, "use_mesh", None)
    if fn is not None:
        return fn(mesh)
    return mesh          # jax.sharding.Mesh is itself a context manager


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """``jax.shard_map`` (newer) / ``jax.experimental.shard_map.shard_map``
    (older, where ``check_vma`` was spelled ``check_rep``)."""
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  **kw)
    from jax.experimental.shard_map import shard_map as legacy
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  **kw)

"""Logical-axis sharding rule engine.

Parameters/caches carry *logical* axis names per dim (see
``models/transformer.py``); this module resolves them against a mesh into
``PartitionSpec``s with two safety rails:

  * divisibility fallback — a dim whose size is not divisible by the mesh
    axes assigned to it is replicated instead (small KV projections, odd
    head counts, B=1 decode batches all degrade gracefully);
  * single-use rail — one mesh axis may shard at most one dim of a given
    array; later dims fall back to replication.

Default rules (TP over "model", FSDP over the batch axes, DP over
pod×data):

  vocab/heads/ff/expert/ssm -> model        (tensor/expert parallelism)
  embed                     -> pod,data     (FSDP: params gathered per layer)
  batch                     -> pod,data     (data parallelism)
  kv_seq                    -> model        (decode KV cache sequence dim)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def default_rules(mesh: Mesh, *, fsdp: bool = True) -> Dict[str, Any]:
    bax = batch_axes(mesh)
    return {
        "vocab": ("model",),
        "heads": ("model",),
        "kv": None,                  # kv_dim: covered by embed-FSDP instead
        "kv_heads": None,
        "ff": ("model",),
        "expert": ("model",),
        "e_ff": None,                # expert hidden: see serve_rules
        "ssm": ("model",),
        "embed": bax if fsdp else None,
        "batch": bax,
        "kv_seq": ("model",),
        "seq": None,
    }


def serve_rules(mesh: Mesh) -> Dict[str, Any]:
    """Weights-stationary decode sharding: no FSDP over the contraction dim
    (which would all-gather every weight once per generated token) — instead
    experts get a second fixed shard dim (e_ff over the batch axes) so the
    full parameter set still spreads across ALL chips while only KB-sized
    activations move per step."""
    r = default_rules(mesh, fsdp=False)
    r["e_ff"] = batch_axes(mesh)
    return r


def _axes_size(mesh: Mesh, axes: Sequence[str]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes]))


def resolve_spec(logical: Tuple[Optional[str], ...], shape: Tuple[int, ...],
                 mesh: Mesh, rules: Dict[str, Any]) -> P:
    used: set = set()
    out = []
    for dim, name in zip(shape, logical):
        axes = rules.get(name) if name else None
        if axes is None:
            out.append(None)
            continue
        axes = (axes,) if isinstance(axes, str) else tuple(axes)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        if not axes or any(a in used for a in axes) \
                or dim % _axes_size(mesh, axes) != 0:
            out.append(None)
            continue
        used.update(axes)
        out.append(axes[0] if len(axes) == 1 else axes)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_pspecs(specs, shapes, mesh: Mesh, rules=None):
    """specs: pytree of logical tuples; shapes: matching pytree of
    array-likes (or ShapeDtypeStructs).  Returns pytree of PartitionSpec."""
    rules = rules or default_rules(mesh)
    def is_spec(x):
        return isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)
    return jax.tree.map(
        lambda sp, a: resolve_spec(sp, a.shape, mesh, rules),
        specs, shapes, is_leaf=is_spec)


def tree_shardings(specs, shapes, mesh: Mesh, rules=None):
    ps = tree_pspecs(specs, shapes, mesh, rules)
    return jax.tree.map(lambda p: NamedSharding(mesh, p), ps,
                        is_leaf=lambda x: isinstance(x, P))


def batch_pspec(mesh: Mesh, global_batch: Optional[int] = None) -> P:
    """Batch sharding over (pod, data); falls back to replication when the
    batch is not divisible (e.g. the B=1 long-context decode shape)."""
    bax = batch_axes(mesh)
    if global_batch is not None and global_batch % _axes_size(mesh, bax):
        return P()
    return P(bax if len(bax) > 1 else bax[0])


# --------------------------------------------------------------------------
# simulator state sharding (core/shard_sim.py)
# --------------------------------------------------------------------------
#
# The DES state has exactly two shardable logical axes: "server" (the
# rack-major per-server axis of ServerFarm/ThermalState) and "rack" (the
# per-rack CRAC arrays).  Both map onto the same mesh axis — a contiguous
# block of whole racks per device — so rack row-reductions never straddle
# a shard boundary.  Everything else (job/flow/switch tables, telemetry
# windows, the trace ring, scalars) is replicated.

SIM_AXIS = "racks"

# ThermalState fields that carry the per-server / per-rack axes.  The
# remaining thermal fields (scalar integrals, ctrl_next) are replicated,
# as is rack_onehot: it is only non-empty for NON-contiguous rack
# groupings, which the sharded path rejects up front.
THERMAL_SERVER_FIELDS = frozenset(
    {"t_srv", "throttled", "rack_id", "t_peak", "throttle_seconds"})
THERMAL_RACK_FIELDS = frozenset({"t_set", "rack_inv"})


def sim_rules(axis: str = SIM_AXIS) -> Dict[str, Any]:
    return {"server": (axis,), "rack": (axis,)}


def sim_state_specs(state, cfg, mesh: Mesh, axis: str = SIM_AXIS):
    """Flat per-leaf PartitionSpecs for a SimState (leaf order of
    ``jax.tree.flatten``): rack-major axes -> P(axis), all else P().

    Uses the same ``resolve_spec`` rail as the model shardings, so a
    non-divisible farm degrades to replication instead of crashing —
    ``shard_sim.run_sharded`` validates divisibility up front and treats
    that fallback as an error."""
    rules = sim_rules(axis)
    N = cfg.n_servers
    leaves_with_path, _ = jax.tree_util.tree_flatten_with_path(state)
    out = []
    for path, leaf in leaves_with_path:
        names = [getattr(k, "name", str(k)) for k in path]
        top, name = names[0], names[-1]
        ndim = getattr(leaf, "ndim", 0)
        ax0 = None
        if ndim >= 1:
            if top == "farm" and leaf.shape[0] == N:
                ax0 = "server"
            elif top == "thermal" and cfg.thermal.enabled:
                if name in THERMAL_SERVER_FIELDS:
                    ax0 = "server"
                elif name in THERMAL_RACK_FIELDS:
                    ax0 = "rack"
        logical = (ax0,) + (None,) * (ndim - 1) if ndim else ()
        out.append(resolve_spec(logical, leaf.shape, mesh, rules))
    return tuple(out)

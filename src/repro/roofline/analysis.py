"""Three-term roofline from the compiled dry-run artifact.

  compute    = FLOPs_per_device / peak_FLOPs_per_chip
  memory     = bytes_per_device / HBM_bw_per_chip
  collective = collective_bytes_per_device / ICI_link_bw_per_chip

``compiled.cost_analysis()`` reports the *partitioned* (per-device) SPMD
module, so all three terms use per-chip quantities against per-chip rates —
numerically identical to the global/(chips×rate) form in the spec.

collective_bytes is not in cost_analysis: we parse the post-optimization
HLO (``compiled.as_text()``) and sum the result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(+ the fused -start variants, counted once).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(\([^=]*?\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def hlo_collective_bytes(hlo_text: str) -> Dict[str, float]:
    out = {k: 0.0 for k in _COLLECTIVES}
    out["total"] = 0.0
    out["count"] = 0
    for m in _LINE_RE.finditer(hlo_text):
        b = _shape_bytes(m.group(1))
        out[m.group(2)] += b
        out["total"] += b
        out["count"] += 1
    return out


def _cost_dict(compiled):
    try:
        c = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(c, list):        # older jax returns [dict]
        c = c[0] if c else {}
    return dict(c) if c else {}


def _memory_stats(compiled):
    out = {}
    try:
        m = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            if hasattr(m, k):
                out[k] = int(getattr(m, k))
    except Exception:
        pass
    return out


def raw_stats(compiled) -> dict:
    """Per-device flops / HBM bytes / collective bytes of one compiled
    module.  NOTE: XLA's cost_analysis counts loop bodies ONCE (not × trip
    count), so this is only meaningful for fully unrolled probe modules —
    see ``extrapolate``."""
    cost = _cost_dict(compiled)
    coll = hlo_collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(coll["total"]),
            "coll_by_type": {k: v for k, v in coll.items()
                             if k in _COLLECTIVES}}


def extrapolate(p1: dict, p2: dict, n_periods: int) -> dict:
    """Linear depth extrapolation from two unrolled probes at 1 and 2
    pattern-periods: total(L) = p1 + (L-1)·(p2-p1)."""
    out = {}
    for k in ("flops", "bytes", "coll"):
        delta = max(p2[k] - p1[k], 0.0)
        out[k] = p1[k] + (n_periods - 1) * delta
    out["coll_by_type"] = {
        k: p1["coll_by_type"][k] + (n_periods - 1) * max(
            p2["coll_by_type"][k] - p1["coll_by_type"][k], 0.0)
        for k in p1["coll_by_type"]}
    return out


def recurrent_flop_correction(cfg, shp, chips: int) -> float:
    """Per-device FLOPs inside time-step lax.scan loops (sLSTM recurrence,
    Mamba state scan) that even unrolled-layer probes undercount (the time
    loop body is counted once).  Analytic, documented in EXPERIMENTS.md.
    Train ≈ 3× forward (fwd + 2× transpose), +1 if full remat."""
    if shp.kind == "decode":
        return 0.0                      # single step, fully counted
    tokens = shp.tokens
    mult = 1.0
    if shp.kind == "train":
        mult = 3.0 + (1.0 if cfg.remat == "full" else 0.0)
    per_layer = 0.0
    counts = {k: cfg.block_pattern.count(k) * cfg.n_periods
              for k in set(cfg.block_pattern)}
    if counts.get("slstm"):
        dh = cfg.d_model // cfg.n_heads
        per_layer += counts["slstm"] * 2 * cfg.n_heads * dh * 4 * dh
    n_mamba = counts.get("mamba", 0) + counts.get("hymba", 0)
    if n_mamba and cfg.ssm_state:
        per_layer += n_mamba * 6 * cfg.d_ssm * cfg.ssm_state
    return mult * tokens * per_layer / max(chips, 1)


def model_flops(cfg, shp) -> float:
    """Paper-convention useful FLOPs: 6·N·D train, 2·N·D inference, with
    N = active params for MoE."""
    n_active = cfg.param_count(active_only=True)
    tokens = shp.tokens if shp.kind != "decode" else shp.global_batch
    mult = 6.0 if shp.kind == "train" else 2.0
    return mult * n_active * tokens


def collect(cfg, shp, mesh, lowered, compiled, *, t_lower=0.0,
            t_compile=0.0, probes=None) -> dict:
    """probes: (p1, p2) raw_stats of the 1- and 2-period unrolled modules;
    when given, flops/bytes/collectives are depth-extrapolated from them
    (the scanned full module undercounts loop bodies).  The full compile
    still supplies memory_analysis and the compile-success proof."""
    chips = mesh.devices.size
    cost = _cost_dict(compiled)
    mem = _memory_stats(compiled)
    text = compiled.as_text()
    coll = hlo_collective_bytes(text)

    if probes is not None:
        p1, p2 = probes
        tot = extrapolate(p1, p2, cfg.n_periods)
        flops = tot["flops"] + recurrent_flop_correction(cfg, shp, chips)
        bytes_acc = tot["bytes"]
        coll_total = tot["coll"]
        coll_by_type = tot["coll_by_type"]
    else:
        flops = float(cost.get("flops", 0.0))
        bytes_acc = float(cost.get("bytes accessed", 0.0))
        coll_total = coll["total"]
        coll_by_type = {k: v for k, v in coll.items() if k in _COLLECTIVES}
    terms = {
        "t_compute": flops / PEAK_FLOPS,
        "t_memory": bytes_acc / HBM_BW,
        "t_collective": coll_total / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    step_est = max(terms.values())
    mflops = model_flops(cfg, shp)
    useful = mflops / max(flops * chips, 1.0)
    roofline_frac = (mflops / chips / PEAK_FLOPS) / max(step_est, 1e-30)

    bytes_per_dev = sum(v for k, v in mem.items()
                        if k in ("argument_size_in_bytes",
                                 "output_size_in_bytes",
                                 "temp_size_in_bytes"))
    return {
        "arch": cfg.name, "shape": shp.name, "kind": shp.kind,
        "chips": chips,
        "mesh": dict(mesh.shape),
        "flops": flops, "bytes_accessed": bytes_acc,
        "collective_bytes": coll_total,
        "collectives": coll_by_type,
        "flops_scanned_module": float(cost.get("flops", 0.0)),
        **terms,
        "dominant": dominant,
        "step_time_est": step_est,
        "model_flops": mflops,
        "useful_flop_ratio": useful,
        "roofline_fraction": roofline_frac,
        "bytes_per_device": bytes_per_dev,
        "memory": mem,
        "t_lower": t_lower, "t_compile": t_compile,
        "params": cfg.param_count(),
        "params_active": cfg.param_count(active_only=True),
    }

"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline table."""
from __future__ import annotations

import json
import pathlib
import sys


def load(dir_="results/dryrun", tag=""):
    rows = []
    for p in sorted(pathlib.Path(dir_).glob("*.json")):
        d = json.loads(p.read_text())
        if "error" in d or d.get("tag", "") != tag:
            continue
        rows.append(d)
    return rows


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def markdown(rows, mesh_filter=None):
    hdr = ("| arch | shape | mesh | compute | memory | collective | "
           "dominant | step est | useful FLOP | roofline frac | GB/dev |")
    sep = "|" + "---|" * 11
    out = [hdr, sep]
    for d in rows:
        mesh = "multipod" if "pod" in d["mesh"] else "pod"
        if mesh_filter and mesh != mesh_filter:
            continue
        out.append(
            f"| {d['arch']} | {d['shape']} | {mesh} "
            f"| {fmt_s(d['t_compute'])} | {fmt_s(d['t_memory'])} "
            f"| {fmt_s(d['t_collective'])} "
            f"| {d['dominant'].replace('t_', '')} "
            f"| {fmt_s(d['step_time_est'])} "
            f"| {d['useful_flop_ratio']:.2f} "
            f"| {d['roofline_fraction']:.3f} "
            f"| {d['bytes_per_device']/1e9:.1f} |")
    return "\n".join(out)


def main():
    rows = load(*(sys.argv[1:2] or ["results/dryrun"]))
    print(markdown(rows))
    print()
    # worst cells by roofline fraction (train/prefill only — decode is
    # inherently memory-bound)
    interesting = [r for r in rows if r["kind"] != "decode"
                   and "pod" not in str(r["mesh"].get("pod", ""))]
    interesting = sorted(rows, key=lambda r: r["roofline_fraction"])
    print("lowest roofline fraction cells:")
    for r in interesting[:6]:
        print(f"  {r['arch']} {r['shape']} {r['mesh']} "
              f"frac={r['roofline_fraction']:.3f} dom={r['dominant']}")
    coll = sorted(rows, key=lambda r: -(r["t_collective"] /
                                        max(r["step_time_est"], 1e-30)))
    print("most collective-bound cells:")
    for r in coll[:6]:
        print(f"  {r['arch']} {r['shape']} {r['mesh']} "
              f"coll_share={r['t_collective']/max(r['step_time_est'],1e-30):.2f}")


if __name__ == "__main__":
    main()

"""Deterministic synthetic token pipeline.

Batches are a pure function of (seed, step, shard) — ``jax.random.fold_in``
chains — which gives the two properties a distributed trainer needs:

  * restart determinism: resuming from step k replays exactly the batches
    k, k+1, ... with no data-state checkpointing (skip-ahead is free);
  * shard determinism: each data shard draws a disjoint, reproducible
    stream regardless of how many hosts the job restarts with.

The token distribution is a Zipf-like categorical (heavy head, long tail)
so cross-entropy curves behave like natural text rather than uniform noise;
labels are next-token shifted with the final position masked.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_alpha: float = 1.2
    n_shards: int = 1


@functools.partial(jax.jit, static_argnames=("dc",))
def _zipf_logits(dc: DataConfig):
    ranks = jnp.arange(1, dc.vocab + 1, dtype=jnp.float32)
    return -dc.zipf_alpha * jnp.log(ranks)


def get_batch(dc: DataConfig, step: int, shard: int = 0):
    """Returns {"tokens" (B_shard, S), "labels"} for this (step, shard)."""
    assert dc.global_batch % dc.n_shards == 0
    b = dc.global_batch // dc.n_shards
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.key(dc.seed), step), shard)
    logits = _zipf_logits(dc)
    toks = jax.random.categorical(
        key, jnp.broadcast_to(logits, (b, dc.seq_len + 1, dc.vocab)))
    tokens = toks[:, :-1].astype(jnp.int32)
    labels = toks[:, 1:].astype(jnp.int32)
    labels = labels.at[:, -1].set(-1)          # mask the boundary position
    return {"tokens": tokens, "labels": labels}


def batch_iterator(dc: DataConfig, start_step: int = 0, shard: int = 0):
    step = start_step
    while True:
        yield step, get_batch(dc, step, shard)
        step += 1

"""High-level simulation entry points + result summarization."""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import engine, jobs as jobs_mod, telemetry as telemetry_mod
from . import traceio
from .types import INF, SimConfig, SimState


@dataclasses.dataclass
class RunInfo:
    """Run provenance: host wall clock + the exact config that produced
    the result, for BENCH/CI artifacts and trace headers."""
    wall_s: float                   # wall time of the timed engine run
    steps: int                      # while-loop iterations (macro-steps)
    events: int                     # events retired
    events_per_s: float             # events / wall_s
    backend: str                    # jax.default_backend()
    config: dict                    # recursive SimConfig dump
    jit_compile_s: float = float("nan")  # only with simulate(profile=True)


def _config_dict(obj):
    """Recursive dataclass -> plain-JSON dump (dtypes etc. stringified)."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {f.name: _config_dict(getattr(obj, f.name))
                for f in dataclasses.fields(obj)}
    if isinstance(obj, (list, tuple)):
        return [_config_dict(v) for v in obj]
    if isinstance(obj, (bool, int, float, str)) or obj is None:
        return obj
    try:
        return np.dtype(obj).name
    except TypeError:
        return str(obj)


@dataclasses.dataclass
class SimResult:
    """Host-side summary of one simulation run."""
    sim_time: float
    events: int
    n_jobs: int
    n_finished: int
    mean_latency: float
    p50_latency: float
    p90_latency: float
    p95_latency: float
    p99_latency: float
    server_energy: float            # joules, total
    switch_energy: float
    energy_per_server: np.ndarray   # (N,)
    residency: np.ndarray           # (N, SrvState.NUM) seconds
    wake_count: np.ndarray          # (N,)
    busy_core_seconds: float
    utilization: float              # busy core-seconds / (N*C*T)
    dropped: int
    latencies: np.ndarray           # (J,) finished-job latencies (sec)
    # device-side telemetry summary (None when cfg.telemetry.enabled=False)
    telemetry: Optional[telemetry_mod.TelemetrySummary] = None
    # network: flow spawns refused by a full FlowTable (drop-resolved)
    flows_dropped: int = 0
    # thermal/carbon-cost subsystem (zeros/NaN when thermal disabled)
    cooling_energy: float = 0.0     # CRAC joules
    carbon_g: float = 0.0           # grams CO2 (IT + cooling)
    energy_cost: float = 0.0        # $ at the diurnal tariff
    peak_temp: float = float("nan")  # °C, hottest server over the run
    mean_temp: float = float("nan")  # °C, final farm mean
    throttle_seconds: float = 0.0   # summed over servers
    temps: Optional[np.ndarray] = None       # (N,) final temperatures
    peak_temps: Optional[np.ndarray] = None  # (N,) per-server peaks
    setpoints: Optional[np.ndarray] = None   # (R,) final CRAC setpoints
    # carbon-aware control plane (SchedPolicy.CARBON_AWARE)
    deferred_jobs: int = 0          # jobs released after a deferral
    deferred_seconds: float = 0.0   # summed deferral wait
    carbon_g_avoided_est: float = 0.0  # first-order grams-avoided estimate
    # flight recorder (None when cfg.trace.enabled=False)
    trace_events: Optional[np.ndarray] = None  # EVENT_DTYPE, chronological
    trace_dropped: int = 0          # records evicted by ring wrap-around
    run_info: Optional[RunInfo] = None

    @property
    def mean_power(self) -> float:
        return (self.server_energy + self.switch_energy
                + self.cooling_energy) / max(self.sim_time, 1e-12)

    @property
    def total_energy(self) -> float:
        return self.server_energy + self.switch_energy + self.cooling_energy


def summarize(state: SimState, cfg: SimConfig) -> SimResult:
    arr = np.asarray(state.jobs.arrival)
    fin = np.asarray(state.jobs.job_finish)
    ok = (fin < INF / 2) & (arr < INF / 2)
    lat = (fin - arr)[ok]
    t = float(state.t)
    N, C = cfg.n_servers, cfg.n_cores
    pct = (lambda q: float(np.percentile(lat, q))) if lat.size else \
        (lambda q: float("nan"))
    thermal_kw = {}
    if cfg.thermal.enabled:
        th = state.thermal
        temps = np.asarray(th.t_srv)
        peaks = np.asarray(th.t_peak)
        thermal_kw = dict(
            cooling_energy=float(th.cool_energy),
            carbon_g=float(th.carbon_g),
            energy_cost=float(th.cost),
            peak_temp=float(peaks.max()),
            mean_temp=float(temps.mean()),
            throttle_seconds=float(np.asarray(th.throttle_seconds).sum()),
            temps=temps,
            peak_temps=peaks,
            setpoints=np.asarray(th.t_set),
            deferred_jobs=int(th.defer_count),
            deferred_seconds=float(th.defer_seconds),
            carbon_g_avoided_est=float(th.grams_avoided),
        )
    trace_kw = {}
    if cfg.trace.enabled:
        ev, n_drop = traceio.decode(state.trace, cfg)
        trace_kw = dict(trace_events=ev, trace_dropped=n_drop)
    return SimResult(
        sim_time=t,
        events=int(state.events),
        n_jobs=int((arr < INF / 2).sum()),
        n_finished=int(ok.sum()),
        mean_latency=float(lat.mean()) if lat.size else float("nan"),
        p50_latency=pct(50), p90_latency=pct(90),
        p95_latency=pct(95), p99_latency=pct(99),
        server_energy=float(np.asarray(state.farm.energy).sum()),
        switch_energy=float(np.asarray(state.net.sw_energy).sum()),
        energy_per_server=np.asarray(state.farm.energy),
        residency=np.asarray(state.farm.residency),
        wake_count=np.asarray(state.farm.wake_count),
        busy_core_seconds=float(np.asarray(
            state.farm.busy_core_seconds).sum()),
        utilization=float(np.asarray(state.farm.busy_core_seconds).sum()
                          / max(N * C * t, 1e-12)),
        dropped=int(state.farm.dropped),
        latencies=lat,
        telemetry=(telemetry_mod.summarize(state, cfg)
                   if cfg.telemetry.enabled else None),
        flows_dropped=int(state.flows.flows_dropped),
        **thermal_kw,
        **trace_kw,
    )


def simulate(cfg: SimConfig, arrivals, specs, topo=None, tau=None,
             pools=None, racks=None, profile: bool = False) -> SimResult:
    """Build the job table, run the engine to completion, summarize.

    tau   — scalar or (N,) delay-timer values (seconds; INF = never sleep)
    pools — (N,) 0/1 pool assignment (dual-timer low/high, WASP active/sleep)
    racks — (N,) rack ids for the thermal recirculation grouping (defaults
            to the topology's top-of-rack grouping, else i // rack_size)
    profile — rerun the (now warm) engine once more to split JIT compile
            time out of the wall clock (result.run_info.jit_compile_s)
    """
    jt = jobs_mod.build_jobs(cfg, np.asarray(arrivals), specs)
    state, tc = engine.init_state(cfg, jt, topo, racks)
    if tau is not None:
        tau_arr = jnp.broadcast_to(jnp.asarray(tau, cfg.time_dtype),
                                   (cfg.n_servers,))
        state = dataclasses.replace(
            state, farm=dataclasses.replace(state.farm, srv_tau=tau_arr))
    if pools is not None:
        state = dataclasses.replace(
            state, farm=dataclasses.replace(
                state.farm,
                srv_pool=jnp.asarray(pools, jnp.int32)))
    t0 = time.perf_counter()
    final = jax.block_until_ready(engine.run(state, cfg, tc))
    wall = time.perf_counter() - t0
    compile_s = float("nan")
    if profile:
        t1 = time.perf_counter()
        final = jax.block_until_ready(engine.run(state, cfg, tc))
        warm = time.perf_counter() - t1
        compile_s = max(wall - warm, 0.0)
        wall = warm
    res = summarize(final, cfg)
    n_ev = int(final.events)
    res.run_info = RunInfo(
        wall_s=wall, steps=int(final.steps), events=n_ev,
        events_per_s=n_ev / max(wall, 1e-12),
        backend=jax.default_backend(), config=_config_dict(cfg),
        jit_compile_s=compile_s)
    return res

"""High-level simulation entry points + result summarization."""
from __future__ import annotations

import dataclasses
import hashlib
import json
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import engine, jobs as jobs_mod, telemetry as telemetry_mod
from . import traceio
from .types import INF, SimConfig, SimState


@dataclasses.dataclass
class RunInfo:
    """Run provenance: host wall clock + the exact config that produced
    the result, for BENCH/CI artifacts and trace headers."""
    wall_s: float                   # wall time of the timed engine run
    steps: int                      # while-loop iterations (macro-steps)
    events: int                     # events retired
    events_per_s: float             # events / wall_s
    backend: str                    # jax.default_backend()
    config: dict                    # recursive SimConfig dump
    jit_compile_s: float = float("nan")  # only with simulate(profile=True)
    # execution-mesh provenance: how the state was laid out, NOT part of
    # the scenario — config_digest deliberately excludes it so the same
    # scenario run on 1 or 8 devices compares equal
    devices: int = 1                # devices the run executed on
    mesh_shape: tuple = ()          # e.g. (8,)
    mesh_axes: tuple = ()           # e.g. ("racks",)
    sharding: str = ""              # PartitionSpec of the server axis
    config_digest: str = ""         # sha1 over the device-count-free config


def _config_dict(obj):
    """Recursive dataclass -> plain-JSON dump (dtypes etc. stringified)."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {f.name: _config_dict(getattr(obj, f.name))
                for f in dataclasses.fields(obj)}
    if isinstance(obj, (list, tuple)):
        return [_config_dict(v) for v in obj]
    if isinstance(obj, (bool, int, float, str)) or obj is None:
        return obj
    try:
        return np.dtype(obj).name
    except TypeError:
        return str(obj)


def config_digest(cfg: SimConfig) -> str:
    """Stable sha1 of the scenario config, EXCLUDING the partition block
    (shard/device count is an execution choice, not a scenario): the same
    farm run unsharded and on an 8-device mesh digests identically."""
    d = _config_dict(cfg)
    d.pop("partition", None)
    return hashlib.sha1(
        json.dumps(d, sort_keys=True).encode()).hexdigest()


def pad_to_racks(cfg: SimConfig, n_shards: Optional[int] = None) -> SimConfig:
    """Round the farm up to whole racks (and to a rack count divisible by
    ``n_shards``) with inert filler rows.

    The returned config has ``n_servers`` padded and ``n_present`` holding
    the real server count.  Padded rows boot OFF/disabled: they draw zero
    power, emit no events, are never scheduler-eligible, and are masked
    out of the telemetry temperature/state columns — so results match the
    unpadded farm while every rack is full and the rack-major partition
    cuts cleanly.  ``n_shards`` defaults to ``cfg.partition.n_shards``."""
    K = max(n_shards if n_shards is not None else cfg.partition.n_shards, 1)
    rs = max(cfg.thermal.rack_size, 1) if cfg.thermal.enabled else 1
    block = rs * K
    real = cfg.present
    n = -(-real // block) * block
    kw = {}
    if n_shards is not None and n_shards != cfg.partition.n_shards:
        kw["partition"] = dataclasses.replace(cfg.partition,
                                              n_shards=n_shards)
    if n == cfg.n_servers and not kw:
        return cfg
    return dataclasses.replace(cfg, n_servers=n,
                               n_present=real if n > real else 0, **kw)


@dataclasses.dataclass
class SimResult:
    """Host-side summary of one simulation run."""
    sim_time: float
    events: int
    n_jobs: int
    n_finished: int
    mean_latency: float
    p50_latency: float
    p90_latency: float
    p95_latency: float
    p99_latency: float
    server_energy: float            # joules, total
    switch_energy: float
    energy_per_server: np.ndarray   # (N,)
    residency: np.ndarray           # (N, SrvState.NUM) seconds
    wake_count: np.ndarray          # (N,)
    busy_core_seconds: float
    utilization: float              # busy core-seconds / (N*C*T)
    dropped: int
    latencies: np.ndarray           # (J,) finished-job latencies (sec)
    # device-side telemetry summary (None when cfg.telemetry.enabled=False)
    telemetry: Optional[telemetry_mod.TelemetrySummary] = None
    # network: flow spawns refused by a full FlowTable (drop-resolved)
    flows_dropped: int = 0
    # thermal/carbon-cost subsystem (zeros/NaN when thermal disabled)
    cooling_energy: float = 0.0     # CRAC joules
    carbon_g: float = 0.0           # grams CO2 (IT + cooling)
    energy_cost: float = 0.0        # $ at the diurnal tariff
    peak_temp: float = float("nan")  # °C, hottest server over the run
    mean_temp: float = float("nan")  # °C, final farm mean
    throttle_seconds: float = 0.0   # summed over servers
    temps: Optional[np.ndarray] = None       # (N,) final temperatures
    peak_temps: Optional[np.ndarray] = None  # (N,) per-server peaks
    setpoints: Optional[np.ndarray] = None   # (R,) final CRAC setpoints
    # carbon-aware control plane (SchedPolicy.CARBON_AWARE)
    deferred_jobs: int = 0          # jobs released after a deferral
    deferred_seconds: float = 0.0   # summed deferral wait
    carbon_g_avoided_est: float = 0.0  # first-order grams-avoided estimate
    # flight recorder (None when cfg.trace.enabled=False)
    trace_events: Optional[np.ndarray] = None  # EVENT_DTYPE, chronological
    trace_dropped: int = 0          # records evicted by ring wrap-around
    run_info: Optional[RunInfo] = None

    @property
    def mean_power(self) -> float:
        return (self.server_energy + self.switch_energy
                + self.cooling_energy) / max(self.sim_time, 1e-12)

    @property
    def total_energy(self) -> float:
        return self.server_energy + self.switch_energy + self.cooling_energy


def summarize(state: SimState, cfg: SimConfig) -> SimResult:
    arr = np.asarray(state.jobs.arrival)
    fin = np.asarray(state.jobs.job_finish)
    ok = (fin < INF / 2) & (arr < INF / 2)
    lat = (fin - arr)[ok]
    t = float(state.t)
    # utilization is over REAL servers: inert filler rows (pad_to_racks)
    # own no cores anyone could have used
    N, C = cfg.present, cfg.n_cores
    pct = (lambda q: float(np.percentile(lat, q))) if lat.size else \
        (lambda q: float("nan"))
    thermal_kw = {}
    if cfg.thermal.enabled:
        th = state.thermal
        temps = np.asarray(th.t_srv)
        peaks = np.asarray(th.t_peak)
        thermal_kw = dict(
            cooling_energy=float(th.cool_energy),
            carbon_g=float(th.carbon_g),
            energy_cost=float(th.cost),
            peak_temp=float(peaks.max()),
            mean_temp=float(temps.mean()),
            throttle_seconds=float(np.asarray(th.throttle_seconds).sum()),
            temps=temps,
            peak_temps=peaks,
            setpoints=np.asarray(th.t_set),
            deferred_jobs=int(th.defer_count),
            deferred_seconds=float(th.defer_seconds),
            carbon_g_avoided_est=float(th.grams_avoided),
        )
    trace_kw = {}
    if cfg.trace.enabled:
        ev, n_drop = traceio.decode(state.trace, cfg)
        trace_kw = dict(trace_events=ev, trace_dropped=n_drop)
    return SimResult(
        sim_time=t,
        events=int(state.events),
        n_jobs=int((arr < INF / 2).sum()),
        n_finished=int(ok.sum()),
        mean_latency=float(lat.mean()) if lat.size else float("nan"),
        p50_latency=pct(50), p90_latency=pct(90),
        p95_latency=pct(95), p99_latency=pct(99),
        server_energy=float(np.asarray(state.farm.energy).sum()),
        switch_energy=float(np.asarray(state.net.sw_energy).sum()),
        energy_per_server=np.asarray(state.farm.energy),
        residency=np.asarray(state.farm.residency),
        wake_count=np.asarray(state.farm.wake_count),
        busy_core_seconds=float(np.asarray(
            state.farm.busy_core_seconds).sum()),
        utilization=float(np.asarray(state.farm.busy_core_seconds).sum()
                          / max(N * C * t, 1e-12)),
        dropped=int(state.farm.dropped),
        latencies=lat,
        telemetry=(telemetry_mod.summarize(state, cfg)
                   if cfg.telemetry.enabled else None),
        flows_dropped=int(state.flows.flows_dropped),
        **thermal_kw,
        **trace_kw,
    )


def simulate(cfg: SimConfig, arrivals, specs, topo=None, tau=None,
             pools=None, racks=None, profile: bool = False,
             mesh=None) -> SimResult:
    """Build the job table, run the engine to completion, summarize.

    tau   — scalar or (N,) delay-timer values (seconds; INF = never sleep)
    pools — (N,) 0/1 pool assignment (dual-timer low/high, WASP active/sleep)
    racks — (N,) rack ids for the thermal recirculation grouping (defaults
            to the topology's top-of-rack grouping, else i // rack_size)
    profile — rerun the (now warm) engine once more to split JIT compile
            time out of the wall clock (result.run_info.jit_compile_s)
    mesh  — run rack-sharded on this device mesh (core/shard_sim.py);
            ``cfg.partition.n_shards > 1`` with mesh=None builds one from
            the visible devices.  Results are bit-identical either way.
    """
    jt = jobs_mod.build_jobs(cfg, np.asarray(arrivals), specs)
    state, tc = engine.init_state(cfg, jt, topo, racks)
    if tau is not None:
        tau_arr = jnp.broadcast_to(jnp.asarray(tau, cfg.time_dtype),
                                   (cfg.n_servers,))
        state = dataclasses.replace(
            state, farm=dataclasses.replace(state.farm, srv_tau=tau_arr))
    if pools is not None:
        state = dataclasses.replace(
            state, farm=dataclasses.replace(
                state.farm,
                srv_pool=jnp.asarray(pools, jnp.int32)))

    sharded = mesh is not None or cfg.partition.sharded
    if sharded:
        from . import shard_sim
        if mesh is None:
            mesh = shard_sim.make_mesh(cfg.partition.n_shards,
                                       cfg.partition.axis)

        def runner():
            return shard_sim.run_sharded(state, cfg, tc, mesh)
    else:
        def runner():
            return engine.run(state, cfg, tc)
    t0 = time.perf_counter()
    final = jax.block_until_ready(runner())
    wall = time.perf_counter() - t0
    compile_s = float("nan")
    if profile:
        t1 = time.perf_counter()
        final = jax.block_until_ready(runner())
        warm = time.perf_counter() - t1
        compile_s = max(wall - warm, 0.0)
        wall = warm
    res = summarize(final, cfg)
    n_ev = int(final.events)
    if sharded:
        axis = cfg.partition.axis
        mesh_shape = tuple(int(mesh.shape[a]) for a in mesh.axis_names)
        mesh_axes = tuple(mesh.axis_names)
        devices, sharding = int(np.prod(mesh_shape)), f"P('{axis}',)"
    else:
        mesh_shape, mesh_axes = (), ()
        devices, sharding = 1, ""
    res.run_info = RunInfo(
        wall_s=wall, steps=int(final.steps), events=n_ev,
        events_per_s=n_ev / max(wall, 1e-12),
        backend=jax.default_backend(), config=_config_dict(cfg),
        jit_compile_s=compile_s,
        devices=devices, mesh_shape=mesh_shape, mesh_axes=mesh_axes,
        sharding=sharding, config_digest=config_digest(cfg))
    return res

"""Host-side decoding/export of the device trace ring (core/trace.py).

Four consumers of one record stream:

  * :func:`decode` — ring buffer -> chronological numpy event array.
  * :func:`lifecycle_spans` — per-task queued->running->finish spans on
    server tracks (using the ``JobTable.start_at`` stamp).
  * :func:`to_chrome_trace` — Chrome trace event format JSON, loadable in
    Perfetto / chrome://tracing: rows are servers grouped into rack
    processes, task executions are duration events, wakeups/crossings/
    ctrl ticks/deferral releases are instants, and queue depth / farm
    power counter tracks come from the telemetry windows.
  * :func:`critical_path` — which task chain bounded each job's latency,
    split into queueing vs service vs flow time.

Plus the debugging workhorse :func:`diff_traces`: the engine emits all
same-time events in one masked pass while the heapq oracle interleaves
them, and engine times are f32 against the oracle's f64 — so both streams
are put in a canonical order (time-clustered, then by kind/tid/server)
and compared with a time tolerance, reporting the FIRST diverging event
instead of a final-state pytree mismatch.
"""
from __future__ import annotations

import json

import numpy as np

from .types import INF, SimConfig, TraceKind

__all__ = ["EVENT_DTYPE", "decode", "as_events", "diff_traces",
           "lifecycle_spans", "critical_path", "to_chrome_trace",
           "save_chrome_trace"]

EVENT_DTYPE = np.dtype([("time", np.float64), ("kind", np.int32),
                        ("server", np.int32), ("tid", np.int32),
                        ("aux", np.float32)])


def decode(trace, cfg: SimConfig):
    """TraceState -> (events (n,) EVENT_DTYPE chronological, n_dropped).

    The ring holds the most recent min(ptr, capacity) records; wrap-around
    discards the oldest (counted in ``dropped``)."""
    cap = cfg.trace.capacity
    ptr = int(trace.ptr)
    n = min(ptr, cap)
    idx = (ptr - n + np.arange(n)) % cap
    buf = np.asarray(trace.buf, np.float64)[idx]   # rows [kind, time,
    ev = np.empty((n,), EVENT_DTYPE)               #  server, tid, aux]
    ev["kind"] = buf[:, 0].astype(np.int32)
    ev["time"] = buf[:, 1]
    ev["server"] = buf[:, 2].astype(np.int32)
    ev["tid"] = buf[:, 3].astype(np.int32)
    ev["aux"] = buf[:, 4].astype(np.float32)
    return ev, int(trace.dropped)


def as_events(records) -> np.ndarray:
    """List of (time, kind, server, tid, aux) tuples (the oracle's
    ``trace`` list) -> EVENT_DTYPE array."""
    ev = np.empty((len(records),), EVENT_DTYPE)
    for i, (t, k, s, tid, aux) in enumerate(records):
        ev[i] = (t, k, s, tid, aux)
    return ev


# ==========================================================================
# trace diffing
# ==========================================================================

def _canonical(ev: np.ndarray, tol: float) -> np.ndarray:
    """Stable canonical order: cluster events whose times are within
    ``tol`` of their neighbors, then sort each cluster by (kind, tid,
    server).  Within-instant emission order (one masked engine pass vs
    the oracle's event-by-event pops) stops mattering; genuinely distinct
    times keep their order."""
    if len(ev) == 0:
        return ev
    ev = ev[np.lexsort((ev["server"], ev["tid"], ev["kind"], ev["time"]))]
    new_cluster = np.empty(len(ev), bool)
    new_cluster[0] = True
    new_cluster[1:] = np.diff(ev["time"]) > tol
    cid = np.cumsum(new_cluster)
    return ev[np.lexsort((ev["server"], ev["tid"], ev["kind"], cid))]


def _fmt(e) -> str:
    k = int(e["kind"])
    name = TraceKind.NAMES[k] if 0 <= k < TraceKind.NUM else f"?{k}"
    return (f"kind={name} time={float(e['time']):.9g} "
            f"server={int(e['server'])} tid={int(e['tid'])} "
            f"aux={float(e['aux']):.6g}")


def diff_traces(a, b, time_tol: float = 1e-4, check_tid: bool = True,
                check_aux: bool = False, names=("engine", "oracle")):
    """Compare two event streams; return None when they match, else a
    human-readable message locating the FIRST divergence.

    ``a``/``b`` are EVENT_DTYPE arrays (from :func:`decode` /
    :func:`as_events`).  Events match when kind and server agree exactly,
    times agree within ``time_tol`` (engine f32 vs oracle f64), and —
    optionally — tid/aux agree.  Streams are canonicalized first (see
    :func:`_canonical`) so same-instant emission order is immaterial.
    """
    a = _canonical(np.asarray(a, EVENT_DTYPE), time_tol)
    b = _canonical(np.asarray(b, EVENT_DTYPE), time_tol)
    n = min(len(a), len(b))
    for i in range(n):
        ea, eb = a[i], b[i]
        bad = (int(ea["kind"]) != int(eb["kind"])
               or int(ea["server"]) != int(eb["server"])
               or abs(float(ea["time"]) - float(eb["time"])) > time_tol)
        if not bad and check_tid:
            bad = int(ea["tid"]) != int(eb["tid"])
        if not bad and check_aux:
            bad = not np.isclose(ea["aux"], eb["aux"], rtol=1e-3,
                                 atol=1e-5)
        if bad:
            return (f"first divergence: event #{i}: "
                    f"{names[0]} ({_fmt(ea)}) vs {names[1]} ({_fmt(eb)})")
    if len(a) != len(b):
        longer, which = (a, names[0]) if len(a) > len(b) else (b, names[1])
        return (f"first divergence: event #{n}: {which} has "
                f"{abs(len(a) - len(b))} extra event(s), starting with "
                f"({_fmt(longer[n])})")
    return None


# ==========================================================================
# lifecycle spans + critical path
# ==========================================================================

def _task_timing(events: np.ndarray, state, cfg: SimConfig):
    """Per-task (ready, start, finish, binding-pred, flow-wait) from the
    final JobTable plus the trace's ADMIT/FLOW_FINISH events.

    ``ready`` is when the task could first run: its job's admission for
    roots, the latest dependency resolution (parent finish, or flow
    delivery for network edges) otherwise.  ``pred``/``flow_wait`` record
    WHICH edge bound that maximum and how much of it was flow time — the
    critical-path links."""
    jobs = state.jobs
    T = cfg.tasks_per_job
    start = np.asarray(jobs.start_at, np.float64)
    finish = np.asarray(jobs.finish, np.float64)
    valid = np.asarray(jobs.valid)
    server = np.asarray(jobs.server)
    children = np.asarray(jobs.children)
    eb = np.asarray(jobs.edge_bytes)
    JT = start.shape[0]

    admit = {}
    for e in events[events["kind"] == TraceKind.ADMIT]:
        admit[int(e["tid"])] = float(e["time"])
    flow_at = {}                     # child tid -> latest flow delivery
    for e in events[events["kind"] == TraceKind.FLOW_FINISH]:
        c = int(e["tid"])
        flow_at[c] = max(flow_at.get(c, -np.inf), float(e["time"]))

    ready = np.full(JT, np.nan)
    pred = np.full(JT, -1, np.int64)
    flow_wait = np.zeros(JT)
    arrival = np.asarray(jobs.arrival, np.float64)
    # roots = tasks no edge points at (final dep_count is 0 for every
    # resolved task, so it cannot distinguish roots)
    has_parent = np.zeros(JT, bool)
    for p in range(JT):
        if valid[p]:
            for c in children[p]:
                if c >= 0:
                    has_parent[c] = True
    is_root = ~has_parent
    # roots: admission time (fall back to arrival when the ADMIT event
    # was wrapped out of the ring)
    for t in range(JT):
        if valid[t]:
            j = t // T
            ready[t] = admit.get(j, arrival[j])
    for p in range(JT):
        if not valid[p] or finish[p] >= INF / 2:
            continue
        for k in range(children.shape[1]):
            c = int(children[p, k])
            if c < 0:
                continue
            is_flow = (cfg.has_network and eb[p, k] > 0
                       and server[p] != server[c])
            t_edge = flow_at.get(c, finish[p]) if is_flow else finish[p]
            if np.isnan(ready[c]) or t_edge > ready[c] \
                    or (pred[c] < 0 and not is_root[c]):
                ready[c] = t_edge
                pred[c] = p
                flow_wait[c] = max(t_edge - finish[p], 0.0) if is_flow \
                    else 0.0
    return ready, start, finish, pred, flow_wait


def lifecycle_spans(events: np.ndarray, state, cfg: SimConfig):
    """Per-task lifecycle spans: queued [ready, start) then running
    [start, finish) on the task's server track.  Tasks that never started
    (dropped / unfinished run) are skipped."""
    ready, start, finish, _, _ = _task_timing(events, state, cfg)
    valid = np.asarray(state.jobs.valid)
    server = np.asarray(state.jobs.server)
    T = cfg.tasks_per_job
    spans = []
    for t in range(len(start)):
        if not valid[t] or start[t] >= INF / 2:
            continue
        end = finish[t] if finish[t] < INF / 2 else start[t]
        spans.append({
            "tid": t, "job": t // T, "server": int(server[t]),
            "queued": (float(ready[t]), float(start[t])),
            "running": (float(start[t]), float(end)),
        })
    return spans


def critical_path(events: np.ndarray, state, cfg: SimConfig):
    """Walk each finished job's binding dependency chain backwards from
    its last-finishing task, splitting the job latency into queueing
    (ready -> start), service (start -> finish), and flow (network
    delivery) time along the path."""
    ready, start, finish, pred, flow_wait = _task_timing(events, state,
                                                         cfg)
    jobs = state.jobs
    T = cfg.tasks_per_job
    valid = np.asarray(jobs.valid).reshape(-1, T)
    job_finish = np.asarray(jobs.job_finish, np.float64)
    arrival = np.asarray(jobs.arrival, np.float64)
    out = []
    for j in range(len(job_finish)):
        if job_finish[j] >= INF / 2:
            continue
        tids = [j * T + k for k in range(T) if valid[j, k]]
        t = max(tids, key=lambda i: (finish[i] if finish[i] < INF / 2
                                     else -np.inf))
        path, queueing, service, flow = [], 0.0, 0.0, 0.0
        while t >= 0:
            path.append(t)
            f = finish[t] if finish[t] < INF / 2 else start[t]
            if start[t] < INF / 2:
                service += f - start[t]
                queueing += max(start[t] - ready[t], 0.0)
            flow += flow_wait[t]
            t = int(pred[t])
        path.reverse()
        out.append({
            "job": j, "latency": float(job_finish[j] - arrival[j]),
            "path": path, "queueing": queueing, "service": service,
            "flow": flow,
        })
    return out


# ==========================================================================
# Chrome trace event format (Perfetto / chrome://tracing)
# ==========================================================================

_US = 1.0e6                           # trace timestamps are microseconds

_INSTANT_KINDS = (TraceKind.WAKEUP, TraceKind.SLEEP, TraceKind.RELEASE,
                  TraceKind.DROP, TraceKind.THROTTLE_CROSSING,
                  TraceKind.CTRL_TICK, TraceKind.FLOW_SPAWN,
                  TraceKind.FLOW_FINISH)


def to_chrome_trace(events: np.ndarray, cfg: SimConfig, state=None,
                    racks=None, n_dropped: int = 0) -> dict:
    """Event array -> Chrome trace event format dict (``json.dump`` it —
    or use :func:`save_chrome_trace` — and load in ui.perfetto.dev or
    chrome://tracing).

    Rows are servers (thread tracks) grouped into rack processes
    (``racks`` (N,) overrides the default ``i // thermal.rack_size``
    grouping); task executions become duration ("X") events via the
    START records + the final state's finish stamps, the remaining kinds
    become instant ("i") events, and — when ``state`` carries enabled
    telemetry — queue-depth and farm-power counter ("C") tracks are
    reconstructed from the windowed series.
    """
    N = cfg.n_servers
    if racks is None:
        rack_of = np.arange(N) // max(cfg.thermal.rack_size, 1)
    else:
        rack_of = np.asarray(racks)

    def pid_tid(srv):
        if srv < 0:
            return {"pid": -1, "tid": 0}        # farm-level track
        return {"pid": int(rack_of[srv]), "tid": int(srv)}

    out = [{"name": "process_name", "ph": "M", "pid": -1,
            "args": {"name": "farm"}}]
    for r in sorted(set(rack_of.tolist())):
        out.append({"name": "process_name", "ph": "M", "pid": int(r),
                    "args": {"name": f"rack {r}"}})
    for s in range(N):
        out.append({"name": "thread_name", "ph": "M",
                    "pid": int(rack_of[s]), "tid": s,
                    "args": {"name": f"server {s}"}})

    # task executions: START records paired with the finish stamps
    finish = None
    if state is not None:
        finish = np.asarray(state.jobs.finish, np.float64)
    for e in events[events["kind"] == TraceKind.START]:
        t0 = float(e["time"])
        tid = int(e["tid"])
        if finish is not None and tid < len(finish) \
                and finish[tid] < INF / 2:
            dur = max(finish[tid] - t0, 0.0)
        else:
            dur = max(float(e["aux"]), 0.0)     # stamped duration
        out.append({"name": f"task {tid}", "cat": "task", "ph": "X",
                    "ts": t0 * _US, "dur": dur * _US,
                    **pid_tid(int(e["server"])),
                    "args": {"job": tid // cfg.tasks_per_job,
                             "task": tid}})

    for e in events[np.isin(events["kind"], _INSTANT_KINDS)]:
        k = int(e["kind"])
        srv = int(e["server"])
        out.append({"name": TraceKind.NAMES[k], "cat": "event",
                    "ph": "i", "ts": float(e["time"]) * _US,
                    "s": "t" if srv >= 0 else "g", **pid_tid(srv),
                    "args": {"tid": int(e["tid"]),
                             "aux": float(e["aux"])}})

    # counter tracks from the windowed telemetry
    if state is not None and cfg.telemetry.enabled:
        from . import telemetry as telem_mod
        win = np.asarray(state.telem.win, np.float64)
        occ = win[:, telem_mod.WIN_OCC]
        tctr = (np.arange(cfg.telemetry.n_windows) + 0.5) \
            * cfg.telemetry.window_dt
        for w in np.nonzero(occ > 0)[0]:
            ts = tctr[w] * _US
            out.append({"name": "queue depth", "ph": "C", "pid": -1,
                        "ts": ts, "args": {"tasks": float(
                            win[w, telem_mod.WIN_QDEPTH] / occ[w])}})
            out.append({"name": "farm power", "ph": "C", "pid": -1,
                        "ts": ts, "args": {"watts": float(
                            win[w, telem_mod.WIN_SRV_POWER] / occ[w])}})

    return {"traceEvents": out, "displayTimeUnit": "ms",
            "otherData": {"n_servers": N, "n_events": int(len(events)),
                          "trace_dropped": int(n_dropped)}}


def save_chrome_trace(path: str, events: np.ndarray, cfg: SimConfig,
                      state=None, racks=None, n_dropped: int = 0) -> dict:
    doc = to_chrome_trace(events, cfg, state, racks, n_dropped)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc

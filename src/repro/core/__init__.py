"""HolDCSim core: the paper's contribution, vectorized for TPU.

Modules: types (pytree state + config), engine (dense min-reduction DES),
server/power/network (hardware models), thermal (RC temperatures, CRAC
cooling, carbon/cost), topology (fat-tree / flattened butterfly / BCube /
CamCube / star), jobs (task DAGs), workload (Poisson / MMPP / trace),
scheduler (global policies + case-study controllers), farm (simulate
entry), montecarlo (replica-parallel sweeps).
"""
from . import (engine, farm, jobs, montecarlo, network, power, scheduler,
               server, thermal, topology, types, workload)  # noqa: F401

"""Core pytree/state types for the HolDCSim-JAX engine.

Design notes
------------
The original HolDCSim is an object-oriented, priority-queue event simulator.
The TPU adaptation (DESIGN.md §3) replaces the heap with dense fixed-shape
state arrays; every "event source" exposes a vector of candidate next-event
times and the engine advances to the global minimum.  All types here are
either *static* configuration (frozen dataclasses hashable for jit) or
*dynamic* state (registered pytree dataclasses of jnp arrays).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

# A "practically infinite" simulation time.  Using a finite sentinel (rather
# than jnp.inf) keeps min-reductions well-defined under f32 and survives
# subtraction without producing NaNs.
INF = 1.0e30

# --------------------------------------------------------------------------
# enums (plain ints so they can live inside jnp arrays)
# --------------------------------------------------------------------------


class SrvState:
    """Hierarchical ACPI-style server power states (paper §III-A)."""

    ACTIVE = 0        # S0, at least one core in C0
    IDLE = 1          # S0, all cores idle (C1)
    PKG_C6 = 2        # package C6: cores+uncore power-gated, fast wake (<1ms)
    S3 = 3            # suspend-to-RAM, slow wake
    OFF = 4           # G2 soft-off
    WAKING = 5        # transitioning to ACTIVE
    NUM = 6


class CoreState:
    C0 = 0            # executing
    C1 = 1            # halt, clock-gated
    C6 = 2            # core power-gated
    NUM = 3


class TaskStatus:
    BLOCKED = 0       # waiting on DAG parents
    READY = 1         # deps satisfied, not yet enqueued at its server
    QUEUED = 2        # sitting in a local/global queue
    RUNNING = 3       # on a core
    COMM = 4          # finished compute, results in flight to children
    DONE = 5
    INVALID = 6       # padding
    NUM = 7


class PortState:
    ACTIVE = 0
    LPI = 1           # IEEE 802.3az Low Power Idle
    OFF = 2
    NUM = 3


class LinecardState:
    ACTIVE = 0
    SLEEP = 1
    OFF = 2
    NUM = 3


class SchedPolicy:
    ROUND_ROBIN = 0
    LOAD_BALANCE = 1       # least queue+running occupancy
    NETWORK_AWARE = 2      # least network wake cost (case study D)
    PROVISIONED = 3        # threshold-driven active-set (case study A)
    WASP_POOLS = 4         # two-pool workload adaptive (case study C)
    THERMAL_AWARE = 5      # coolest eligible server (thermal subsystem)
    CARBON_AWARE = 6       # LOAD_BALANCE placement + deferrable jobs held
                           # while the carbon/price signal is above
                           # ThermalConfig.defer_threshold (thermal ctrl
                           # plane); released at the solved sinusoid
                           # down-crossing or at their deadline


class SleepPolicy:
    """Local (per-server) power controller."""

    ALWAYS_ON = 0          # Active-Idle baseline
    SINGLE_TIMER = 1       # idle --tau--> deep state
    DUAL_TIMER = 2         # per-server tau (two pools with low/high tau)
    WASP = 3               # shallow PkgC6 in active pool; PkgC6->S3 in sleep pool


class TraceKind:
    """Event kinds recorded by the device-side flight recorder
    (core/trace.py).  Values are stable — they appear in exported traces
    and in the oracle mirror (tests/oracle.py)."""

    ARRIVAL = 0            # job's arrival processed (tid = job id)
    ADMIT = 1              # job admitted/placed (tid = job id, server =
                           # first task's server, aux = queue depth there)
    RELEASE = 2            # carbon-deferred job released (aux = seconds held)
    START = 3              # task started on a core (aux = stretched duration)
    FINISH = 4             # task finished compute
    JOB_FINISH = 5         # last task of a job done (tid = job id,
                           # aux = job latency)
    WAKEUP = 6             # server wake transition completed
    SLEEP = 7              # server entered a sleep state (aux = SrvState)
    DROP = 8               # task dropped on a full queue
    FLOW_SPAWN = 9         # network flow spawned (server = src,
                           # tid = child task, aux = bytes)
    FLOW_FINISH = 10       # network flow delivered (server = dst,
                           # tid = child task)
    THROTTLE_CROSSING = 11  # thermal throttle engaged/released
                            # (aux = temperature °C)
    CTRL_TICK = 12         # CRAC setpoint controller tick
    NUM = 13

    NAMES = ("arrival", "admit", "release", "start", "finish", "job_finish",
             "wakeup", "sleep", "drop", "flow_spawn", "flow_finish",
             "throttle_crossing", "ctrl_tick")


# --------------------------------------------------------------------------
# pytree dataclass helper
# --------------------------------------------------------------------------

def pytree_dataclass(cls):
    """A dataclass whose fields are all pytree leaves (jnp arrays)."""
    cls = dataclasses.dataclass(cls)
    fields = [f.name for f in dataclasses.fields(cls)]
    jax.tree_util.register_dataclass(cls, data_fields=fields, meta_fields=[])
    return cls


def replace(obj, **kw):
    return dataclasses.replace(obj, **kw)


# --------------------------------------------------------------------------
# static configuration
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ServerPowerProfile:
    """Per-server power (Watts) by state; loosely calibrated to a 10-core
    Xeon E5-2680 class machine (paper §V-A) and the ACPI hierarchy."""

    p_core_active: float = 13.0     # C0, per core
    p_core_idle: float = 2.0        # C1, per core
    p_core_c6: float = 0.3          # core C6, per core
    p_base: float = 65.0            # uncore+platform when in S0
    p_pkg_c6: float = 15.0          # package C6 (uncore gated, DRAM refresh)
    p_s3: float = 9.0               # suspend to RAM
    p_off: float = 0.0
    p_wake: float = 145.0           # burst draw during wake transition
    # transition latencies (seconds)
    t_wake_pkg_c6: float = 1.0e-3   # <1ms per paper §IV-C
    t_wake_s3: float = 1.0          # seconds-scale resume
    t_wake_off: float = 30.0        # full boot
    t_core_c6_wake: float = 5.0e-5

    def active_power(self, busy_cores: int, total_cores: int) -> float:
        idle = total_cores - busy_cores
        return (self.p_base + busy_cores * self.p_core_active
                + idle * self.p_core_idle)


@dataclass(frozen=True)
class SwitchPowerProfile:
    """Cisco WS-C2960-24-S calibration from the paper's §V-B: measured base
    14.7 W plus 0.23 W per active port."""

    p_chassis: float = 14.7
    p_port_active: float = 0.23
    p_port_lpi: float = 0.023       # ~10% of active, 802.3az ballpark
    p_port_off: float = 0.0
    p_linecard_active: float = 0.0  # folded into chassis for small switches
    p_linecard_sleep: float = 0.0
    t_lpi_wake: float = 5.0e-6      # 802.3az refresh/wake ~ microseconds
    t_port_lpi_enter: float = 1.0e-3  # idle threshold before entering LPI
    t_switch_wake: float = 0.5      # waking a slept switch (case study D)


@dataclass(frozen=True)
class ThermalConfig:
    """Thermal / cooling / carbon-cost subsystem knobs (core/thermal.py).

    Per-server thermal RC model: ``T' = (P·r_th − (T − T_inlet)) / tau_th``.
    Power is piecewise constant between DES events, so the closed-form
    exponential update integrates the ODE with zero discretization error —
    the same trick as the exact energy accrual.  Rack-level recirculation
    couples a server's inlet to its rack's mean excess temperature (held
    piecewise constant per interval, recomputed at every event).

    All behavioral couplings are off by default: ``enabled=False`` adds
    nothing to the step, and ``t_throttle=INF`` disables throttling even
    when temperatures are tracked.
    """

    enabled: bool = False
    # RC parameters: steady state T = T_inlet + P·r_th
    r_th: float = 0.25          # °C per Watt of server power
    tau_th: float = 60.0        # thermal time constant (seconds)
    t_inlet: float = 22.0       # CRAC supply / cold-aisle setpoint (°C)
    # --- control plane -----------------------------------------------
    # per-rack CRAC supply setpoints: None = one uniform setpoint
    # (t_inlet, the static path — COP folds to a Python constant at trace
    # time); a scalar or length-R tuple makes the setpoints *state*
    # (ThermalState.t_set) and COP a per-rack quadratic evaluated
    # in-trace, so each rack's supply temperature carries its own
    # cooling-efficiency cost
    t_setpoint: object = None
    # diurnal ambient sinusoid added onto the supply/cold-aisle
    # temperature: amb(t) = ambient_swing·sin(2π(t+ambient_phase)/
    # ambient_period) °C.  The RC integration stays exact per interval —
    # the inlet is held piecewise constant (evaluated at interval start),
    # the same operator split as the rack recirculation — and the
    # throttle crossing solve honors the time-varying target.
    ambient_swing: float = 0.0
    ambient_period: float = 86400.0
    ambient_phase: float = 0.0
    # simple per-rack setpoint controller: every ctrl_period seconds (a
    # real event) each rack's setpoint steps DOWN by ctrl_step when its
    # hottest server exceeds ctrl_target, UP when it sits below
    # ctrl_target - ctrl_band (cheaper cooling via a better COP), clipped
    # into [ctrl_min, ctrl_max].  ctrl_period = 0 disables.
    ctrl_period: float = 0.0
    ctrl_target: float = 55.0
    ctrl_band: float = 2.0
    ctrl_step: float = 1.0
    ctrl_min: float = 12.0
    ctrl_max: float = 27.0
    # carbon-aware deferral (SchedPolicy.CARBON_AWARE): deferrable jobs
    # arriving while the defer_signal ("carbon" or "price") sits above
    # defer_threshold are held unadmitted and released at the solved
    # sinusoid down-crossing or at their deadline, whichever is earlier
    # (a deadline at/before now — or no finite release candidate at all —
    # admits immediately, so deferral can never deadlock).  INF = never
    # defer.
    defer_threshold: float = INF
    defer_signal: str = "carbon"
    # rack recirculation: inlet_i = t_inlet + recirc·rack_mean(T − t_inlet)
    recirc: float = 0.2
    rack_size: int = 8          # servers per rack (rack id = i // rack_size
                                # unless a topology grouping is supplied)
    # temperature-coupled throttling with hysteresis: servers at/above
    # t_throttle run at core_freq·throttle_freq (in-flight work stretches)
    # until they cool to t_release; active-core power scales by
    # throttle_power_scale while throttled (linear-DVFS approximation)
    t_throttle: float = INF     # °C; INF = never throttle
    t_release: float = INF      # effective release = min(t_release, t_throttle)
    throttle_freq: float = 0.5
    throttle_power_scale: float = 0.5
    # crossing-solve guard band (°C): the per-step analytic crossing solve
    # (thermal.next_crossing — power eval + inlet recirculation + logs) is
    # cond-gated on "any server within this band of its pending threshold"
    # (t_throttle from below when unthrottled, t_release from above when
    # throttled).  Servers outside the band latch at the next ordinary
    # event instead of at the exact crossing instant, which only matters
    # when a temperature jumps the whole band within one event interval.
    # INF = solve every step (exact crossings regardless of distance).
    crossing_guard: float = 8.0
    # CRAC efficiency: COP(T_sup) = cop_a·T² + cop_b·T + cop_c evaluated at
    # the (static) supply setpoint; cooling power = P_IT / COP
    cop_a: float = 0.0068
    cop_b: float = 0.0008
    cop_c: float = 0.458
    # grid carbon intensity (gCO2/kWh) and electricity price ($/kWh):
    # diurnal sinusoids base·(1 + swing·sin(2π(t+phase)/period)) integrated
    # in closed form over each event interval
    carbon_base: float = 350.0
    carbon_swing: float = 0.4
    carbon_period: float = 86400.0
    carbon_phase: float = 0.0
    price_base: float = 0.12
    price_swing: float = 0.5
    price_period: float = 86400.0
    price_phase: float = 0.0
    # THERMAL_AWARE placement: score = load + (T − t_inlet)·weight
    sched_temp_weight: float = 100.0

    @property
    def cop(self) -> float:
        t = self.t_inlet
        return self.cop_a * t * t + self.cop_b * t + self.cop_c

    @property
    def throttling(self) -> bool:
        return self.enabled and self.t_throttle < INF / 2

    @property
    def has_ctrl(self) -> bool:
        """Setpoint controller armed (control-period ticks are events)."""
        return self.enabled and self.ctrl_period > 0.0

    @property
    def per_rack(self) -> bool:
        """Setpoints live in ThermalState (in-trace per-rack COP) instead
        of folding to the static t_inlet constant."""
        return self.enabled and (self.t_setpoint is not None
                                 or self.has_ctrl)

    @property
    def ambient_on(self) -> bool:
        return self.enabled and self.ambient_swing != 0.0

    @property
    def deferral(self) -> bool:
        """CARBON_AWARE deferral armed (a finite signal threshold)."""
        return self.enabled and self.defer_threshold < INF / 2


@dataclass(frozen=True)
class TelemetryConfig:
    """Device-side telemetry (histograms / windowed series / QoS) knobs.

    The simulator accumulates distributions *inside* the jitted event loop
    (core/telemetry.py) so replica sweeps never haul per-job tables off
    device.  All fields are static (hashable) — they size the Telemetry
    pytree arrays.
    """

    enabled: bool = True
    # log-spaced latency histogram: n_bins bins over [lat_lo, lat_hi) sec
    n_bins: int = 64
    lat_lo: float = 1.0e-5
    lat_hi: float = 1.0e3
    # windowed time series: n_windows buckets of window_dt seconds (times
    # past the last window clamp into it)
    n_windows: int = 256
    window_dt: float = 0.1
    # QoS: job latency above this counts as a tail-latency violation;
    # per-job deadlines come from JobTable.sla
    tail_thresh: float = 1.0
    # route the hot accumulation through the fused Pallas kernel
    # (kernels/telemetry_bin.py); off-TPU it falls back to interpret mode
    use_kernel: bool = False
    # compact the "new finishes" set into a batch of this size before
    # histogram binning when few jobs/tasks finished this step (the jnp
    # path otherwise pays dense (J·T)-wide binning per finishing step);
    # 0 disables compaction, and steps with more finishes than the batch
    # fall back to the dense path
    compact: int = 32


@dataclass(frozen=True)
class TraceConfig:
    """Device-side event flight recorder (core/trace.py) knobs.

    When enabled, every retired event appends fixed-width records to a
    ring buffer living in ``SimState.trace`` — written from both the
    cheap macro-step core and the full step, so the recorded stream is
    identical for every ``events_per_step``.  When disabled the state
    shrinks to (1,)-sized placeholders and the emission code is
    statically absent from the trace (the thermal-off trick): dynamics
    are bit-identical and the step costs nothing extra.
    """

    enabled: bool = False
    # ring capacity in records (~17 bytes/record on device).  When the
    # run emits more, the oldest records are overwritten and counted in
    # TraceState.dropped — decode/export still see the most recent
    # `capacity` events in order.
    capacity: int = 65536


@dataclass(frozen=True)
class PartitionConfig:
    """Rack-major sharding of the per-server state axes (core/shard_sim.py).

    The farm is stored rack-major: server ``i`` sits in rack
    ``i // thermal.rack_size``, so a flat (N,) server axis IS the flattened
    (R, S) rack-major layout and a contiguous block partition along it cuts
    exactly on rack boundaries.  ``n_shards`` declares how many equal rack
    groups the per-server (and per-rack) axes split into; each shard lands
    on one device of the "racks" mesh axis, making recirculation row means,
    CRAC setpoints, and per-rack COP shard-local by construction.

    ``n_shards = 1`` (default) is the unsharded engine, bit-identical to a
    mesh-free run; the sharded step gathers the rack shards once per
    macro-step (the thin collective phase), runs the event core
    collective-free, and re-slices — so any ``n_shards`` produces the
    same trajectory bit-for-bit.
    """

    n_shards: int = 1
    axis: str = "racks"            # mesh axis name the rack groups map to

    @property
    def sharded(self) -> bool:
        return self.n_shards > 1


@dataclass(frozen=True)
class SimConfig:
    """Static shape/topology/policy configuration (hashable; jit-static)."""

    n_servers: int = 50
    n_cores: int = 4
    local_q: int = 64               # per-server ring-buffer capacity
    global_q: int = 256
    max_jobs: int = 2048
    tasks_per_job: int = 1          # T (padded DAG width)
    max_children: int = 4           # Dmax fanout per task
    max_flows: int = 256            # concurrent network flows
    max_events: int = 50_000        # scan iteration budget
    ready_per_step: int = 8         # bounded ready->enqueue work per step
    arrivals_per_step: int = 8      # same-timestamp jobs admitted per step
                                    # (one shared scheduler snapshot — open
                                    # loop bursts no longer serialize)
    # event-coalesced macro-stepping: one jitted sim_step retires up to
    # this many successive event TIMES.  The first events_per_step-1 go
    # through the cheap advance/completion core (an inner bounded
    # while_loop) whenever gating shows the pending event needs no
    # expensive pass (no flow completion/spawn, no throttle crossing);
    # the final event always runs the full step.  1 = seed one-event
    # behavior.  Final states are identical for any value (the gating is
    # conservative); only the step decomposition changes.
    events_per_step: int = 8
    # hot-loop implementation: dense masked batch updates for drain /
    # arrival-assignment / flow-spawn (True) vs the seed scalar fori_loops
    # (False, kept as the semantic reference — tests compare both)
    use_vectorized_hot_loop: bool = True
    # route the interval advance (energy accrual + completion free + farm
    # next-event candidate) through the fused Pallas kernel
    # (kernels/dcsim_step.py); off-TPU it falls back to interpret mode,
    # mirroring the telemetry backend switch
    use_kernel: bool = False
    # policies
    sched_policy: int = SchedPolicy.LOAD_BALANCE
    sleep_policy: int = SleepPolicy.ALWAYS_ON
    sleep_state: int = SrvState.S3  # which state the timer drops into
    use_global_queue: bool = False
    # provisioning thresholds (case A): load per enabled server
    prov_lo: float = 0.3
    prov_hi: float = 0.9
    # WASP thresholds (case C): pending jobs per server
    wasp_t_wakeup: float = 1.5
    wasp_t_sleep: float = 0.5
    # frequency scaling (P-state): service time scales by 1/freq
    core_freq: float = 1.0
    # network
    has_network: bool = False
    flow_mtu: float = 1500.0
    comm_model: int = 0             # 0=flow(fluid), 1=packet(store&forward)
    hop_latency: float = 5.0e-6     # per-hop switching latency (packet model)
    # power profiles
    server_power: ServerPowerProfile = field(default_factory=ServerPowerProfile)
    switch_power: SwitchPowerProfile = field(default_factory=SwitchPowerProfile)
    # device-side telemetry subsystem
    telemetry: TelemetryConfig = field(default_factory=TelemetryConfig)
    # thermal / cooling / carbon-cost subsystem
    thermal: ThermalConfig = field(default_factory=ThermalConfig)
    # device-side event flight recorder
    trace: TraceConfig = field(default_factory=TraceConfig)
    # rack-major device sharding of the per-server state axes
    partition: PartitionConfig = field(default_factory=PartitionConfig)
    # farm padding (see farm.pad_to_racks): servers at index >= n_present
    # are inert filler rows that round the farm up to whole racks (and to
    # a shardable rack-group multiple).  0 means "all n_servers real".
    # Padded rows boot OFF/disabled: they draw zero power, never receive
    # work, and are masked out of the telemetry temperature/state columns.
    n_present: int = 0
    time_dtype: Any = jnp.float32

    @property
    def n_tasks(self) -> int:
        return self.max_jobs * self.tasks_per_job

    @property
    def present(self) -> int:
        """Number of real (schedulable) servers; <= n_servers."""
        return self.n_present if self.n_present else self.n_servers

    @property
    def has_padding(self) -> bool:
        return 0 < self.n_present < self.n_servers


# --------------------------------------------------------------------------
# dynamic state pytrees
# --------------------------------------------------------------------------

@pytree_dataclass
class ServerFarm:
    # cores
    core_busy_until: jnp.ndarray    # (N, C) time current task completes, INF idle
    # server-level power
    srv_state: jnp.ndarray          # (N,) SrvState
    srv_wake_at: jnp.ndarray        # (N,) wake completion time (INF otherwise)
    srv_idle_since: jnp.ndarray     # (N,) time the server last went fully idle
    srv_tau: jnp.ndarray            # (N,) delay-timer value (INF = never sleep)
    srv_pool: jnp.ndarray           # (N,) 0 active pool / 1 sleep pool (WASP)
    srv_enabled: jnp.ndarray        # (N,) bool: receives new work (case A)
    # task-major local queues: queue membership lives on the TASKS
    # (JobTable.status == QUEUED + JobTable.enqueue_seq for FIFO order);
    # the farm only carries the per-server occupancy counter and the
    # global enqueue sequence counter.  The seed's (N, Q) ring-buffer —
    # 5 MB of per-step state at 20K servers, plus a core->task gather and
    # slot scatters on every start — is gone.
    q_len: jnp.ndarray              # (N,) queued-task count per server
    q_seq: jnp.ndarray              # () global FIFO enqueue counter
    # stats
    energy: jnp.ndarray             # (N,) joules
    residency: jnp.ndarray          # (N, SrvState.NUM) seconds per state
    busy_core_seconds: jnp.ndarray  # (N,)
    wake_count: jnp.ndarray         # (N,) number of sleep->active transitions
    dropped: jnp.ndarray            # () tasks dropped on full queues


@pytree_dataclass
class JobTable:
    arrival: jnp.ndarray            # (J,) job arrival times (INF padded)
    arr_ptr: jnp.ndarray            # () next arrival index
    service: jnp.ndarray            # (J*T,) task service time @ freq 1.0
    valid: jnp.ndarray              # (J*T,) bool
    dep_count: jnp.ndarray          # (J*T,) unfinished parents
    children: jnp.ndarray           # (J*T, Dmax) flat child ids (-1 pad)
    edge_bytes: jnp.ndarray         # (J*T, Dmax) result size to child
    status: jnp.ndarray             # (J*T,) TaskStatus
    edge_sent: jnp.ndarray          # (J*T, Dmax) network edge already handled
    server: jnp.ndarray             # (J*T,) assigned server (-1 unassigned)
    enqueue_seq: jnp.ndarray        # (J*T,) global FIFO stamp set when the
                                    # task enters its server's queue (each
                                    # task enqueues at most once, so stamps
                                    # are unique and bounded by J*T)
    task_end: jnp.ndarray           # (J*T,) busy_until stamped at start (INF
                                    # otherwise) — lets completions resolve
                                    # elementwise in task space, no scatter
    start_at: jnp.ndarray           # (J*T,) time the task began running (INF
                                    # until started) — the lifecycle stamp
                                    # between enqueue and finish, used by
                                    # traceio span/critical-path decoding
    finish: jnp.ndarray             # (J*T,) task finish time
    job_finish: jnp.ndarray         # (J,) completion time (INF if not done)
    tasks_done: jnp.ndarray         # (J,) per-job finished-task count
    sla: jnp.ndarray                # (J,) latency deadline (INF = no SLA)
    deferrable: jnp.ndarray         # (J,) bool — may be carbon-deferred
    deadline: jnp.ndarray           # (J,) absolute latest ADMIT time for a
                                    # deferred job (INF = no deadline)
    admit_at: jnp.ndarray           # (J,) release time of a currently
                                    # deferred job (INF = not deferred); the
                                    # min is a next_event_time candidate


@pytree_dataclass
class FlowTable:
    src: jnp.ndarray                # (F,) source server
    dst: jnp.ndarray                # (F,) destination server
    rem: jnp.ndarray                # (F,) remaining bytes
    rate: jnp.ndarray               # (F,) current share (bytes/s)
    extra: jnp.ndarray              # (F,) fixed latency budget left (seconds)
    done_at: jnp.ndarray            # (F,) projected completion (INF inactive)
    child: jnp.ndarray              # (F,) task whose dep_count decrements
    active: jnp.ndarray             # (F,) bool
    flows_dropped: jnp.ndarray      # () spawns refused by a full table (the
                                    # edge drop-resolves: dep decremented
                                    # immediately instead of deadlocking)


@pytree_dataclass
class NetState:
    port_state: jnp.ndarray         # (W, P) PortState
    port_idle_since: jnp.ndarray    # (W, P)
    lc_state: jnp.ndarray           # (W, LC) LinecardState
    sw_awake: jnp.ndarray           # (W,) bool (case D switch sleeping)
    link_flows: jnp.ndarray         # (L,) active flow count per link
    sw_energy: jnp.ndarray          # (W,) joules
    port_residency: jnp.ndarray     # (W, P, PortState.NUM)


@pytree_dataclass
class SchedState:
    rr_ptr: jnp.ndarray             # () round-robin pointer
    n_enabled: jnp.ndarray          # () provisioning active-set size
    gq_tasks: jnp.ndarray           # (GQ,) global queue ring
    gq_head: jnp.ndarray            # ()
    gq_len: jnp.ndarray             # ()


@pytree_dataclass
class Telemetry:
    """Device-side streaming telemetry accumulated inside the event loop.

    ``win`` packs all windowed time series as time-weighted column sums
    (metric·dt scattered into the window containing the interval midpoint);
    dividing by the occupancy column recovers time-averaged values.  Column
    layout is ``core/telemetry.py`` (WIN_* constants).
    """

    job_hist: jnp.ndarray           # (B,) job-latency histogram (weights)
    task_hist: jnp.ndarray          # (B,) task-latency histogram
    win: jnp.ndarray                # (W, K) windowed time-weighted series
    sla_miss: jnp.ndarray           # () jobs finishing past their sla
    sla_total: jnp.ndarray          # () finished jobs with a finite sla
    tail_viol: jnp.ndarray          # () jobs with latency > tail_thresh
    win_overflow: jnp.ndarray       # () seconds of simulated time falling
                                    # past the n_windows·window_dt horizon
                                    # (clamped into the last window, whose
                                    # time-averages are then contaminated)


@pytree_dataclass
class ThermalState:
    """Thermal/carbon/cost state (core/thermal.py).  Sized (1,) minimal
    arrays when the subsystem is disabled, like Telemetry."""

    t_srv: jnp.ndarray              # (N,) server temperature (°C)
    throttled: jnp.ndarray          # (N,) bool — hysteresis latch
    rack_id: jnp.ndarray            # (N,) server -> rack map (constant)
    rack_onehot: jnp.ndarray        # (R, N) f32 membership (constant)
    rack_inv: jnp.ndarray           # (R,) 1/servers-per-rack (constant)
    t_set: jnp.ndarray              # (R,) per-rack CRAC supply setpoint
                                    # (°C; state — the setpoint controller
                                    # moves it on a control period)
    ctrl_next: jnp.ndarray          # () next setpoint-controller tick (a
                                    # next_event_time candidate; INF = off)
    t_peak: jnp.ndarray             # (N,) running max temperature
    throttle_seconds: jnp.ndarray   # (N,) time spent throttled
    cool_energy: jnp.ndarray        # () CRAC joules
    carbon_g: jnp.ndarray           # () grams CO2 (IT + cooling)
    cost: jnp.ndarray               # () electricity cost ($)
    defer_seconds: jnp.ndarray      # () summed job deferral time (carbon-
                                    # aware control plane)
    defer_count: jnp.ndarray        # () jobs released after a deferral
    grams_avoided: jnp.ndarray      # () first-order estimate of CO2 grams
                                    # avoided by deferral: Δintensity at
                                    # (defer, release) × marginal job energy


@pytree_dataclass
class TraceState:
    """Device-side flight-recorder ring buffer (core/trace.py).  Sized
    (1, 5) placeholder when tracing is disabled, like Telemetry.

    Records are packed into ONE (cap, 5) float buffer — columns
    [kind, time, server, tid, aux] — so the per-step flush is a single
    row scatter (XLA CPU scatter costs ~60ns per update ROW, so op
    count, not buffer size, is what the hot loop pays for).  The dtype
    is ``cfg.time_dtype`` promoted to at least f32: integer columns
    round-trip exactly below 2^24 (f32) / 2^53 (f64), far above any
    realistic id space."""

    buf: jnp.ndarray                # (cap, 5) [kind, time, server(-1 =
                                    # farm-level), tid(-1 = n/a), aux]
    ptr: jnp.ndarray                # () monotonic write pointer (total
                                    # events ever emitted; slot = ptr % cap)
    dropped: jnp.ndarray            # () records overwritten by wrap-around


@pytree_dataclass
class SimState:
    t: jnp.ndarray                  # () current simulation time
    farm: ServerFarm
    jobs: JobTable
    flows: FlowTable
    net: NetState
    sched: SchedState
    telem: Telemetry
    thermal: ThermalState
    trace: TraceState
    events: jnp.ndarray             # () processed event count
    steps: jnp.ndarray              # () jitted sim_step invocations
    done: jnp.ndarray               # () bool — all jobs finished


# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------

def init_farm(cfg: SimConfig) -> ServerFarm:
    N, C = cfg.n_servers, cfg.n_cores
    tdt = cfg.time_dtype
    # padded filler rows (index >= cfg.present) boot OFF and disabled: the
    # OFF power row is the literal 0.0, every next-event candidate is INF,
    # and no scheduling policy can pick a disabled server — so the rows
    # are power/event/scheduler inert without any per-step masking
    real = jnp.arange(N) < cfg.present
    return ServerFarm(
        core_busy_until=jnp.full((N, C), INF, tdt),
        srv_state=jnp.where(real, SrvState.IDLE,
                            SrvState.OFF).astype(jnp.int32),
        srv_wake_at=jnp.full((N,), INF, tdt),
        srv_idle_since=jnp.zeros((N,), tdt),
        srv_tau=jnp.full((N,), INF, tdt),
        srv_pool=jnp.zeros((N,), jnp.int32),
        srv_enabled=real,
        q_len=jnp.zeros((N,), jnp.int32),
        q_seq=jnp.zeros((), jnp.int32),
        energy=jnp.zeros((N,), jnp.float32),
        residency=jnp.zeros((N, SrvState.NUM), jnp.float32),
        busy_core_seconds=jnp.zeros((N,), jnp.float32),
        wake_count=jnp.zeros((N,), jnp.int32),
        dropped=jnp.zeros((), jnp.int32),
    )


def init_flows(cfg: SimConfig) -> FlowTable:
    F = cfg.max_flows
    tdt = cfg.time_dtype
    return FlowTable(
        src=jnp.full((F,), -1, jnp.int32),
        dst=jnp.full((F,), -1, jnp.int32),
        rem=jnp.zeros((F,), jnp.float32),
        rate=jnp.zeros((F,), jnp.float32),
        extra=jnp.zeros((F,), tdt),
        done_at=jnp.full((F,), INF, tdt),
        child=jnp.full((F,), -1, jnp.int32),
        active=jnp.zeros((F,), bool),
        flows_dropped=jnp.zeros((), jnp.int32),
    )


def init_net(n_switches: int, n_ports: int, n_links: int,
             n_linecards: int, cfg: SimConfig) -> NetState:
    W, P, L = max(n_switches, 1), max(n_ports, 1), max(n_links, 1)
    LC = max(n_linecards, 1)
    tdt = cfg.time_dtype
    return NetState(
        port_state=jnp.full((W, P), PortState.LPI, jnp.int32),
        port_idle_since=jnp.zeros((W, P), tdt),
        lc_state=jnp.full((W, LC), LinecardState.ACTIVE, jnp.int32),
        sw_awake=jnp.ones((W,), bool),
        link_flows=jnp.zeros((L,), jnp.int32),
        sw_energy=jnp.zeros((W,), jnp.float32),
        port_residency=jnp.zeros((W, P, PortState.NUM), jnp.float32),
    )


def init_sched(cfg: SimConfig) -> SchedState:
    return SchedState(
        rr_ptr=jnp.zeros((), jnp.int32),
        n_enabled=jnp.asarray(cfg.present, jnp.int32),
        gq_tasks=jnp.full((cfg.global_q,), -1, jnp.int32),
        gq_head=jnp.zeros((), jnp.int32),
        gq_len=jnp.zeros((), jnp.int32),
    )

"""Flow/packet communication model + switch state dynamics (paper §III-B).

Flow-based model: a flow's instantaneous rate is the min over its route links
of ``cap(l) / n_active_flows(l)`` (equal-share fluid approximation of the
paper's "multiple flows can share an unsaturated link").  Rates are
recomputed at every event, so completions are exact under piecewise-constant
sharing.

Packet model: adds store-and-forward serialization — a fixed extra latency of
``hops * hop_latency + (hops-1) * mtu/cap`` consumed before bytes drain.

Switch dynamics: ports enter LPI when their link has no flows (802.3az);
line cards sleep when all their ports are in LPI; whole switches doze when
traffic-idle (used by case study D's wake-cost-aware placement).  Waking an
LPI port / slept switch adds its wake latency to the flow's ``extra`` budget.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .types import (INF, FlowTable, LinecardState, NetState, PortState,
                    SimConfig, replace)

__all__ = ["TopoConsts", "topo_consts", "spawn_flow", "spawn_flows_many",
           "advance_flows", "recompute_rates", "complete_flows",
           "update_switch_states", "route_wake_cost"]


class TopoConsts:
    """Device-resident dense topology arrays (host-built once).  Registered
    as a pytree so it can be passed through jit boundaries."""

    _ARRAYS = ("routes", "route_len", "route_sw", "link_cap", "link_sw",
               "link_port")
    _META = ("n_switches", "n_ports", "n_links", "max_hops", "ports_per_lc",
             "n_linecards")

    def __init__(self, topo=None, **kw):
        if topo is not None:
            self.n_switches = topo.n_switches
            self.n_ports = topo.n_ports
            self.n_links = topo.n_links
            self.max_hops = topo.max_hops
            self.ports_per_lc = topo.ports_per_linecard
            self.n_linecards = topo.n_linecards
            self.routes = jnp.asarray(topo.routes)        # (N,N,H) link ids
            self.route_len = jnp.asarray(topo.route_len)  # (N,N)
            self.route_sw = jnp.asarray(topo.route_sw)    # (N,N,Hs) switches
            self.link_cap = jnp.asarray(topo.link_cap)    # (L,)
            # (L,2): switch index of each endpoint (-1 = server side)
            ls = np.where(topo.links >= topo.n_servers,
                          topo.links - topo.n_servers, -1)
            self.link_sw = jnp.asarray(ls, jnp.int32)
            self.link_port = jnp.asarray(topo.link_port)  # (L,2)
        else:
            for k, v in kw.items():
                setattr(self, k, v)

    def tree_flatten(self):
        return ([getattr(self, a) for a in self._ARRAYS],
                tuple(getattr(self, m) for m in self._META))

    @classmethod
    def tree_unflatten(cls, meta, arrays):
        kw = dict(zip(cls._ARRAYS, arrays))
        kw.update(dict(zip(cls._META, meta)))
        return cls(**kw)


jax.tree_util.register_pytree_node(
    TopoConsts, lambda tc: tc.tree_flatten(),
    lambda meta, arrays: TopoConsts.tree_unflatten(meta, arrays))


def topo_consts(topo) -> TopoConsts:
    return TopoConsts(topo)


def route_wake_cost(tc: TopoConsts, net: NetState, src, dst):
    """Case study D metric: number of *sleeping* switches that a flow
    src->dst would have to wake."""
    sws = tc.route_sw[src, dst]                           # (Hs,)
    valid = sws >= 0
    asleep = ~net.sw_awake[jnp.clip(sws, 0)]
    return jnp.sum(valid & asleep).astype(jnp.int32)


def spawn_flow(flows: FlowTable, net: NetState, tc: TopoConsts,
               cfg: SimConfig, src, dst, nbytes, child, now):
    """Allocate a free slot for one flow src->dst (scalar args).
    Returns (flows, net, ok)."""
    free = ~flows.active
    ok = free.any()
    slot = jnp.argmax(free)

    links = tc.routes[src, dst]                           # (H,)
    lmask = links >= 0
    lc = jnp.clip(links, 0)
    swp = cfg.switch_power
    sw_a, sw_b = tc.link_sw[lc, 0], tc.link_sw[lc, 1]
    pt_a = jnp.clip(tc.link_port[lc, 0], 0)
    port_lpi = (net.port_state[jnp.clip(sw_a, 0), pt_a] == PortState.LPI) \
        & (sw_a >= 0)
    asleep_a = jnp.where(sw_a >= 0, ~net.sw_awake[jnp.clip(sw_a, 0)], False)
    asleep_b = jnp.where(sw_b >= 0, ~net.sw_awake[jnp.clip(sw_b, 0)], False)
    n_sleep_sw = jnp.sum(jnp.where(lmask, asleep_a | asleep_b, False))
    n_lpi = jnp.sum(jnp.where(lmask, port_lpi, False))
    hops = tc.route_len[src, dst].astype(jnp.float32)
    extra = (n_lpi * swp.t_lpi_wake
             + jnp.minimum(n_sleep_sw, 1) * swp.t_switch_wake)
    if cfg.comm_model == 1:  # packet store-and-forward serialization
        cap0 = tc.link_cap[jnp.clip(links[0], 0)]
        extra = extra + hops * cfg.hop_latency + \
            jnp.maximum(hops - 1.0, 0.0) * cfg.flow_mtu / cap0

    # wake every switch on the route
    sws = tc.route_sw[src, dst]
    sw_awake = net.sw_awake.at[jnp.where(sws >= 0, sws, tc.n_switches + 1)
                               ].set(True, mode="drop")

    def set_if(arr, val):
        return arr.at[slot].set(jnp.where(ok, val, arr[slot]))

    flows = replace(
        flows,
        src=set_if(flows.src, src.astype(jnp.int32)),
        dst=set_if(flows.dst, dst.astype(jnp.int32)),
        rem=set_if(flows.rem, nbytes.astype(jnp.float32)),
        rate=set_if(flows.rate, jnp.float32(0.0)),
        extra=set_if(flows.extra, extra.astype(flows.extra.dtype)),
        done_at=set_if(flows.done_at, jnp.asarray(INF, flows.done_at.dtype)),
        child=set_if(flows.child, child.astype(jnp.int32)),
        active=set_if(flows.active, True),
        flows_dropped=flows.flows_dropped
        + jnp.where(ok, 0, 1).astype(jnp.int32),
    )
    net = replace(net, sw_awake=sw_awake)
    return flows, net, ok


def spawn_flows_many(flows: FlowTable, net: NetState, tc: TopoConsts,
                     cfg: SimConfig, need, src, dst, nbytes, child, now):
    """Spawn flows for every edge with need[e]=True in ONE batched update —
    the vectorized replacement for E sequential spawn_flow calls.

    Slot allocation is a prefix sum over free flow slots (edge e in
    need-order k takes the k-th free slot; edges past the free count fail,
    exactly like sequential first-free allocation).  Switch-wake charging
    preserves the sequential order semantics: a sleeping switch's
    t_switch_wake is only paid by the FIRST needed edge whose route touches
    it — later edges in the same batch see it already awake.

    need/src/dst/nbytes/child (E,).  Returns (flows, net, ok (E,) bool).
    """
    E = need.shape[0]
    F = flows.active.shape[0]
    W = net.sw_awake.shape[0]
    swp = cfg.switch_power
    order = jnp.cumsum(need) - 1                  # rank among needed edges
    srcc, dstc = jnp.clip(src, 0), jnp.clip(dst, 0)

    # first needed edge (in order) whose route touches each switch
    sws = tc.route_sw[srcc, dstc]                             # (E, Hs)
    touch = (sws >= 0) & need[:, None]
    first = jnp.full((W,), E, jnp.int32).at[
        jnp.where(touch, sws, W)].min(
        jnp.broadcast_to(jnp.where(need, order, E)[:, None], sws.shape),
        mode="drop")

    links = tc.routes[srcc, dstc]                             # (E, H)
    lmask = links >= 0
    lc = jnp.clip(links, 0)
    sw_a, sw_b = tc.link_sw[lc, 0], tc.link_sw[lc, 1]         # (E, H)
    pt_a = jnp.clip(tc.link_port[lc, 0], 0)
    port_lpi = (net.port_state[jnp.clip(sw_a, 0), pt_a] == PortState.LPI) \
        & (sw_a >= 0)
    sleeping0 = ~net.sw_awake

    def asleep_at_turn(sw):
        # sleeping when this edge spawns = initially sleeping AND not yet
        # woken by an earlier edge in the batch
        s0 = jnp.where(sw >= 0, sleeping0[jnp.clip(sw, 0)], False)
        return s0 & (first[jnp.clip(sw, 0)] >= order[:, None])

    asleep = asleep_at_turn(sw_a) | asleep_at_turn(sw_b)
    n_sleep_sw = jnp.sum(jnp.where(lmask, asleep, False), axis=1)
    n_lpi = jnp.sum(jnp.where(lmask, port_lpi, False), axis=1)
    hops = tc.route_len[srcc, dstc].astype(jnp.float32)
    extra = (n_lpi * swp.t_lpi_wake
             + jnp.minimum(n_sleep_sw, 1) * swp.t_switch_wake)
    if cfg.comm_model == 1:  # packet store-and-forward serialization
        cap0 = tc.link_cap[jnp.clip(links[:, 0], 0)]
        extra = extra + hops * cfg.hop_latency + \
            jnp.maximum(hops - 1.0, 0.0) * cfg.flow_mtu / cap0

    # prefix-sum slot allocator over free flow slots
    free = ~flows.active
    free_rank = jnp.cumsum(free) - 1
    slot_by_rank = jnp.full((F,), F, jnp.int32).at[
        jnp.where(free, free_rank, F)].set(
        jnp.arange(F, dtype=jnp.int32), mode="drop")
    ok = need & (order < free.sum())
    slot = jnp.where(ok, slot_by_rank[jnp.clip(order, 0, F - 1)], F)

    flows = replace(
        flows,
        src=flows.src.at[slot].set(src.astype(jnp.int32), mode="drop"),
        dst=flows.dst.at[slot].set(dst.astype(jnp.int32), mode="drop"),
        rem=flows.rem.at[slot].set(nbytes.astype(jnp.float32), mode="drop"),
        rate=flows.rate.at[slot].set(0.0, mode="drop"),
        extra=flows.extra.at[slot].set(extra.astype(flows.extra.dtype),
                                       mode="drop"),
        done_at=flows.done_at.at[slot].set(
            jnp.asarray(INF, flows.done_at.dtype), mode="drop"),
        child=flows.child.at[slot].set(child.astype(jnp.int32), mode="drop"),
        active=flows.active.at[slot].set(True, mode="drop"),
        flows_dropped=flows.flows_dropped
        + (need & ~ok).sum().astype(jnp.int32),
    )
    # wake every switch on every needed route (even slot-exhausted spawns,
    # matching the sequential path which wakes before checking ok)
    sw_awake = net.sw_awake.at[jnp.where(touch, sws, W)].set(True,
                                                             mode="drop")
    return flows, replace(net, sw_awake=sw_awake), ok


def recompute_rates(flows: FlowTable, tc: TopoConsts, now):
    """Equal-share fluid rates + projected completion times.
    done_at = now + extra + rem/rate."""
    links = tc.routes[jnp.clip(flows.src, 0), jnp.clip(flows.dst, 0)]  # (F,H)
    lmask = (links >= 0) & flows.active[:, None]
    lidx = jnp.clip(links, 0)
    link_flows = jnp.zeros((tc.n_links,), jnp.int32).at[
        lidx.reshape(-1)].add(lmask.reshape(-1).astype(jnp.int32))
    share = tc.link_cap[lidx] / jnp.maximum(link_flows[lidx], 1)
    share = jnp.where(lmask, share, jnp.inf)
    rate = jnp.where(flows.active, share.min(axis=1), 0.0)
    rate = jnp.where(jnp.isfinite(rate), rate, 0.0).astype(jnp.float32)
    done = jnp.where(
        flows.active & (rate > 0),
        now + flows.extra + flows.rem / jnp.maximum(rate, 1e-30),
        INF).astype(flows.done_at.dtype)
    return replace(flows, rate=rate, done_at=done), link_flows


def advance_flows(flows: FlowTable, dt):
    """Drain dt seconds: consume fixed latency first, then bytes."""
    lat_used = jnp.minimum(flows.extra, dt)
    drain_t = dt - lat_used
    rem = jnp.where(flows.active,
                    jnp.maximum(flows.rem - flows.rate * drain_t, 0.0),
                    flows.rem)
    extra = jnp.where(flows.active, flows.extra - lat_used, flows.extra)
    return replace(flows, rem=rem, extra=extra)


def complete_flows(flows: FlowTable, now, eps=1e-9):
    """Deactivate flows whose done_at <= now; returns (flows, done_mask)."""
    fin = flows.active & (flows.done_at <= now + eps)
    flows = replace(
        flows,
        active=flows.active & ~fin,
        done_at=jnp.where(fin, INF, flows.done_at),
        rem=jnp.where(fin, 0.0, flows.rem),
        rate=jnp.where(fin, 0.0, flows.rate),
        extra=jnp.where(fin, 0.0, flows.extra),
    )
    return flows, fin


def update_switch_states(net: NetState, link_flows, tc: TopoConsts,
                         cfg: SimConfig, now):
    """Port LPI entry/exit from link activity; linecards sleep when all their
    ports are idle; traffic-idle switches doze (case D)."""
    swp = cfg.switch_power
    W, P = net.port_state.shape
    busy = jnp.zeros((W, P), bool)
    for side in range(2):
        sw = tc.link_sw[:, side]
        pt = tc.link_port[:, side]
        m = sw >= 0
        busy = busy.at[jnp.clip(sw, 0), jnp.clip(pt, 0)].max(
            m & (link_flows > 0))
    was_active = net.port_state == PortState.ACTIVE
    idle_since = jnp.where(was_active & ~busy, now, net.port_idle_since)
    lpi_ready = ~busy & (now - idle_since >= swp.t_port_lpi_enter)
    port_state = jnp.where(
        busy, PortState.ACTIVE,
        jnp.where(lpi_ready, PortState.LPI, net.port_state))

    # linecards sleep when no port on them is active
    LC = net.lc_state.shape[1]
    lc_of = jnp.arange(P) // tc.ports_per_lc
    port_act = (port_state == PortState.ACTIVE).astype(jnp.int32)
    lc_busy = jnp.zeros((W, LC), jnp.int32).at[:, jnp.clip(lc_of, 0, LC - 1)
                                               ].add(port_act)
    lc_state = jnp.where(lc_busy > 0, LinecardState.ACTIVE,
                         LinecardState.SLEEP)

    sw_busy = busy.any(axis=1)
    sw_awake = jnp.where(sw_busy, True, net.sw_awake)
    return replace(net, port_state=port_state, port_idle_since=idle_since,
                   lc_state=lc_state, sw_awake=sw_awake,
                   link_flows=link_flows)

"""Global & local scheduling policies (paper §III-E) plus the power-policy
controllers of the four case studies:

  * round-robin / load-balance task->server assignment
  * network-aware assignment (case D): least wake cost, then least load
  * threshold provisioning (case A): grow/shrink the enabled set
  * delay timers, single & dual (case B): per-server τ before deep sleep
  * WASP two-pool management (case C): active pool in shallow PkgC6,
    sleep pool demoted to S3, pool migration on load thresholds

Everything is branch-free dense math over the farm arrays so it can live
inside the jitted engine step.
"""
from __future__ import annotations

import jax.numpy as jnp

from .types import (INF, SchedPolicy, ServerFarm, SimConfig, SleepPolicy,
                    SrvState, replace)

BIG = 1.0e9


def server_load(farm: ServerFarm, cfg: SimConfig):
    """Per-server occupancy = running + queued (N,)."""
    busy = (farm.core_busy_until < INF).sum(axis=1)
    return busy + farm.q_len


def pick_server(farm: ServerFarm, cfg: SimConfig, sched, net_cost=None,
                temp=None, extra_load=None):
    """Choose a server for one task.  Returns (server, new_rr_ptr).

    net_cost (N,) — case D: number of sleeping switches that would need a
    wakeup to reach each server (0 when network disabled).
    temp (N,) — THERMAL_AWARE: current server temperatures; placement
    prefers the coolest eligible server (load as tiebreak), the thermal
    mirror of the network wake-cost policy.
    extra_load (N,) — load already committed by earlier jobs of the same
    same-timestamp admission batch (their enqueued roots), so a burst
    spreads exactly as it did when each job admitted on its own step.
    """
    N = cfg.n_servers
    load = server_load(farm, cfg).astype(jnp.float32)
    if extra_load is not None:
        load = load + extra_load
    enabled = farm.srv_enabled
    full = farm.q_len >= cfg.local_q

    if cfg.sched_policy == SchedPolicy.ROUND_ROBIN:
        # first enabled, non-full server at/after rr_ptr; when every
        # enabled server is full, fall back to the least-loaded enabled
        # one.  Assignment happens at ARRIVAL but the push happens later,
        # at READY drain — the least-loaded queue is the one most likely
        # to have drained below capacity by then, whereas the seed's
        # argmax(ok)=0 pushed at rr_ptr regardless of load
        idx = (sched.rr_ptr + jnp.arange(N)) % N
        ok = enabled[idx] & ~full[idx]
        off = jnp.argmax(ok)                      # first True
        fb = jnp.argmin(jnp.where(enabled, load, jnp.float32(2 * BIG)))
        srv = jnp.where(ok.any(), idx[off], fb).astype(jnp.int32)
        return srv, (srv + 1) % N

    # CARBON_AWARE deliberately falls through to the plain load score:
    # its novelty is WHEN deferrable jobs admit (engine._apply_releases /
    # the deferral gate in _apply_arrival), not WHERE they land
    score = load
    if cfg.sched_policy == SchedPolicy.NETWORK_AWARE and net_cost is not None:
        sleeping = (farm.srv_state == SrvState.PKG_C6) \
            | (farm.srv_state == SrvState.S3) | (farm.srv_state == SrvState.OFF)
        score = load + net_cost.astype(jnp.float32) * 100.0 \
            + sleeping.astype(jnp.float32) * 10.0
    elif cfg.sched_policy == SchedPolicy.THERMAL_AWARE and temp is not None:
        score = load + (temp - cfg.thermal.t_inlet).astype(jnp.float32) \
            * cfg.thermal.sched_temp_weight
    elif cfg.sched_policy == SchedPolicy.WASP_POOLS:
        score = load + farm.srv_pool.astype(jnp.float32) * BIG
    elif cfg.sleep_policy == SleepPolicy.DUAL_TIMER:
        # prioritize the high-τ pool (pool 0) so low-τ servers can sleep
        score = load + farm.srv_pool.astype(jnp.float32) * 1000.0

    score = jnp.where(enabled & ~full, score, jnp.float32(2 * BIG))
    return jnp.argmin(score).astype(jnp.int32), sched.rr_ptr


def pick_servers_for_job(farm: ServerFarm, cfg: SimConfig, sched, valid,
                         net_cost=None, temp=None):
    """Assign servers to ALL tasks of one job in one shot (T picks).

    Equivalent to T sequential pick_server calls against the same farm
    snapshot (the farm does not change during a job's assignment — tasks
    enqueue later, at READY drain).  For the score policies every pick is
    therefore the same argmin; ROUND_ROBIN walks the cyclically-ordered
    enabled & non-full servers via rank matching instead of a fori_loop.

    valid (T,) bool — padding tasks get a pick too but callers must not
    commit them (matching the scalar loop, which gates commits on valid).
    T is any length: the engine also calls this with the flattened task
    mask of a same-timestamp arrival BATCH (all K admitted jobs share the
    same farm snapshot, so the equivalence argument is unchanged).
    Returns (servers (T,) int32, new_rr_ptr).
    """
    N, T = cfg.n_servers, valid.shape[0]

    if cfg.sched_policy != SchedPolicy.ROUND_ROBIN:
        srv, _ = pick_server(farm, cfg, sched, net_cost, temp)
        return jnp.broadcast_to(srv, (T,)), sched.rr_ptr

    load = server_load(farm, cfg).astype(jnp.float32)
    enabled = farm.srv_enabled
    full = farm.q_len >= cfg.local_q
    idx = (sched.rr_ptr + jnp.arange(N)) % N      # cyclic order from rr_ptr
    ok = enabled[idx] & ~full[idx]
    n_ok = ok.sum()
    rank = jnp.cumsum(ok) - 1                     # rank of each ok server
    vi = jnp.cumsum(valid) - 1                    # pick index per valid task
    want = vi % jnp.maximum(n_ok, 1)
    match = ok[None, :] & (rank[None, :] == want[:, None])        # (T, N)
    srv = idx[jnp.argmax(match, axis=1)]
    fb = jnp.argmin(jnp.where(enabled, load, jnp.float32(2 * BIG)))
    srv = jnp.where(n_ok > 0, srv, fb).astype(jnp.int32)
    last = srv[jnp.argmax(jnp.where(valid, vi, -1))]
    rr_new = jnp.where(valid.any(), (last + 1) % N,
                       sched.rr_ptr).astype(jnp.int32)
    return srv, rr_new


def provisioning_adjust(farm: ServerFarm, cfg: SimConfig, sched,
                        active_jobs):
    """Case A: keep load-per-enabled-server between (prov_lo, prov_hi) by
    enabling / disabling one server at a time."""
    if cfg.sched_policy != SchedPolicy.PROVISIONED:
        return farm, sched
    n = sched.n_enabled.astype(jnp.float32)
    # load per enabled server, normalized by its core count (a server at
    # 1.0 has every core busy)
    per = active_jobs.astype(jnp.float32) / jnp.maximum(n * cfg.n_cores, 1.0)
    grow = per > cfg.prov_hi
    shrink = (per < cfg.prov_lo) & (sched.n_enabled > 1)
    # the enabled set can only grow into real servers — padded filler
    # rows (index >= cfg.present) stay disabled forever
    n_new = jnp.clip(sched.n_enabled + jnp.where(grow, 1, 0)
                     - jnp.where(shrink, 1, 0), 1, cfg.present)
    enabled = jnp.arange(cfg.n_servers) < n_new
    return replace(farm, srv_enabled=enabled), replace(sched, n_enabled=n_new)


def wasp_adjust(farm: ServerFarm, cfg: SimConfig, active_jobs, now):
    """Case C: migrate one server between active(0)/sleep(1) pools based on
    pending jobs per active server."""
    if cfg.sleep_policy != SleepPolicy.WASP:
        return farm
    n_active = jnp.maximum((farm.srv_pool == 0).sum(), 1)
    per = active_jobs.astype(jnp.float32) / n_active.astype(jnp.float32)

    # wake: pick one sleep-pool server (prefer shallowest sleep state)
    want_wake = per > cfg.wasp_t_wakeup
    in_sleep_pool = farm.srv_pool == 1
    wake_score = jnp.where(in_sleep_pool,
                           farm.srv_state.astype(jnp.float32), BIG)
    cand_w = jnp.argmin(wake_score)
    do_wake = want_wake & in_sleep_pool.any()
    pool = farm.srv_pool.at[cand_w].set(
        jnp.where(do_wake, 0, farm.srv_pool[cand_w]))

    # sleep: demote one idle active-pool server
    want_sleep = per < cfg.wasp_t_sleep
    idle_active = (pool == 0) & (farm.srv_state == SrvState.IDLE)
    n_act = (pool == 0).sum()
    sleep_score = jnp.where(idle_active, server_load(farm, cfg), BIG)
    cand_s = jnp.argmin(sleep_score.astype(jnp.float32))
    do_sleep = want_sleep & idle_active.any() & (n_act > 1) & ~do_wake
    pool = pool.at[cand_s].set(jnp.where(do_sleep, 1, pool[cand_s]))
    return replace(farm, srv_pool=pool)


def timer_transitions(farm: ServerFarm, cfg: SimConfig, now):
    """Local power controllers: move IDLE servers whose delay timer expired
    into their sleep state (paper §IV-B/C)."""
    idle = farm.srv_state == SrvState.IDLE
    # compare against the SAME f32 expression next_timer_event emits —
    # rewriting it as (now - idle_since >= tau) loses a ulp and livelocks
    expired = idle & (now >= farm.srv_idle_since + farm.srv_tau)

    if cfg.sleep_policy == SleepPolicy.ALWAYS_ON:
        return farm
    if cfg.sleep_policy == SleepPolicy.WASP:
        # active pool: shallow PkgC6 immediately on idle; sleep pool:
        # PkgC6 first, S3 after τ in PkgC6
        to_c6 = idle
        new_state = jnp.where(to_c6, SrvState.PKG_C6, farm.srv_state)
        in_c6 = farm.srv_state == SrvState.PKG_C6
        to_s3 = in_c6 & (farm.srv_pool == 1) \
            & (now >= farm.srv_idle_since + farm.srv_tau)
        new_state = jnp.where(to_s3, SrvState.S3, new_state)
        return replace(farm, srv_state=new_state)

    # SINGLE_TIMER / DUAL_TIMER: idle --τ--> cfg.sleep_state
    # disabled (provisioned-away) servers sleep immediately
    expired = expired | (idle & ~farm.srv_enabled)
    new_state = jnp.where(expired, cfg.sleep_state, farm.srv_state)
    return replace(farm, srv_state=new_state)


def next_timer_event(farm: ServerFarm, cfg: SimConfig):
    """Earliest pending delay-timer expiry (scalar; INF if none)."""
    if cfg.sleep_policy in (SleepPolicy.ALWAYS_ON,):
        return jnp.asarray(INF, cfg.time_dtype)
    idle = farm.srv_state == SrvState.IDLE
    t = jnp.where(idle, farm.srv_idle_since + farm.srv_tau, INF)
    if cfg.sleep_policy == SleepPolicy.WASP:
        in_c6 = (farm.srv_state == SrvState.PKG_C6) & (farm.srv_pool == 1)
        t2 = jnp.where(in_c6, farm.srv_idle_since + farm.srv_tau, INF)
        t = jnp.minimum(t, t2)
    return t.min().astype(cfg.time_dtype)

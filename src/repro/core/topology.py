"""Data center network topologies (paper §III-B).

Supported, mirroring the paper's list:
  * fat-tree (switch-only)            — Al-Fares et al. [8]
  * flattened butterfly (switch-only) — Kim et al. [34] (k-ary 2-flat)
  * BCube (hybrid, servers forward)   — Guo et al. [26] (level-1)
  * CamCube (server-only 3D torus)    — Abu-Libdeh et al. [6]
  * star (single switch)              — used for the paper's §V-B validation

Topology construction and all-pairs routing run host-side in numpy once at
config time (graph algorithms do not belong on the MXU — DESIGN.md §3); the
simulator consumes only dense arrays:

  links      (L, 2)  node endpoints (servers are 0..N-1, switches N..N+W-1)
  link_cap   (L,)    bytes/s
  routes     (N, N, H) link-id paths between server pairs (-1 padded)
  route_len  (N, N)
  link_port  (L, 2)  port index within the endpoint switch (-1 for servers)
  route_sw   (N, N, H+1) switch ids along the path (-1 padded), for case D
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

__all__ = ["Topology", "fat_tree", "flattened_butterfly", "bcube", "camcube",
           "star", "rack_of_servers"]


@dataclasses.dataclass
class Topology:
    name: str
    n_servers: int
    n_switches: int
    n_ports: int                 # max ports per switch
    ports_per_linecard: int
    links: np.ndarray            # (L, 2) int32
    link_cap: np.ndarray         # (L,) float32
    link_port: np.ndarray        # (L, 2) int32
    routes: np.ndarray           # (N, N, H) int32 link ids
    route_len: np.ndarray        # (N, N) int32
    route_sw: np.ndarray         # (N, N, Hs) int32 switch ids on path

    @property
    def n_links(self) -> int:
        return len(self.links)

    @property
    def max_hops(self) -> int:
        return self.routes.shape[2]

    def linecard_of_port(self, p):
        return p // self.ports_per_linecard

    @property
    def n_linecards(self) -> int:
        return -(-self.n_ports // self.ports_per_linecard)


def _build(name, n_servers, n_switches, edges, link_cap, ports_per_lc=8):
    """edges: list of (node_a, node_b). Computes ports, BFS all-pairs routes."""
    links = np.asarray(edges, np.int32).reshape(-1, 2)
    L = len(links)
    n_nodes = n_servers + n_switches

    # assign switch-local port indices in link order
    port_ctr = np.zeros(n_nodes, np.int32)
    link_port = np.full((L, 2), -1, np.int32)
    for li, (a, b) in enumerate(links):
        for side, node in enumerate((a, b)):
            if node >= n_servers:                      # switch side
                link_port[li, side] = port_ctr[node]
            port_ctr[node] += 1
    n_ports = int(port_ctr[n_servers:].max()) if n_switches else 1

    # adjacency: node -> [(neighbor, link_id)]
    adj = [[] for _ in range(n_nodes)]
    for li, (a, b) in enumerate(links):
        adj[a].append((b, li))
        adj[b].append((a, li))

    # BFS from every server -> parent pointers -> link paths to other servers
    H = 0
    paths = {}
    for s in range(n_servers):
        par = np.full(n_nodes, -1, np.int64)
        plink = np.full(n_nodes, -1, np.int64)
        par[s] = s
        dq = deque([s])
        while dq:
            u = dq.popleft()
            for (v, li) in adj[u]:
                if par[v] < 0:
                    par[v] = u
                    plink[v] = li
                    dq.append(v)
        for d in range(n_servers):
            if d == s or par[d] < 0:
                continue
            p, sw = [], []
            u = d
            while u != s:
                p.append(int(plink[u]))
                if u >= n_servers:
                    sw.append(int(u - n_servers))
                u = int(par[u])
            p.reverse()
            sw.reverse()
            paths[(s, d)] = (p, sw)
            H = max(H, len(p))

    H = max(H, 1)
    Hs = max(H, 1)
    routes = np.full((n_servers, n_servers, H), -1, np.int32)
    route_len = np.zeros((n_servers, n_servers), np.int32)
    route_sw = np.full((n_servers, n_servers, Hs), -1, np.int32)
    for (s, d), (p, sw) in paths.items():
        routes[s, d, :len(p)] = p
        route_len[s, d] = len(p)
        route_sw[s, d, :len(sw)] = sw

    return Topology(
        name=name, n_servers=n_servers, n_switches=n_switches,
        n_ports=n_ports, ports_per_linecard=ports_per_lc,
        links=links, link_cap=np.full((L,), link_cap, np.float32),
        link_port=link_port, routes=routes, route_len=route_len,
        route_sw=route_sw)


def rack_of_servers(topo: Topology, rack_size: int = 8) -> np.ndarray:
    """(N,) rack grouping for the thermal recirculation model
    (core/thermal.py): servers sharing a first-hop switch share a rack —
    the natural top-of-rack reading of every switch-based topology here
    (fat-tree edge switches, butterfly routers, BCube level-0, the star's
    single rack).  Switchless topologies (CamCube) fall back to
    ``i // rack_size`` chunks.

    Ids are raw first-switch indices; ``thermal.init_thermal`` densifies
    them, so gaps are fine.
    """
    n = topo.n_servers
    if topo.n_switches == 0:
        return np.arange(n) // max(rack_size, 1)
    first_sw = np.full(n, -1, np.int64)
    for a, b in topo.links:
        a, b = int(a), int(b)
        if a < n <= b and first_sw[a] < 0:
            first_sw[a] = b - n
        elif b < n <= a and first_sw[b] < 0:
            first_sw[b] = a - n
    # isolated servers (none in the provided builders) get their own rack
    lone = first_sw < 0
    first_sw[lone] = topo.n_switches + np.arange(n)[lone]
    return first_sw


def star(n_servers: int, link_cap: float = 125e6, ports_per_lc: int = 24):
    """All servers on one switch — the paper's §V-B validation setup
    (24 servers, one Cisco WS-C2960-24-S)."""
    sw = n_servers
    edges = [(s, sw) for s in range(n_servers)]
    return _build("star", n_servers, 1, edges, link_cap, ports_per_lc)


def fat_tree(k: int, link_cap: float = 125e6, ports_per_lc: int = 8):
    """Standard k-ary fat-tree: k pods, (k/2)^2 servers/pod, full bisection.
    Servers: k^3/4.  Switches: edge k^2/2 + agg k^2/2 + core (k/2)^2."""
    assert k % 2 == 0
    half = k // 2
    n_servers = k * half * half
    n_edge = k * half
    n_agg = k * half
    n_core = half * half
    base = n_servers
    def edge_id(pod, e):
        return base + pod * half + e

    def agg_id(pod, a):
        return base + n_edge + pod * half + a

    def core_id(i, j):
        return base + n_edge + n_agg + i * half + j

    edges = []
    for pod in range(k):
        for e in range(half):
            for h in range(half):
                srv = pod * half * half + e * half + h
                edges.append((srv, edge_id(pod, e)))
            for a in range(half):
                edges.append((edge_id(pod, e), agg_id(pod, a)))
        for a in range(half):
            for j in range(half):
                edges.append((agg_id(pod, a), core_id(a, j)))
    return _build(f"fat_tree_k{k}", n_servers, n_edge + n_agg + n_core,
                  edges, link_cap, ports_per_lc)


def flattened_butterfly(k: int, link_cap: float = 125e6,
                        ports_per_lc: int = 8):
    """k-ary 2-flat: k routers, each attached to k servers, routers fully
    connected (one inter-router hop max)."""
    n_servers = k * k
    base = n_servers
    edges = []
    for r in range(k):
        for h in range(k):
            edges.append((r * k + h, base + r))
    for r in range(k):
        for r2 in range(r + 1, k):
            edges.append((base + r, base + r2))
    return _build(f"flat_bfly_k{k}", n_servers, k, edges, link_cap,
                  ports_per_lc)


def bcube(n: int, link_cap: float = 125e6, ports_per_lc: int = 8):
    """BCube(n,1): n^2 servers, 2n switches of n ports; hybrid — servers have
    two NICs and participate in forwarding (via BFS paths through servers)."""
    n_servers = n * n
    base = n_servers
    def lvl0(g):                       # level-0 switch of group g
        return base + g

    def lvl1(i):                       # level-1 switch i
        return base + n + i
    edges = []
    for g in range(n):
        for s in range(n):
            srv = g * n + s
            edges.append((srv, lvl0(g)))
            edges.append((srv, lvl1(s)))
    return _build(f"bcube_n{n}", n_servers, 2 * n, edges, link_cap,
                  ports_per_lc)


def camcube(dx: int, dy: int, dz: int, link_cap: float = 125e6):
    """CamCube: server-only 3D torus; servers forward (symbiotic routing)."""
    n_servers = dx * dy * dz
    def idx(x, y, z):
        return (x % dx) * dy * dz + (y % dy) * dz + (z % dz)
    edges = set()
    for x in range(dx):
        for y in range(dy):
            for z in range(dz):
                a = idx(x, y, z)
                for b in (idx(x + 1, y, z), idx(x, y + 1, z),
                          idx(x, y, z + 1)):
                    if a != b:
                        edges.add((min(a, b), max(a, b)))
    return _build(f"camcube_{dx}x{dy}x{dz}", n_servers, 0, sorted(edges),
                  link_cap, 8)

"""The event-driven simulation engine, vectorized for TPU (DESIGN.md §3).

The paper's sequential priority-queue loop becomes:

    while not done:
        t_next = min over all dense candidate-event arrays      (VPU reduce)
        accrue energy for (t_next - t)                          (exact: state
                                                                 is piecewise
                                                                 constant)
        apply ALL events with time <= t_next as masked updates  (dense)

Semantics are identical to a heap-based DES — we always advance to the
global minimum event time, so there is no time-discretization error.  The
per-iteration work is O(state) streaming instead of O(log n) pointer
chasing, which is exactly the trade the TPU wants; `kernels/dcsim_step.py`
fuses the min-reduction + energy accrual of the hot loop into one VMEM pass.

Event sources:
  job arrival            jobs.arrival[arr_ptr]
  task completion        min core_busy_until
  wake completion        min srv_wake_at
  delay-timer expiry     scheduler.next_timer_event
  flow completion        min flows.done_at          (network mode)
  pending work           t (now) when READY tasks await placement

Scheduling/assignment model: the global scheduler assigns servers to ALL
tasks of a job at arrival (policy-driven, sequential over the job's <=T
tasks).  When a parent task finishes, each DAG edge either decrements the
child's dep_count immediately (no network / same server / zero bytes) or
spawns a flow parent_server -> child_server; the flow's completion
decrements it.  dep_count==0 turns a task READY; READY tasks are drained
(bounded per step) into their server's local ring queue, waking sleeping
servers on demand.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import network as net_mod
from . import power, scheduler, server, telemetry
from . import thermal as thermal_mod
from .types import (INF, FlowTable, JobTable, NetState, SchedPolicy,
                    SchedState, ServerFarm, SimConfig, SimState, SrvState,
                    TaskStatus, init_farm, init_flows, init_net, init_sched,
                    replace)


# ==========================================================================
# helpers
# ==========================================================================

def _active_jobs(jobs: JobTable) -> jnp.ndarray:
    """Tasks in flight (READY/QUEUED/RUNNING) — the provisioning load
    metric."""
    s = jobs.status
    return ((s == TaskStatus.READY) | (s == TaskStatus.QUEUED)
            | (s == TaskStatus.RUNNING)).sum()


def _pending_jobs(jobs: JobTable) -> jnp.ndarray:
    """Tasks waiting for a core (READY/QUEUED, excluding RUNNING) — the
    WASP pool metric (paper §IV-C: 'pending jobs per server'); counting
    running tasks would stop multi-core servers from ever consolidating."""
    s = jobs.status
    return ((s == TaskStatus.READY) | (s == TaskStatus.QUEUED)).sum()


def _next_arrival(jobs: JobTable) -> jnp.ndarray:
    J = jobs.arrival.shape[0]
    return jnp.where(jobs.arr_ptr < J,
                     jobs.arrival[jnp.clip(jobs.arr_ptr, 0, J - 1)], INF)


def next_event_time(state: SimState, cfg: SimConfig) -> jnp.ndarray:
    cands = [
        _next_arrival(state.jobs),
        state.farm.core_busy_until.min(),
        state.farm.srv_wake_at.min(),
        scheduler.next_timer_event(state.farm, cfg),
    ]
    if cfg.has_network:
        cands.append(state.flows.done_at.min())
    if cfg.thermal.throttling:
        # throttle-threshold crossings are real events: the RC exponential
        # is solved for the crossing time, so throttling engages exactly
        # when the temperature reaches it, not at the next unrelated event
        cands.append(thermal_mod.next_crossing(state, cfg))
    t_next = functools.reduce(jnp.minimum, cands)
    # pending READY tasks (or queued work on awake free cores) execute "now"
    ready = (state.jobs.status == TaskStatus.READY).any()
    awake = (state.farm.srv_state == SrvState.ACTIVE) \
        | (state.farm.srv_state == SrvState.IDLE)
    startable = (awake & (state.farm.q_len > 0)
                 & (state.farm.core_busy_until >= INF).any(axis=1)).any()
    t_next = jnp.where(ready | startable, state.t, t_next)
    return jnp.maximum(t_next, state.t).astype(cfg.time_dtype)


# ==========================================================================
# event appliers
# ==========================================================================

def _rebuild_job_completion(jobs: JobTable, cfg: SimConfig, now):
    """(tasks_done, job_finish) rebuilt from task statuses: DONE is
    terminal (completions and drops both land there), so the per-job count
    is a pure function of the current statuses.  Newly-complete jobs get
    job_finish stamped at ``now``."""
    T = cfg.tasks_per_job
    tasks_done = ((jobs.status == TaskStatus.DONE)
                  & jobs.valid).reshape(-1, T).sum(axis=1)
    n_valid_tasks = jobs.valid.reshape(-1, T).sum(axis=1)
    job_complete = (tasks_done >= n_valid_tasks) & (tasks_done > 0)
    job_finish = jnp.where(job_complete & (jobs.job_finish >= INF),
                           now, jobs.job_finish)
    return tasks_done, job_finish


def _promote_ready(jobs: JobTable, dep_count, cfg: SimConfig):
    """BLOCKED -> READY where deps are now satisfied (arrived jobs only)."""
    T = cfg.tasks_per_job
    arrived = jnp.arange(jobs.status.shape[0]) // T < jobs.arr_ptr
    ready = (jobs.status == TaskStatus.BLOCKED) & (dep_count <= 0) & arrived
    return jnp.where(ready, TaskStatus.READY, jobs.status)


def _apply_wakeups(farm: ServerFarm, cfg, now):
    done = (farm.srv_state == SrvState.WAKING) & (farm.srv_wake_at <= now)
    return replace(
        farm,
        srv_state=jnp.where(done, SrvState.IDLE, farm.srv_state),
        srv_wake_at=jnp.where(done, INF, farm.srv_wake_at),
        srv_idle_since=jnp.where(done, now, farm.srv_idle_since))


def _apply_completions(state: SimState, cfg: SimConfig, tc=None):
    """Handle all cores whose busy_until <= now.  Marks tasks DONE, updates
    job bookkeeping, and resolves DAG edges (immediate dep decrement or
    flow spawn).

    Task-level bookkeeping is pure elementwise task-space math: a RUNNING
    task with task_end <= now is complete (task_end was stamped with its
    core's busy_until at start), so no core->task scatter is needed.  Only
    the DAG-edge resolution still walks the completed cores, and it is
    statically absent for single-task jobs and runtime-gated on "any core
    finished" otherwise."""
    farm, jobs, flows, net = state.farm, state.jobs, state.flows, state.net
    now = state.t
    T = cfg.tasks_per_job
    done_mask = farm.core_busy_until <= now                       # (N, C)
    core_task = farm.core_task

    # free the cores (elementwise)
    farm = replace(
        farm,
        core_busy_until=jnp.where(done_mask, INF, farm.core_busy_until),
        core_task=jnp.where(done_mask, -1, farm.core_task))

    # mark DONE + record finish time (elementwise in task space)
    done_task = (jobs.status == TaskStatus.RUNNING) \
        & (jobs.task_end <= now)
    status = jnp.where(done_task, TaskStatus.DONE, jobs.status)
    finish = jnp.where(done_task, now, jobs.finish)
    jobs = replace(jobs, status=status, finish=finish)
    tasks_done, job_finish = _rebuild_job_completion(jobs, cfg, now)
    jobs = replace(jobs, tasks_done=tasks_done, job_finish=job_finish)

    if T > 1:
        jobs, flows, net = _resolve_done_edges(
            jobs, flows, net, cfg, tc, done_mask, core_task, now)
    return replace(state, farm=farm, jobs=jobs, flows=flows, net=net)


def _resolve_done_edges(jobs, flows, net, cfg, tc, done_mask, core_task,
                        now):
    """DAG edges of tasks completed this step: immediate dep decrement or
    flow spawn, then BLOCKED -> READY.  Single-task jobs have no edges, so
    this is only traced for T > 1 and only runs when a core finished."""
    T = cfg.tasks_per_job
    JT = jobs.status.shape[0]

    def resolve(args):
        jobs, flows, net = args
        tid = jnp.where(done_mask, core_task, -1)                 # (N, C)
        flat_tid = tid.reshape(-1)
        valid = flat_tid >= 0
        safe_tid = jnp.clip(flat_tid, 0)
        # scatter index with out-of-bounds sentinel: clipping -1 to 0
        # would make every inactive core slot write a STALE value into
        # task 0 (duplicate scatter .set is non-deterministic);
        # mode="drop" discards them instead
        sc_tid = jnp.where(valid, flat_tid, JT)

        ch = jobs.children[safe_tid]                              # (NC, D)
        eb = jobs.edge_bytes[safe_tid]
        ch_valid = (ch >= 0) & valid[:, None] & ~jobs.edge_sent[safe_tid]
        edge_sent = jobs.edge_sent.at[sc_tid].set(
            jobs.edge_sent[safe_tid] | ch_valid, mode="drop")

        dep_count = jobs.dep_count
        if cfg.has_network:
            # same-server or zero-byte edges resolve immediately; others
            # spawn flows parent_server -> child_server
            src_srv = jobs.server[safe_tid]                       # (NC,)
            dst_srv = jobs.server[jnp.clip(ch, 0)]                # (NC, D)
            needs_flow = ch_valid & (eb > 0) & (dst_srv != src_srv[:, None])
            immediate = ch_valid & ~needs_flow
            dep_count = dep_count.at[jnp.clip(ch, 0).reshape(-1)].add(
                -immediate.reshape(-1).astype(jnp.int32), mode="drop")

            flat = needs_flow.reshape(-1)
            f_src = jnp.broadcast_to(src_srv[:, None], ch.shape).reshape(-1)
            f_dst = dst_srv.reshape(-1)
            f_bytes = eb.reshape(-1)
            f_child = ch.reshape(-1)

            no_fail = jnp.zeros_like(flat)
            if cfg.use_vectorized_hot_loop:
                def spawn(args):
                    flows, net, _ = args
                    flows, net, ok = net_mod.spawn_flows_many(
                        flows, net, tc, cfg, flat, f_src, f_dst, f_bytes,
                        f_child, now)
                    return flows, net, flat & ~ok

                # most steps spawn nothing — gate the dense pass
                flows, net, failed = jax.lax.cond(
                    flat.any(), spawn, lambda a: a, (flows, net, no_fail))
            else:
                def spawn_one(i, carry):
                    flows, net, failed = carry

                    def do(args):
                        flows, net, failed = args
                        fl, nt, ok = net_mod.spawn_flow(
                            flows, net, tc, cfg, f_src[i], f_dst[i],
                            f_bytes[i], f_child[i], now)
                        return fl, nt, failed.at[i].set(~ok)
                    return jax.lax.cond(flat[i], do, lambda a: a,
                                        (flows, net, failed))

                flows, net, failed = jax.lax.fori_loop(
                    0, flat.shape[0], spawn_one, (flows, net, no_fail))

            # a full FlowTable drop-resolves the edge like the queue-drop
            # path: the child's dep decrements immediately (the results
            # simply never ship) instead of leaving it BLOCKED forever;
            # the spawn primitives count the drop in flows.flows_dropped
            dep_count = dep_count.at[jnp.where(failed, f_child, JT)].add(
                -1, mode="drop")
        else:
            dep_count = dep_count.at[jnp.clip(ch, 0).reshape(-1)].add(
                -ch_valid.reshape(-1).astype(jnp.int32), mode="drop")

        status = _promote_ready(jobs, dep_count, cfg)
        jobs = replace(jobs, status=status, dep_count=dep_count,
                       edge_sent=edge_sent)
        return jobs, flows, net

    return jax.lax.cond(done_mask.any(), resolve, lambda a: a,
                        (jobs, flows, net))


def _apply_flow_completions(state: SimState, cfg: SimConfig):
    flows, fin = net_mod.complete_flows(state.flows, state.t)

    def resolve(jobs):
        child = jnp.where(fin, flows.child, -1)
        dep_count = jobs.dep_count.at[jnp.clip(child, 0)].add(
            -fin.astype(jnp.int32), mode="drop")
        status = _promote_ready(jobs, dep_count, cfg)
        return replace(jobs, dep_count=dep_count, status=status)

    jobs = jax.lax.cond(fin.any(), resolve, lambda j: j, state.jobs)
    return replace(state, flows=flows, jobs=jobs)


def _apply_arrival(state: SimState, cfg: SimConfig, tc=None):
    """Admit up to cfg.arrivals_per_step jobs whose arrival <= t in one
    pass: assign servers to all their tasks (policy), mark roots READY.

    All jobs admitted in the same step share one scheduler snapshot —
    admission itself never changes server load (queue pushes happen later,
    at READY drain), so the batched pass equals K sequential picks against
    the same farm, exactly the ``pick_servers_for_job`` argument one level
    up.  Same-timestamp bursts (MMPP high state, trace replays) therefore
    no longer serialize one step per job."""
    jobs, farm, sched = state.jobs, state.farm, state.sched
    J = jobs.arrival.shape[0]
    T = cfg.tasks_per_job
    K = cfg.arrivals_per_step
    j0 = jobs.arr_ptr
    jid = j0 + jnp.arange(K)
    nxt = jobs.arrival[jnp.clip(jid, 0, J - 1)]
    elig = (jid < J) & (nxt <= state.t) & (nxt < INF / 2)
    # arrivals are sorted, so eligibility is a prefix; enforce it anyway
    # so an unsorted table degrades to the old one-at-a-time behavior
    elig = jnp.cumprod(elig.astype(jnp.int32)).astype(bool)
    n_adm = elig.sum()

    def _net_cost():
        if cfg.has_network and \
                cfg.sched_policy == SchedPolicy.NETWORK_AWARE:
            # wake cost from the front-end (server 0) to each server; the
            # net state does not change during admission, so one
            # evaluation serves every task of the batch
            return jax.vmap(
                lambda d: net_mod.route_wake_cost(
                    tc, state.net, jnp.int32(0), d)
            )(jnp.arange(cfg.n_servers))
        return None

    def _temp():
        if cfg.thermal.enabled and \
                cfg.sched_policy == SchedPolicy.THERMAL_AWARE:
            return state.thermal.t_srv
        return None

    def admit(args):
        jobs, farm, sched = args
        JT = jobs.status.shape[0]
        tids = j0 * T + jnp.arange(K * T)                  # flat task ids
        in_range = tids < JT
        sc = jnp.where(in_range, tids, JT)                 # scatter sentinel
        gather = jnp.clip(tids, 0, JT - 1)
        elig_t = jnp.repeat(elig, T)
        is_valid = jobs.valid[gather] & elig_t & in_range

        root = is_valid & (jobs.dep_count[gather] <= 0)

        if cfg.sched_policy == SchedPolicy.ROUND_ROBIN:
            if cfg.use_vectorized_hot_loop:
                # all K*T assignments in one shot (cumulative-offset
                # round-robin rank matching)
                srvs, rr_new = scheduler.pick_servers_for_job(
                    farm, cfg, sched, is_valid)
                server_arr = jobs.server.at[sc].set(
                    jnp.where(is_valid, srvs, jobs.server[gather]),
                    mode="drop")
                jobs = replace(jobs, server=server_arr)
                sched = replace(sched, rr_ptr=rr_new)
            else:
                def assign_one(i, carry):
                    jobs, sched = carry
                    tid = gather[i]
                    v = is_valid[i]
                    srv, rr = scheduler.pick_server(farm, cfg, sched)
                    server_arr = jobs.server.at[tid].set(
                        jnp.where(v, srv, jobs.server[tid]))
                    sched = replace(sched,
                                    rr_ptr=jnp.where(v, rr, sched.rr_ptr))
                    return replace(jobs, server=server_arr), sched

                jobs, sched = jax.lax.fori_loop(0, K * T, assign_one,
                                                (jobs, sched))
        else:
            # score policies: one pick PER JOB (the farm cannot change
            # during a single job's assignment), but job k's pick must see
            # the roots committed by jobs 0..k-1 of the same batch —
            # otherwise a same-timestamp burst piles onto the one argmin
            # server, where the old one-job-per-step path spread it (each
            # admit saw the previous job's drained roots as queue load)
            net_cost = _net_cost()
            temp = _temp()
            root_k = root.reshape(K, T)
            extra = jnp.zeros((cfg.n_servers,), jnp.float32)
            picks = []
            for k in range(K):                     # static unroll, K small
                srv_k, _ = scheduler.pick_server(farm, cfg, sched,
                                                 net_cost, temp, extra)
                extra = extra.at[srv_k].add(
                    root_k[k].sum().astype(jnp.float32))
                picks.append(srv_k)
            srvs = jnp.repeat(jnp.stack(picks), T)
            server_arr = jobs.server.at[sc].set(
                jnp.where(is_valid, srvs, jobs.server[gather]), mode="drop")
            jobs = replace(jobs, server=server_arr)

        # roots -> READY
        status = jobs.status.at[sc].set(
            jnp.where(root, TaskStatus.READY, jobs.status[gather]),
            mode="drop")
        jobs = replace(jobs, status=status, arr_ptr=j0 + n_adm)
        return jobs, farm, sched

    jobs, farm, sched = jax.lax.cond(
        n_adm > 0, admit, lambda a: a, (jobs, farm, sched))
    return replace(state, jobs=jobs, farm=farm, sched=sched)


def _resolve_drops(state: SimState, cfg: SimConfig, dropped):
    """Complete the bookkeeping for tasks dropped by a full queue
    (dropped (JT,) bool, already marked DONE by the drain).

    Without this, a drop deadlocks DAG workloads: the task is DONE but its
    children's dep_count never reaches zero, so they stay BLOCKED forever
    and the sim spins to max_events.  A dropped task counts toward job
    completion (finish/job_finish stamped at drop time, flagged via
    farm.dropped) and resolves its DAG edges immediately — it never ran,
    so there are no results to ship and no flows to spawn.

    Gated on dropped.any(): overflow is the exception, and the healthy
    path must not pay the bookkeeping every step.
    """
    now = state.t

    def resolve(jobs):
        finish = jnp.where(dropped, now, jobs.finish)
        # drops were already marked DONE by the drain
        tasks_done, job_finish = _rebuild_job_completion(jobs, cfg, now)

        ch = jobs.children                           # (JT, D)
        ch_valid = (ch >= 0) & dropped[:, None] & ~jobs.edge_sent
        edge_sent = jobs.edge_sent | ch_valid
        dep_count = jobs.dep_count.at[jnp.clip(ch, 0).reshape(-1)].add(
            -ch_valid.reshape(-1).astype(jnp.int32), mode="drop")

        status = _promote_ready(jobs, dep_count, cfg)
        return replace(jobs, status=status, finish=finish,
                       tasks_done=tasks_done, job_finish=job_finish,
                       dep_count=dep_count, edge_sent=edge_sent)

    jobs = jax.lax.cond(dropped.any(), resolve, lambda j: j, state.jobs)
    return replace(state, jobs=jobs)


def _drain_ready(state: SimState, cfg: SimConfig):
    """Enqueue up to cfg.ready_per_step READY tasks at their servers
    (first K in task-id order).  Queue-full drops are resolved afterwards
    (_resolve_drops); their newly-READY children drain on the next step —
    still at the same simulation time, since READY tasks pin t_next to t."""
    if cfg.use_vectorized_hot_loop:
        return _drain_ready_batched(state, cfg)
    return _drain_ready_scalar(state, cfg)


def _drain_ready_batched(state: SimState, cfg: SimConfig):
    """One multi-push: rank the first K READY tasks per destination server
    and write them into ring-queue slots with a single scatter.  The whole
    pass is gated on "any READY task" so quiet steps stay free."""
    is_ready = state.jobs.status == TaskStatus.READY

    def drain(state):
        jobs, farm = state.jobs, state.farm
        K = cfg.ready_per_step
        JT = jobs.status.shape[0]
        r = jnp.cumsum(is_ready) - 1                # rank among READY
        sel = is_ready & (r < K)
        # gather selected tids into (K,) batch slots, ascending tid order
        tids = jnp.full((K,), -1, jnp.int32).at[jnp.where(sel, r, K)].set(
            jnp.arange(JT, dtype=jnp.int32), mode="drop")
        valid = tids >= 0
        srv = jnp.where(valid, jobs.server[jnp.clip(tids, 0)], -1)

        farm, ok = server.queue_push_many(farm, cfg, srv, tids, valid)
        dest = jnp.zeros((cfg.n_servers,), bool).at[
            jnp.where(valid, srv, cfg.n_servers)].set(True, mode="drop")
        farm = server.begin_wake_mask(farm, cfg, dest, state.t)

        sc = jnp.where(valid, tids, JT)
        status = jobs.status.at[sc].set(
            jnp.where(ok, TaskStatus.QUEUED, TaskStatus.DONE), mode="drop")
        state = replace(state, jobs=replace(jobs, status=status), farm=farm)
        dropped = jnp.zeros((JT,), bool).at[
            jnp.where(valid & ~ok, tids, JT)].set(True, mode="drop")
        return _resolve_drops(state, cfg, dropped)

    return jax.lax.cond(is_ready.any(), drain, lambda s: s, state)


def _drain_ready_scalar(state: SimState, cfg: SimConfig):
    """Seed reference path: K sequential scalar queue_push + begin_wake."""
    status_before = state.jobs.status

    def one(_, st):
        jobs, farm = st.jobs, st.farm
        is_ready = jobs.status == TaskStatus.READY
        any_ready = is_ready.any()
        tid = jnp.argmax(is_ready)                      # first READY
        srv = jobs.server[tid]

        def do(st):
            jobs, farm = st.jobs, st.farm
            farm2, ok = server.queue_push(farm, cfg, srv, tid)
            farm2 = server.begin_wake(farm2, cfg, srv, st.t)
            status = jobs.status.at[tid].set(
                jnp.where(ok, TaskStatus.QUEUED, TaskStatus.DONE))
            jobs2 = replace(jobs, status=status)
            return replace(st, jobs=jobs2, farm=farm2)

        return jax.lax.cond(any_ready, do, lambda s: s, st)

    state = jax.lax.fori_loop(0, cfg.ready_per_step, one, state)
    # READY -> DONE transitions during the loop are exactly the drops
    dropped = (status_before == TaskStatus.READY) \
        & (state.jobs.status == TaskStatus.DONE)
    return _resolve_drops(state, cfg, dropped)


def _start_tasks(state: SimState, cfg: SimConfig):
    # throttled servers start work at their reduced effective frequency;
    # freq=None keeps the seed scalar expression when thermal is off
    freq = thermal_mod.effective_freq(state.thermal, cfg) \
        if cfg.thermal.throttling else None
    farm, started = server.try_start(
        state.farm, cfg, state.jobs.service, state.t, freq)
    sid = started.reshape(-1)
    JT = state.jobs.status.shape[0]
    sc = jnp.where(sid >= 0, sid, JT)          # drop-sentinel (see above)

    def stamp(jobs):
        status = jobs.status.at[sc].set(TaskStatus.RUNNING, mode="drop")
        # stamp the core's busy_until so completion resolves elementwise
        task_end = jobs.task_end.at[sc].set(
            farm.core_busy_until.reshape(-1), mode="drop")
        return replace(jobs, status=status, task_end=task_end)

    jobs = jax.lax.cond((sid >= 0).any(), stamp, lambda j: j, state.jobs)
    return replace(state, farm=farm, jobs=jobs)


# ==========================================================================
# the step
# ==========================================================================

def sim_step(state: SimState, cfg: SimConfig, tc=None) -> SimState:
    t_next = next_event_time(state, cfg)
    # a t_next at the INF sentinel means "no pending events": freeze time
    # (the done check below will terminate the loop) instead of integrating
    # energy over an unbounded interval
    t_next = jnp.where(t_next >= INF / 2, state.t, t_next)
    dt = t_next - state.t

    telemetry_on = cfg.telemetry.enabled
    if telemetry_on:
        # window metrics integrate the PRE-advance state over [t, t_next)
        # (piecewise constant, same exactness as the energy accrual);
        # finish arrays are captured so the INF -> finite transition below
        # identifies this step's completions.
        wvals = telemetry.window_values(state, cfg, dt)
        widx = telemetry.window_index(state.t, dt, cfg.telemetry)
        old_job_finish = state.jobs.job_finish
        old_task_finish = state.jobs.finish

    thermal_on = cfg.thermal.enabled
    p_busy = None
    if thermal_on:
        # one evaluation of the (throttle-scaled) per-server power feeds
        # both the exact energy accrual and the thermal RC integrator
        p_busy = power.server_power(state.farm, cfg,
                                    state.thermal.throttled)

    farm = power.accrue_server_energy(state.farm, cfg, dt, p_busy)
    net, flows = state.net, state.flows
    if cfg.has_network:
        net = power.accrue_switch_energy(net, cfg, dt)
        # drain the fluid model over the interval (rates are piecewise
        # constant, fixed at the last recompute): without this, bytes
        # never drained and every intervening event pushed done_at later
        flows = net_mod.advance_flows(flows, dt)
    therm = state.thermal
    if thermal_on:
        p_sw = power.switch_power(net, cfg).sum() if cfg.has_network \
            else jnp.float32(0.0)
        therm = thermal_mod.advance(therm, cfg, p_busy[0], p_sw,
                                    state.t, dt)
    state = replace(state, farm=farm, net=net, flows=flows, thermal=therm,
                    t=t_next)

    if cfg.thermal.throttling:
        # hysteresis latch + in-flight stretch; cond-gated on "any flip"
        farm, jobs, therm = thermal_mod.apply_throttle(
            state.farm, state.jobs, state.thermal, cfg, state.t)
        state = replace(state, farm=farm, jobs=jobs, thermal=therm)

    state = replace(state, farm=_apply_wakeups(state.farm, cfg, state.t))
    state = _apply_completions(state, cfg, tc)
    if cfg.has_network:
        state = _apply_flow_completions(state, cfg)
    state = _apply_arrival(state, cfg, tc)
    state = _drain_ready(state, cfg)
    state = _start_tasks(state, cfg)

    # refresh ACTIVE/IDLE, run local power controllers + pool managers
    farm = server.refresh_idle_state(state.farm, cfg, state.t)
    active = _active_jobs(state.jobs)
    farm, sched = scheduler.provisioning_adjust(farm, cfg, state.sched,
                                                active)
    farm = scheduler.wasp_adjust(farm, cfg, _pending_jobs(state.jobs),
                                 state.t)
    farm = scheduler.timer_transitions(farm, cfg, state.t)
    state = replace(state, farm=farm, sched=sched)

    if cfg.has_network:
        # rate recomputation is only needed while flows are in flight —
        # gate the (F, H) pass.  The no-flow branch must still ZERO
        # link_flows (recompute_rates would): reusing last step's counts
        # would pin ports ACTIVE forever after the final flow completes.
        flows, link_flows = jax.lax.cond(
            state.flows.active.any(),
            lambda args: net_mod.recompute_rates(args[0], tc, state.t),
            lambda args: (args[0], jnp.zeros_like(args[1])),
            (state.flows, state.net.link_flows))
        net = net_mod.update_switch_states(state.net, link_flows, tc,
                                           cfg, state.t)
        state = replace(state, flows=flows, net=net)

    if telemetry_on:
        state = replace(state, telem=telemetry.accumulate(
            state.telem, cfg, state.jobs, old_job_finish, old_task_finish,
            widx, wvals))

    all_done = (~state.jobs.valid
                | (state.jobs.status == TaskStatus.DONE)).all() \
        & (_next_arrival(state.jobs) >= INF)
    if cfg.has_network:
        all_done = all_done & ~state.flows.active.any()
    return replace(state, events=state.events + 1, done=all_done)


def init_state(cfg: SimConfig, jobs: JobTable, topo=None,
               racks=None) -> SimState:
    """``racks`` — optional (N,) host array of rack ids for the thermal
    recirculation grouping; defaults to the topology's first-hop-switch
    grouping when a topo is given, else ``i // thermal.rack_size``."""
    if cfg.has_network and topo is None:
        raise ValueError(
            "cfg.has_network=True requires a topology: pass topo= "
            "(flows would silently never route with tc=None)")
    if cfg.sched_policy == SchedPolicy.THERMAL_AWARE \
            and not cfg.thermal.enabled:
        raise ValueError(
            "SchedPolicy.THERMAL_AWARE requires cfg.thermal.enabled=True "
            "(placement would silently ignore temperatures)")
    tc = net_mod.topo_consts(topo) if (topo is not None and
                                       cfg.has_network) else None
    if racks is None and topo is not None and cfg.thermal.enabled:
        from . import topology as topo_mod
        racks = topo_mod.rack_of_servers(topo, cfg.thermal.rack_size)
    n_sw = topo.n_switches if topo is not None else 0
    n_ports = topo.n_ports if topo is not None else 1
    n_links = topo.n_links if topo is not None else 1
    n_lc = topo.n_linecards if topo is not None else 1
    state = SimState(
        t=jnp.zeros((), cfg.time_dtype),
        farm=init_farm(cfg),
        jobs=jobs,
        flows=init_flows(cfg),
        net=init_net(n_sw, n_ports, n_links, n_lc, cfg),
        sched=init_sched(cfg),
        telem=telemetry.init_telemetry(cfg),
        thermal=thermal_mod.init_thermal(cfg, racks),
        events=jnp.zeros((), jnp.int32),
        done=jnp.zeros((), bool),
    )
    return state, tc


@functools.partial(jax.jit, static_argnames=("cfg",))
def run(state: SimState, cfg: SimConfig, tc=None) -> SimState:
    """Run to completion (or cfg.max_events) under lax.while_loop."""
    def cond(s):
        return (~s.done) & (s.events < cfg.max_events)

    return jax.lax.while_loop(cond, lambda s: sim_step(s, cfg, tc), state)

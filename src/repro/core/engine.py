"""The event-driven simulation engine, vectorized for TPU (DESIGN.md §3).

The paper's sequential priority-queue loop becomes:

    while not done:
        t_next = min over all dense candidate-event arrays      (VPU reduce)
        accrue energy for (t_next - t)                          (exact: state
                                                                 is piecewise
                                                                 constant)
        apply ALL events with time <= t_next as masked updates  (dense)

Semantics are identical to a heap-based DES — we always advance to the
global minimum event time, so there is no time-discretization error.  The
per-iteration work is O(state) streaming instead of O(log n) pointer
chasing, which is exactly the trade the TPU wants; `kernels/dcsim_step.py`
fuses the min-reduction + energy accrual of the hot loop into one VMEM pass.

Event sources:
  job arrival            jobs.arrival[arr_ptr]
  task completion        min core_busy_until
  wake completion        min srv_wake_at
  delay-timer expiry     scheduler.next_timer_event
  flow completion        min flows.done_at          (network mode)
  pending work           t (now) when READY tasks await placement

Scheduling/assignment model: the global scheduler assigns servers to ALL
tasks of a job at arrival (policy-driven, sequential over the job's <=T
tasks).  When a parent task finishes, each DAG edge either decrements the
child's dep_count immediately (no network / same server / zero bytes) or
spawns a flow parent_server -> child_server; the flow's completion
decrements it.  dep_count==0 turns a task READY; READY tasks are drained
(bounded per step) into their server's local ring queue, waking sleeping
servers on demand.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import network as net_mod
from . import power, scheduler, server, telemetry
from .types import (INF, FlowTable, JobTable, NetState, SchedState,
                    ServerFarm, SimConfig, SimState, SrvState, TaskStatus,
                    init_farm, init_flows, init_net, init_sched, replace)


# ==========================================================================
# helpers
# ==========================================================================

def _active_jobs(jobs: JobTable) -> jnp.ndarray:
    """Tasks in flight (READY/QUEUED/RUNNING) — the provisioning load
    metric."""
    s = jobs.status
    return ((s == TaskStatus.READY) | (s == TaskStatus.QUEUED)
            | (s == TaskStatus.RUNNING)).sum()


def _pending_jobs(jobs: JobTable) -> jnp.ndarray:
    """Tasks waiting for a core (READY/QUEUED, excluding RUNNING) — the
    WASP pool metric (paper §IV-C: 'pending jobs per server'); counting
    running tasks would stop multi-core servers from ever consolidating."""
    s = jobs.status
    return ((s == TaskStatus.READY) | (s == TaskStatus.QUEUED)).sum()


def _next_arrival(jobs: JobTable) -> jnp.ndarray:
    J = jobs.arrival.shape[0]
    return jnp.where(jobs.arr_ptr < J,
                     jobs.arrival[jnp.clip(jobs.arr_ptr, 0, J - 1)], INF)


def next_event_time(state: SimState, cfg: SimConfig) -> jnp.ndarray:
    cands = [
        _next_arrival(state.jobs),
        state.farm.core_busy_until.min(),
        state.farm.srv_wake_at.min(),
        scheduler.next_timer_event(state.farm, cfg),
    ]
    if cfg.has_network:
        cands.append(state.flows.done_at.min())
    t_next = functools.reduce(jnp.minimum, cands)
    # pending READY tasks (or queued work on awake free cores) execute "now"
    ready = (state.jobs.status == TaskStatus.READY).any()
    awake = (state.farm.srv_state == SrvState.ACTIVE) \
        | (state.farm.srv_state == SrvState.IDLE)
    startable = (awake & (state.farm.q_len > 0)
                 & (state.farm.core_busy_until >= INF).any(axis=1)).any()
    t_next = jnp.where(ready | startable, state.t, t_next)
    return jnp.maximum(t_next, state.t).astype(cfg.time_dtype)


# ==========================================================================
# event appliers
# ==========================================================================

def _apply_wakeups(farm: ServerFarm, cfg, now):
    done = (farm.srv_state == SrvState.WAKING) & (farm.srv_wake_at <= now)
    return replace(
        farm,
        srv_state=jnp.where(done, SrvState.IDLE, farm.srv_state),
        srv_wake_at=jnp.where(done, INF, farm.srv_wake_at),
        srv_idle_since=jnp.where(done, now, farm.srv_idle_since))


def _apply_completions(state: SimState, cfg: SimConfig, tc=None):
    """Handle all cores whose busy_until <= now.  Marks tasks DONE, updates
    job bookkeeping, and resolves DAG edges (immediate dep decrement or
    flow spawn)."""
    farm, jobs, flows, net = state.farm, state.jobs, state.flows, state.net
    now = state.t
    N, C = farm.core_busy_until.shape
    T = cfg.tasks_per_job
    JT = jobs.status.shape[0]
    done_mask = farm.core_busy_until <= now                       # (N, C)
    tid = jnp.where(done_mask, farm.core_task, -1)                # (N, C)
    flat_tid = tid.reshape(-1)
    valid = flat_tid >= 0
    safe_tid = jnp.clip(flat_tid, 0)
    # scatter index with out-of-bounds sentinel: clipping -1 to 0 would make
    # every inactive core slot write a STALE value into task 0 (duplicate
    # scatter .set is non-deterministic); mode="drop" discards them instead
    sc_tid = jnp.where(valid, flat_tid, JT)

    # free the cores
    farm = replace(
        farm,
        core_busy_until=jnp.where(done_mask, INF, farm.core_busy_until),
        core_task=jnp.where(done_mask, -1, farm.core_task))

    # mark DONE + record finish time
    status = jobs.status.at[sc_tid].set(TaskStatus.DONE, mode="drop")
    finish = jobs.finish.at[sc_tid].set(now, mode="drop")

    # per-job completion counters
    tasks_done = jobs.tasks_done.at[safe_tid // T].add(
        jnp.where(valid, 1, 0).astype(jnp.int32))
    n_valid_tasks = jobs.valid.reshape(-1, T).sum(axis=1)
    job_complete = (tasks_done >= n_valid_tasks) & (tasks_done > 0)
    job_finish = jnp.where(job_complete & (jobs.job_finish >= INF),
                           now, jobs.job_finish)

    # DAG edges: children of completed tasks
    ch = jobs.children[safe_tid]                                  # (NC, D)
    eb = jobs.edge_bytes[safe_tid]
    ch_valid = (ch >= 0) & valid[:, None] & ~jobs.edge_sent[safe_tid]
    edge_sent = jobs.edge_sent.at[sc_tid].set(
        jobs.edge_sent[safe_tid] | ch_valid, mode="drop")

    dep_count = jobs.dep_count
    if cfg.has_network:
        # same-server or zero-byte edges resolve immediately; others spawn
        # flows sequentially (bounded: N*C*D small in network configs)
        src_srv = jobs.server[safe_tid]                           # (NC,)
        dst_srv = jobs.server[jnp.clip(ch, 0)]                    # (NC, D)
        needs_flow = ch_valid & (eb > 0) & (dst_srv != src_srv[:, None])
        immediate = ch_valid & ~needs_flow
        dep_count = dep_count.at[jnp.clip(ch, 0).reshape(-1)].add(
            -immediate.reshape(-1).astype(jnp.int32), mode="drop")

        flat = needs_flow.reshape(-1)
        f_src = jnp.broadcast_to(src_srv[:, None], ch.shape).reshape(-1)
        f_dst = dst_srv.reshape(-1)
        f_bytes = eb.reshape(-1)
        f_child = ch.reshape(-1)

        def spawn_one(i, carry):
            flows, net = carry
            def do(args):
                flows, net = args
                fl, nt, ok = net_mod.spawn_flow(
                    flows, net, tc, cfg, f_src[i], f_dst[i],
                    f_bytes[i], f_child[i], now)
                return fl, nt
            return jax.lax.cond(flat[i], do, lambda a: a, (flows, net))

        flows, net = jax.lax.fori_loop(0, flat.shape[0], spawn_one,
                                       (flows, net))
    else:
        dep_count = dep_count.at[jnp.clip(ch, 0).reshape(-1)].add(
            -ch_valid.reshape(-1).astype(jnp.int32), mode="drop")

    # BLOCKED -> READY where deps are now satisfied (only arrived jobs)
    arrived = jnp.arange(jobs.status.shape[0]) // T < jobs.arr_ptr
    becomes_ready = (status == TaskStatus.BLOCKED) & (dep_count <= 0) \
        & arrived
    status = jnp.where(becomes_ready, TaskStatus.READY, status)

    jobs = replace(jobs, status=status, finish=finish,
                   tasks_done=tasks_done, job_finish=job_finish,
                   dep_count=dep_count, edge_sent=edge_sent)
    return replace(state, farm=farm, jobs=jobs, flows=flows, net=net)


def _apply_flow_completions(state: SimState, cfg: SimConfig):
    flows, fin = net_mod.complete_flows(state.flows, state.t)
    child = jnp.where(fin, flows.child, -1)
    dep_count = state.jobs.dep_count.at[jnp.clip(child, 0)].add(
        -fin.astype(jnp.int32), mode="drop")
    T = cfg.tasks_per_job
    arrived = jnp.arange(dep_count.shape[0]) // T < state.jobs.arr_ptr
    ready = (state.jobs.status == TaskStatus.BLOCKED) & (dep_count <= 0) \
        & arrived
    status = jnp.where(ready, TaskStatus.READY, state.jobs.status)
    return replace(state, flows=flows,
                   jobs=replace(state.jobs, dep_count=dep_count,
                                status=status))


def _apply_arrival(state: SimState, cfg: SimConfig, tc=None):
    """Admit ONE job whose arrival <= t: assign servers to all its tasks
    (policy), mark roots READY."""
    jobs, farm, sched = state.jobs, state.farm, state.sched
    J = jobs.arrival.shape[0]
    T = cfg.tasks_per_job
    j = jobs.arr_ptr
    nxt = jobs.arrival[jnp.clip(j, 0, J - 1)]
    can = (j < J) & (nxt <= state.t) & (nxt < INF / 2)

    def admit(args):
        jobs, farm, sched = args
        base = j * T

        def assign_one(i, carry):
            jobs, farm, sched = carry
            tid = base + i
            is_valid = jobs.valid[tid]
            net_cost = None
            if cfg.has_network and \
                    cfg.sched_policy == scheduler.SchedPolicy.NETWORK_AWARE:
                # wake cost from the front-end (server 0) to each server
                costs = jax.vmap(
                    lambda d: net_mod.route_wake_cost(
                        tc, state.net, jnp.int32(0), d)
                )(jnp.arange(cfg.n_servers))
                net_cost = costs
            srv, rr = scheduler.pick_server(farm, cfg, sched, net_cost)
            server_arr = jobs.server.at[tid].set(
                jnp.where(is_valid, srv, jobs.server[tid]))
            sched = replace(sched, rr_ptr=jnp.where(is_valid, rr,
                                                    sched.rr_ptr))
            return replace(jobs, server=server_arr), farm, sched

        jobs, farm, sched = jax.lax.fori_loop(
            0, T, assign_one, (jobs, farm, sched))

        # roots -> READY
        tids = base + jnp.arange(T)
        root = jobs.valid[tids] & (jobs.dep_count[tids] <= 0)
        status = jobs.status.at[tids].set(
            jnp.where(root, TaskStatus.READY, jobs.status[tids]))
        jobs = replace(jobs, status=status, arr_ptr=j + 1)
        return jobs, farm, sched

    jobs, farm, sched = jax.lax.cond(
        can, admit, lambda a: a, (jobs, farm, sched))
    return replace(state, jobs=jobs, farm=farm, sched=sched)


def _drain_ready(state: SimState, cfg: SimConfig):
    """Enqueue up to cfg.ready_per_step READY tasks at their servers."""
    def one(_, st):
        jobs, farm = st.jobs, st.farm
        is_ready = jobs.status == TaskStatus.READY
        any_ready = is_ready.any()
        tid = jnp.argmax(is_ready)                      # first READY
        srv = jobs.server[tid]

        def do(st):
            jobs, farm = st.jobs, st.farm
            farm2, ok = server.queue_push(farm, cfg, srv, tid)
            farm2 = server.begin_wake(farm2, cfg, srv, st.t)
            status = jobs.status.at[tid].set(
                jnp.where(ok, TaskStatus.QUEUED, TaskStatus.DONE))
            # a dropped task counts as finished-with-drop (stat recorded)
            jobs2 = replace(jobs, status=status)
            return replace(st, jobs=jobs2, farm=farm2)

        return jax.lax.cond(any_ready, do, lambda s: s, st)

    return jax.lax.fori_loop(0, cfg.ready_per_step, one, state)


def _start_tasks(state: SimState, cfg: SimConfig):
    farm, started = server.try_start(
        state.farm, cfg, state.jobs.service, state.t)
    sid = started.reshape(-1)
    JT = state.jobs.status.shape[0]
    sc = jnp.where(sid >= 0, sid, JT)          # drop-sentinel (see above)
    status = state.jobs.status.at[sc].set(TaskStatus.RUNNING, mode="drop")
    return replace(state, farm=farm, jobs=replace(state.jobs, status=status))


# ==========================================================================
# the step
# ==========================================================================

def sim_step(state: SimState, cfg: SimConfig, tc=None) -> SimState:
    t_next = next_event_time(state, cfg)
    # a t_next at the INF sentinel means "no pending events": freeze time
    # (the done check below will terminate the loop) instead of integrating
    # energy over an unbounded interval
    t_next = jnp.where(t_next >= INF / 2, state.t, t_next)
    dt = t_next - state.t

    telemetry_on = cfg.telemetry.enabled
    if telemetry_on:
        # window metrics integrate the PRE-advance state over [t, t_next)
        # (piecewise constant, same exactness as the energy accrual);
        # finish arrays are captured so the INF -> finite transition below
        # identifies this step's completions.
        wvals = telemetry.window_values(state, cfg, dt)
        widx = telemetry.window_index(state.t, dt, cfg.telemetry)
        old_job_finish = state.jobs.job_finish
        old_task_finish = state.jobs.finish

    farm = power.accrue_server_energy(state.farm, cfg, dt)
    net = state.net
    if cfg.has_network:
        net = power.accrue_switch_energy(net, cfg, dt)
    state = replace(state, farm=farm, net=net, t=t_next)

    state = replace(state, farm=_apply_wakeups(state.farm, cfg, state.t))
    state = _apply_completions(state, cfg, tc)
    if cfg.has_network:
        state = _apply_flow_completions(state, cfg)
    state = _apply_arrival(state, cfg, tc)
    state = _drain_ready(state, cfg)
    state = _start_tasks(state, cfg)

    # refresh ACTIVE/IDLE, run local power controllers + pool managers
    farm = server.refresh_idle_state(state.farm, cfg, state.t)
    active = _active_jobs(state.jobs)
    farm, sched = scheduler.provisioning_adjust(farm, cfg, state.sched,
                                                active)
    farm = scheduler.wasp_adjust(farm, cfg, _pending_jobs(state.jobs),
                                 state.t)
    farm = scheduler.timer_transitions(farm, cfg, state.t)
    state = replace(state, farm=farm, sched=sched)

    if cfg.has_network:
        flows, link_flows = net_mod.recompute_rates(state.flows, tc,
                                                    state.t)
        net = net_mod.update_switch_states(state.net, link_flows, tc,
                                           cfg, state.t)
        state = replace(state, flows=flows, net=net)

    if telemetry_on:
        state = replace(state, telem=telemetry.accumulate(
            state.telem, cfg, state.jobs, old_job_finish, old_task_finish,
            widx, wvals))

    all_done = (~state.jobs.valid
                | (state.jobs.status == TaskStatus.DONE)).all() \
        & (_next_arrival(state.jobs) >= INF)
    if cfg.has_network:
        all_done = all_done & ~state.flows.active.any()
    return replace(state, events=state.events + 1, done=all_done)


def init_state(cfg: SimConfig, jobs: JobTable, topo=None) -> SimState:
    tc = net_mod.topo_consts(topo) if (topo is not None and
                                       cfg.has_network) else None
    n_sw = topo.n_switches if topo is not None else 0
    n_ports = topo.n_ports if topo is not None else 1
    n_links = topo.n_links if topo is not None else 1
    n_lc = topo.n_linecards if topo is not None else 1
    state = SimState(
        t=jnp.zeros((), cfg.time_dtype),
        farm=init_farm(cfg),
        jobs=jobs,
        flows=init_flows(cfg),
        net=init_net(n_sw, n_ports, n_links, n_lc, cfg),
        sched=init_sched(cfg),
        telem=telemetry.init_telemetry(cfg),
        events=jnp.zeros((), jnp.int32),
        done=jnp.zeros((), bool),
    )
    return state, tc


@functools.partial(jax.jit, static_argnames=("cfg",))
def run(state: SimState, cfg: SimConfig, tc=None) -> SimState:
    """Run to completion (or cfg.max_events) under lax.while_loop."""
    def cond(s):
        return (~s.done) & (s.events < cfg.max_events)

    return jax.lax.while_loop(cond, lambda s: sim_step(s, cfg, tc), state)

"""The event-driven simulation engine, vectorized for TPU (DESIGN.md §3).

The paper's sequential priority-queue loop becomes:

    while not done:
        t_next = min over all dense candidate-event arrays      (VPU reduce)
        accrue energy for (t_next - t)                          (exact: state
                                                                 is piecewise
                                                                 constant)
        apply ALL events with time <= t_next as masked updates  (dense)

Semantics are identical to a heap-based DES — we always advance to the
global minimum event time, so there is no time-discretization error.  The
per-iteration work is O(state) streaming instead of O(log n) pointer
chasing, which is exactly the trade the TPU wants; `kernels/dcsim_step.py`
fuses the min-reduction + energy accrual + completion free of the hot loop
into one VMEM pass (enabled with ``cfg.use_kernel``).

Event sources:
  job arrival            jobs.arrival[arr_ptr]
  task completion        min core_busy_until
  wake completion        min srv_wake_at
  delay-timer expiry     scheduler.next_timer_event
  flow completion        min flows.done_at          (network mode)
  throttle crossing      thermal.next_crossing      (thermal throttling)
  pending work           t (now) when READY tasks await placement

Macro-stepping (``cfg.events_per_step``): one jitted sim_step retires up
to K successive event times.  The first K-1 run a CHEAP core — the full
advance/wakeup/completion/admission/drain/start pipeline minus the
expensive passes (flow completion + rate recompute, flow spawning,
throttle-crossing handling, latency-histogram binning) — and a gate stops
the chew whenever the pending event needs one of those, handing it to the
full step that always closes the macro-step.  The gating is conservative,
so final states are identical for every K; only the per-step event count
changes.  Latency binning is deferred to once per macro-step (the finish
arrays identify every completion since the macro began); window accrual
stays exact per interval.

Scheduling/assignment model: the global scheduler assigns servers to ALL
tasks of a job at arrival (policy-driven).  When a parent task finishes,
each DAG edge either decrements the child's dep_count immediately (no
network / same server / zero bytes) or spawns a flow parent_server ->
child_server; the flow's completion decrements it.  dep_count==0 turns a
task READY; READY tasks are drained (bounded per step) into their server's
task-major FIFO queue (status QUEUED + enqueue_seq stamp — see server.py),
waking sleeping servers on demand.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import network as net_mod
from . import power, scheduler, server, telemetry
from . import thermal as thermal_mod
from . import trace as trace_mod
from .types import (INF, JobTable, SchedPolicy,
                    ServerFarm, SimConfig, SimState,
                    SleepPolicy, SrvState, TaskStatus, TraceKind,
                    init_farm, init_flows, init_net, init_sched, replace)


# ==========================================================================
# helpers
# ==========================================================================

def _active_jobs(jobs: JobTable) -> jnp.ndarray:
    """Tasks in flight (READY/QUEUED/RUNNING) — the provisioning load
    metric."""
    s = jobs.status
    return ((s == TaskStatus.READY) | (s == TaskStatus.QUEUED)
            | (s == TaskStatus.RUNNING)).sum()


def _pending_jobs(jobs: JobTable) -> jnp.ndarray:
    """Tasks waiting for a core (READY/QUEUED, excluding RUNNING) — the
    WASP pool metric (paper §IV-C: 'pending jobs per server'); counting
    running tasks would stop multi-core servers from ever consolidating."""
    s = jobs.status
    return ((s == TaskStatus.READY) | (s == TaskStatus.QUEUED)).sum()


def _next_arrival(jobs: JobTable) -> jnp.ndarray:
    J = jobs.arrival.shape[0]
    return jnp.where(jobs.arr_ptr < J,
                     jobs.arrival[jnp.clip(jobs.arr_ptr, 0, J - 1)], INF)


def _deferral_on(cfg: SimConfig) -> bool:
    """Static: carbon-aware deferral machinery is traced only when the
    policy is CARBON_AWARE AND a finite signal threshold arms it —
    CARBON_AWARE with the default defer_threshold=INF is plain
    LOAD_BALANCE placement with zero extra step cost."""
    return cfg.sched_policy == SchedPolicy.CARBON_AWARE \
        and cfg.thermal.deferral


def _farm_candidates(state: SimState, cfg: SimConfig):
    """Candidate next-event time from arrivals + farm sources, with the
    READY/startable pin to ``now`` — everything the cheap core handles."""
    cands = [
        _next_arrival(state.jobs),
        state.farm.core_busy_until.min(),
        state.farm.srv_wake_at.min(),
        scheduler.next_timer_event(state.farm, cfg),
    ]
    if _deferral_on(cfg):
        # deferred-job releases (solved carbon down-crossing / deadline)
        # are ordinary events: the cheap core runs the release pass too
        cands.append(state.jobs.admit_at.min())
    if cfg.thermal.has_ctrl:
        # setpoint-controller ticks: applied right after the interval
        # advance in both the cheap and the full step
        cands.append(state.thermal.ctrl_next)
    t_next = functools.reduce(jnp.minimum, cands)
    # pending READY tasks (or queued work on awake free cores) execute "now"
    ready = (state.jobs.status == TaskStatus.READY).any()
    awake = (state.farm.srv_state == SrvState.ACTIVE) \
        | (state.farm.srv_state == SrvState.IDLE)
    startable = (awake & (state.farm.q_len > 0)
                 & (state.farm.core_busy_until >= INF).any(axis=1)).any()
    t_next = jnp.where(ready | startable, state.t, t_next)
    return jnp.maximum(t_next, state.t).astype(cfg.time_dtype)


def next_event_time(state: SimState, cfg: SimConfig) -> jnp.ndarray:
    t_next = _farm_candidates(state, cfg)
    if cfg.has_network:
        t_next = jnp.minimum(t_next, state.flows.done_at.min())
    if cfg.thermal.throttling:
        # throttle-threshold crossings are real events: the RC exponential
        # is solved for the crossing time, so throttling engages exactly
        # when the temperature reaches it, not at the next unrelated event
        t_next = jnp.minimum(t_next, thermal_mod.next_crossing(state, cfg))
    return jnp.maximum(t_next, state.t).astype(cfg.time_dtype)


# ==========================================================================
# interval advance (accrual phase)
# ==========================================================================

def _advance_interval(state: SimState, cfg: SimConfig, tc, t_next):
    """Integrate everything over the piecewise-constant interval
    [t, t_next) in one shared-pass sweep, then set t := t_next.

    The per-server power, busy count, and state one-hot are computed ONCE
    and shared by the energy/residency accrual, the telemetry window
    columns, and the thermal RC integrator (the seed step recomputed them
    in each subsystem).  With ``cfg.use_kernel`` the energy accrual +
    completion free runs in the fused Pallas kernel."""
    farm = state.farm
    dt = t_next - state.t
    with jax.named_scope("f32_domain"):
        # intentional exit from the clock domain: physics/energy math runs
        # in f32 regardless of time_dtype (audited — analysis/jaxpr_audit)
        dtf = dt.astype(jnp.float32)
    telemetry_on = cfg.telemetry.enabled
    thermal_on = cfg.thermal.enabled
    throttled = state.thermal.throttled if thermal_on else None
    need_p = telemetry_on or thermal_on or not cfg.use_kernel
    p_busy = power.server_power(farm, cfg, throttled) if need_p else None
    onehot = (farm.srv_state[:, None]
              == jnp.arange(SrvState.NUM)[None, :]).astype(jnp.float32)
    thermal_ctx = t_end = p_cool = p_sw_t = None
    if thermal_on:
        # one RC evaluation (recirculated inlet + exponential) and one
        # CRAC/COP evaluation shared by the telemetry columns and the
        # thermal integrator
        tcfg = cfg.thermal
        target = p_busy[0] * tcfg.r_th \
            + thermal_mod.inlet_temps(state.thermal, tcfg, state.t)
        alpha = 1.0 - jnp.exp(-dtf / tcfg.tau_th)
        t_end = state.thermal.t_srv \
            + (target - state.thermal.t_srv) * alpha
        p_sw_t = power.switch_power(state.net, cfg).sum() \
            if cfg.has_network else jnp.float32(0.0)
        p_cool = thermal_mod.cooling_power(p_busy[0], p_sw_t,
                                           state.thermal, tcfg)
        thermal_ctx = (target, alpha, t_end, p_cool)

    telem = state.telem
    if telemetry_on:
        # window metrics integrate the PRE-advance state over [t, t_next)
        # (piecewise constant, same exactness as the energy accrual)
        wvals = telemetry.window_values(state, cfg, dt, p_busy, onehot,
                                        thermal_ctx)
        widx = telemetry.window_index(state.t, dt, cfg.telemetry)
        # intervals past the window horizon still clamp into the last
        # window (conservation: columns keep integrating to the run
        # totals) but the clamped seconds are counted so summarize can
        # flag/NaN the contaminated last-window time-averages
        spill = telemetry.window_spill(state.t, dt, cfg.telemetry)
        telem = replace(telem, win=telem.win.at[widx].add(wvals),
                        win_overflow=telem.win_overflow + spill)

    if cfg.use_kernel:
        if cfg.time_dtype != jnp.float32:
            raise ValueError(
                "cfg.use_kernel requires time_dtype=float32: the fused "
                "advance kernel computes in f32, and the core_busy_until "
                "round-trip would silently destroy f64 precision")
        from ..kernels import dcsim_step
        sp = cfg.server_power
        table = jnp.asarray([sp.p_base, sp.p_base, sp.p_pkg_c6, sp.p_s3,
                             sp.p_off, sp.p_wake], jnp.float32)
        thr = throttled if cfg.thermal.throttling else None
        interp = jax.default_backend() != "tpu"
        nb, _done, en, bs, _cand = dcsim_step.dcsim_advance(
            farm.core_busy_until.astype(jnp.float32), farm.srv_state,
            farm.energy, farm.busy_core_seconds, state.t, t_next, table,
            sp.p_core_active, sp.p_core_idle,
            farm.srv_wake_at.astype(jnp.float32),
            farm.srv_idle_since.astype(jnp.float32),
            farm.srv_tau.astype(jnp.float32), throttled=thr,
            throttle_power_scale=cfg.thermal.throttle_power_scale,
            interpret=interp)
        farm = replace(farm,
                       core_busy_until=nb.astype(cfg.time_dtype),
                       energy=en, busy_core_seconds=bs,
                       residency=farm.residency + onehot * dtf)
    else:
        farm = power.accrue_server_energy(farm, cfg, dt, p_busy, onehot)

    net, flows = state.net, state.flows
    if cfg.has_network:
        net = power.accrue_switch_energy(net, cfg, dt)
        # drain the fluid model over the interval (rates are piecewise
        # constant, fixed at the last recompute)
        flows = net_mod.advance_flows(flows, dt)
    therm = state.thermal
    if thermal_on:
        therm = thermal_mod.advance(therm, cfg, p_busy[0], p_sw_t,
                                    state.t, dt, t_new=t_end,
                                    p_cool=p_cool)
    return replace(state, farm=farm, net=net, flows=flows, thermal=therm,
                   telem=telem, t=t_next)


# ==========================================================================
# event appliers
# ==========================================================================

def _rebuild_job_completion(jobs: JobTable, cfg: SimConfig, now):
    """(tasks_done, job_finish) rebuilt from task statuses: DONE is
    terminal (completions and drops both land there), so the per-job count
    is a pure function of the current statuses.  Newly-complete jobs get
    job_finish stamped at ``now``."""
    T = cfg.tasks_per_job
    tasks_done = ((jobs.status == TaskStatus.DONE)
                  & jobs.valid).reshape(-1, T).sum(axis=1,
                                                   dtype=jnp.int32)
    n_valid_tasks = jobs.valid.reshape(-1, T).sum(axis=1, dtype=jnp.int32)
    job_complete = (tasks_done >= n_valid_tasks) & (tasks_done > 0)
    job_finish = jnp.where(job_complete & (jobs.job_finish >= INF),
                           now, jobs.job_finish)
    return tasks_done, job_finish


def _promote_ready(jobs: JobTable, dep_count, cfg: SimConfig):
    """BLOCKED -> READY where deps are now satisfied (arrived jobs only).

    Carbon-deferred jobs are NOT promotable even though arr_ptr has moved
    past them (admission consumed their arrival slot): their zero-dep
    roots must stay BLOCKED until _apply_releases admits them — without
    the parked mask, any DAG-edge resolution between arrival and release
    would flip the parked roots READY on the server=-1 sentinel, running
    the job mid-high-carbon-window with no placement and no telemetry."""
    T = cfg.tasks_per_job
    arrived = jnp.arange(jobs.status.shape[0]) // T < jobs.arr_ptr
    if _deferral_on(cfg):
        parked = jnp.repeat(jobs.admit_at < INF / 2, T)
        arrived = arrived & ~parked
    ready = (jobs.status == TaskStatus.BLOCKED) & (dep_count <= 0) & arrived
    return jnp.where(ready, TaskStatus.READY, jobs.status)


def _apply_wakeups(farm: ServerFarm, cfg, now):
    done = (farm.srv_state == SrvState.WAKING) & (farm.srv_wake_at <= now)
    return replace(
        farm,
        srv_state=jnp.where(done, SrvState.IDLE, farm.srv_state),
        srv_wake_at=jnp.where(done, INF, farm.srv_wake_at),
        srv_idle_since=jnp.where(done, now, farm.srv_idle_since))


def _apply_completions(state: SimState, cfg: SimConfig, tc=None,
                       recs=None):
    """Handle all tasks whose task_end <= now.  Marks tasks DONE, updates
    job bookkeeping, and resolves DAG edges (immediate dep decrement or
    flow spawn).

    Everything is elementwise in task space: a RUNNING task with
    task_end <= now is complete (task_end was stamped with its core's
    busy_until at start), and its DAG edges live on task rows too — no
    core->task gather or scatter anywhere.  The core array just frees its
    expired slots elementwise."""
    farm, jobs, flows, net = state.farm, state.jobs, state.flows, state.net
    now = state.t
    T = cfg.tasks_per_job

    # free the cores (elementwise; a no-op for slots the fused kernel
    # already freed during the advance)
    done_core = farm.core_busy_until <= now                       # (N, C)
    farm = replace(
        farm,
        core_busy_until=jnp.where(done_core, INF, farm.core_busy_until))

    # mark DONE + record finish time (elementwise in task space)
    done_task = (jobs.status == TaskStatus.RUNNING) \
        & (jobs.task_end <= now)
    status = jnp.where(done_task, TaskStatus.DONE, jobs.status)
    finish = jnp.where(done_task, now, jobs.finish)
    jobs = replace(jobs, status=status, finish=finish)
    tasks_done, job_finish = _rebuild_job_completion(jobs, cfg, now)

    if cfg.trace.enabled:
        JT = jobs.status.shape[0]
        trace_mod.stage(
            recs, done_task, TraceKind.FINISH, jobs.server,
            jnp.arange(JT, dtype=jnp.int32), now - jobs.start_at)
        new_jf = (jobs.job_finish >= INF / 2) & (job_finish < INF / 2)
        J = job_finish.shape[0]
        trace_mod.stage(
            recs, new_jf, TraceKind.JOB_FINISH, -1,
            jnp.arange(J, dtype=jnp.int32), job_finish - jobs.arrival)
    jobs = replace(jobs, tasks_done=tasks_done, job_finish=job_finish)

    if T > 1:
        jobs, flows, net = _resolve_done_edges(
            jobs, flows, net, cfg, tc, done_task, now, recs)
    return replace(state, farm=farm, jobs=jobs, flows=flows, net=net)


def _resolve_done_edges(jobs, flows, net, cfg, tc, done_task, now,
                        recs=None):
    """DAG edges of tasks completed this step: immediate dep decrement or
    flow spawn, then BLOCKED -> READY.  Works on the COMPLETING tasks'
    rows only: at most N·C tasks can finish simultaneously (each RUNNING
    task occupies a core), so when the task table is wider than the core
    array the done set is first compacted into a (N·C,)-batch — exact,
    not a heuristic — and all edge math runs on (Kd, D) rows.  (The seed
    walked (N·C, D) core slots via a core->task gather; the task table
    carries the same information without the gather.)  Single-task jobs
    have no edges, so this is only traced for T > 1 and only runs when a
    task finished."""
    JT = jobs.status.shape[0]
    Kd = min(JT, cfg.n_servers * cfg.n_cores)
    D = jobs.children.shape[1]
    # flow-spawn records leave the cond as data (mask + payload lanes);
    # the identity branch hands back all-false/zero lanes
    spawn0 = (jnp.zeros((Kd * D,), bool),
              jnp.full((Kd * D,), -1, jnp.int32),
              jnp.full((Kd * D,), -1, jnp.int32),
              jnp.zeros((Kd * D,), jobs.edge_bytes.dtype))

    def resolve(args):
        jobs, flows, net, _ = args
        if Kd < JT:
            tid_b, valid_b, _ = server.compact_mask(done_task, Kd)
            tq = jnp.clip(tid_b, 0)
            ch = jobs.children[tq]                                # (Kd, D)
            eb = jobs.edge_bytes[tq]
            ch_valid = (ch >= 0) & valid_b[:, None] \
                & ~jobs.edge_sent[tq]
            edge_sent = jobs.edge_sent.at[
                jnp.where(valid_b, tid_b, JT)].set(
                jobs.edge_sent[tq] | ch_valid, mode="drop")
            src_of = jobs.server[tq]                              # (Kd,)
        else:
            ch = jobs.children                                    # (JT, D)
            eb = jobs.edge_bytes
            ch_valid = (ch >= 0) & done_task[:, None] & ~jobs.edge_sent
            edge_sent = jobs.edge_sent | ch_valid
            src_of = jobs.server

        dep_count = jobs.dep_count
        if cfg.has_network:
            # same-server or zero-byte edges resolve immediately; others
            # spawn flows parent_server -> child_server
            dst_srv = jobs.server[jnp.clip(ch, 0)]                # (Kd, D)
            needs_flow = ch_valid & (eb > 0) & (dst_srv != src_of[:, None])
            immediate = ch_valid & ~needs_flow
            dep_count = dep_count.at[jnp.clip(ch, 0).reshape(-1)].add(
                -immediate.reshape(-1).astype(jnp.int32), mode="drop")

            flat = needs_flow.reshape(-1)
            f_src = jnp.broadcast_to(src_of[:, None], ch.shape).reshape(-1)
            f_dst = dst_srv.reshape(-1)
            f_bytes = eb.reshape(-1)
            f_child = ch.reshape(-1)

            no_fail = jnp.zeros_like(flat)
            if cfg.use_vectorized_hot_loop:
                def spawn(args):
                    flows, net, _ = args
                    flows, net, ok = net_mod.spawn_flows_many(
                        flows, net, tc, cfg, flat, f_src, f_dst, f_bytes,
                        f_child, now)
                    return flows, net, flat & ~ok

                # most steps spawn nothing — gate the dense pass
                flows, net, failed = jax.lax.cond(
                    flat.any(), spawn, lambda a: a, (flows, net, no_fail))
            else:
                def spawn_one(i, carry):
                    flows, net, failed = carry

                    def do(args):
                        flows, net, failed = args
                        fl, nt, ok = net_mod.spawn_flow(
                            flows, net, tc, cfg, f_src[i], f_dst[i],
                            f_bytes[i], f_child[i], now)
                        return fl, nt, failed.at[i].set(~ok)
                    return jax.lax.cond(flat[i], do, lambda a: a,
                                        (flows, net, failed))

                flows, net, failed = jax.lax.fori_loop(
                    0, flat.shape[0], spawn_one, (flows, net, no_fail))

            # a full FlowTable drop-resolves the edge like the queue-drop
            # path: the child's dep decrements immediately (the results
            # simply never ship) instead of leaving it BLOCKED forever;
            # the spawn primitives count the drop in flows.flows_dropped
            dep_count = dep_count.at[jnp.where(failed, f_child, JT)].add(
                -1, mode="drop")
            spawned = (flat & ~failed, f_src, f_child, f_bytes)
        else:
            dep_count = dep_count.at[jnp.clip(ch, 0).reshape(-1)].add(
                -ch_valid.reshape(-1).astype(jnp.int32), mode="drop")
            spawned = spawn0

        status = _promote_ready(jobs, dep_count, cfg)
        jobs = replace(jobs, status=status, dep_count=dep_count,
                       edge_sent=edge_sent)
        return jobs, flows, net, spawned

    jobs, flows, net, spawned = jax.lax.cond(
        done_task.any(), resolve, lambda a: a, (jobs, flows, net, spawn0))
    if cfg.trace.enabled and cfg.has_network:
        sm, s_src, s_child, s_bytes = spawned
        trace_mod.stage(recs, sm, TraceKind.FLOW_SPAWN, s_src, s_child,
                        s_bytes)
    return jobs, flows, net


def _apply_flow_completions(state: SimState, cfg: SimConfig, recs=None):
    flows, fin = net_mod.complete_flows(state.flows, state.t)

    def resolve(jobs):
        child = jnp.where(fin, flows.child, -1)
        dep_count = jobs.dep_count.at[jnp.clip(child, 0)].add(
            -fin.astype(jnp.int32), mode="drop")
        status = _promote_ready(jobs, dep_count, cfg)
        return replace(jobs, dep_count=dep_count, status=status)

    jobs = jax.lax.cond(fin.any(), resolve, lambda j: j, state.jobs)
    if cfg.trace.enabled:
        # complete_flows keeps dst/child on deactivated rows, so the
        # delivered edge is still addressable here
        trace_mod.stage(recs, fin, TraceKind.FLOW_FINISH, flows.dst,
                        flows.child)
    return replace(state, flows=flows, jobs=jobs)


def _apply_arrival(state: SimState, cfg: SimConfig, tc=None, hold=None,
                   recs=None):
    """Admit up to cfg.arrivals_per_step jobs whose arrival <= t in one
    pass: assign servers to all their tasks (policy), mark roots READY.

    All jobs admitted in the same step share one scheduler snapshot —
    admission itself never changes server load (queue pushes happen later,
    at READY drain), so the batched pass equals K sequential picks against
    the same farm, exactly the ``pick_servers_for_job`` argument one level
    up.  Same-timestamp bursts (MMPP high state, trace replays) therefore
    no longer serialize one step per job."""
    jobs, farm, sched = state.jobs, state.farm, state.sched
    J = jobs.arrival.shape[0]
    T = cfg.tasks_per_job
    K = cfg.arrivals_per_step
    j0 = jobs.arr_ptr
    jid = j0 + jnp.arange(K)
    nxt = jobs.arrival[jnp.clip(jid, 0, J - 1)]
    elig = (jid < J) & (nxt <= state.t) & (nxt < INF / 2)
    # arrivals are sorted, so eligibility is a prefix; enforce it anyway
    # so an unsorted table degrades to the old one-at-a-time behavior
    elig = jnp.cumprod(elig.astype(jnp.int32)).astype(bool)
    if hold is not None:
        # deferred releases strictly precede fresh arrivals at the same
        # instant: while this step entered with due-but-unreleased jobs,
        # hold arrivals for the next same-time step — the oracle admits
        # (and enqueues) every release chunk before popping a coincident
        # arrival event, so an arrival admitted in the same step as a
        # release chunk would see a load snapshot missing that chunk's
        # not-yet-drained roots
        elig = elig & ~hold
    # pinned accumulator dtype: under jax_enable_x64 a bare bool sum lands
    # int64 and poisons arr_ptr's branch dtypes (found by the simlint
    # f64-clock twin configs)
    n_adm = elig.sum(dtype=jnp.int32)

    def _net_cost():
        if cfg.has_network and \
                cfg.sched_policy == SchedPolicy.NETWORK_AWARE:
            # wake cost from the front-end (server 0) to each server; the
            # net state does not change during admission, so one
            # evaluation serves every task of the batch
            return jax.vmap(
                lambda d: net_mod.route_wake_cost(
                    tc, state.net, jnp.int32(0), d)
            )(jnp.arange(cfg.n_servers))
        return None

    def _temp():
        if cfg.thermal.enabled and \
                cfg.sched_policy == SchedPolicy.THERMAL_AWARE:
            return state.thermal.t_srv
        return None

    def admit(args):
        jobs, farm, sched = args
        JT = jobs.status.shape[0]
        if _deferral_on(cfg):
            # carbon-aware deferral: deferrable jobs arriving while the
            # carbon/price signal exceeds the threshold are NOT admitted;
            # they park with a release time (solved sinusoid down-crossing
            # or their deadline, whichever first) that becomes an event
            # candidate.  A release candidate at/before now — or none at
            # all — admits immediately, so deferral never deadlocks.
            tcfg = cfg.thermal
            jc = jnp.clip(jid, 0, J - 1)
            sig = thermal_mod.defer_signal_now(tcfg, state.t)
            rel = thermal_mod.next_release_time(tcfg, state.t)
            cand = jnp.minimum(rel.astype(cfg.time_dtype),
                               jobs.deadline[jc])
            dfr = (elig & jobs.deferrable[jc]
                   & (sig > tcfg.defer_threshold)
                   & (cand > state.t) & (cand < INF / 2))
            jobs = replace(jobs, admit_at=jobs.admit_at.at[
                jnp.where(dfr, jid, J)].set(
                jnp.where(dfr, cand, INF), mode="drop"))
            adm = elig & ~dfr
        else:
            adm = elig
        tids = j0 * T + jnp.arange(K * T)                  # flat task ids
        in_range = tids < JT
        sc = jnp.where(in_range, tids, JT)                 # scatter sentinel
        gather = jnp.clip(tids, 0, JT - 1)
        elig_t = jnp.repeat(adm, T)
        is_valid = jobs.valid[gather] & elig_t & in_range

        root = is_valid & (jobs.dep_count[gather] <= 0)

        if cfg.sched_policy == SchedPolicy.ROUND_ROBIN:
            if cfg.use_vectorized_hot_loop:
                # all K*T assignments in one shot (cumulative-offset
                # round-robin rank matching)
                srvs, rr_new = scheduler.pick_servers_for_job(
                    farm, cfg, sched, is_valid)
                server_arr = jobs.server.at[sc].set(
                    jnp.where(is_valid, srvs, jobs.server[gather]),
                    mode="drop")
                jobs = replace(jobs, server=server_arr)
                sched = replace(sched, rr_ptr=rr_new)
            else:
                def assign_one(i, carry):
                    jobs, sched = carry
                    tid = gather[i]
                    v = is_valid[i]
                    srv, rr = scheduler.pick_server(farm, cfg, sched)
                    server_arr = jobs.server.at[tid].set(
                        jnp.where(v, srv, jobs.server[tid]))
                    sched = replace(sched,
                                    rr_ptr=jnp.where(v, rr, sched.rr_ptr))
                    return replace(jobs, server=server_arr), sched

                jobs, sched = jax.lax.fori_loop(0, K * T, assign_one,
                                                (jobs, sched))
        else:
            # score policies: one pick PER JOB (the farm cannot change
            # during a single job's assignment), but job k's pick must see
            # the roots committed by jobs 0..k-1 of the same batch —
            # otherwise a same-timestamp burst piles onto the one argmin
            # server, where the old one-job-per-step path spread it (each
            # admit saw the previous job's drained roots as queue load)
            net_cost = _net_cost()
            temp = _temp()
            root_k = root.reshape(K, T)
            extra = jnp.zeros((cfg.n_servers,), jnp.float32)
            picks = []
            for k in range(K):                     # static unroll, K small
                srv_k, _ = scheduler.pick_server(farm, cfg, sched,
                                                 net_cost, temp, extra)
                extra = extra.at[srv_k].add(
                    root_k[k].sum().astype(jnp.float32))
                picks.append(srv_k)
            srvs = jnp.repeat(jnp.stack(picks), T)
            server_arr = jobs.server.at[sc].set(
                jnp.where(is_valid, srvs, jobs.server[gather]), mode="drop")
            jobs = replace(jobs, server=server_arr)

        # roots -> READY
        status = jobs.status.at[sc].set(
            jnp.where(root, TaskStatus.READY, jobs.status[gather]),
            mode="drop")
        return replace(jobs, status=status, arr_ptr=j0 + n_adm), farm, \
            sched

    jobs, farm, sched = jax.lax.cond(
        n_adm > 0, admit, lambda a: a, (jobs, farm, sched))
    if cfg.trace.enabled:
        # ARRIVAL for every job whose arrival slot was consumed this
        # chunk (deferred ones included), ADMIT only for placed jobs
        # (server = the job's first task's pick, aux = its queue depth).
        # Staged OUTSIDE the admit cond: admission wrote everything the
        # records need (deferral is visible as a finite admit_at, the
        # pick as the first task's server; q_len doesn't change until
        # the READY drain), and a skipped cond means elig is all-false.
        JT = jobs.status.shape[0]
        trace_mod.stage(recs, elig, TraceKind.ARRIVAL, -1,
                        jid.astype(jnp.int32))
        adm = elig
        if _deferral_on(cfg):
            adm = elig & ~(jobs.admit_at[jnp.clip(jid, 0, J - 1)]
                           < INF / 2)
        first = jnp.clip(j0 * T + jnp.arange(K) * T, 0, JT - 1)
        job_srv = jobs.server[first]
        trace_mod.stage(recs, adm, TraceKind.ADMIT, job_srv,
                        jid.astype(jnp.int32),
                        farm.q_len[jnp.clip(job_srv, 0)])
    return replace(state, jobs=jobs, farm=farm, sched=sched)


def _apply_releases(state: SimState, cfg: SimConfig, tc=None, recs=None):
    """Admit deferred jobs whose release time has come (CARBON_AWARE
    only): up to cfg.arrivals_per_step per step in ascending job id, one
    shared scheduler snapshot per step — mirroring batched arrival
    admission, so a window's worth of deferred jobs spreads exactly like
    a same-timestamp burst.  Leftover due jobs pin the next event to
    ``now`` (their admit_at is a next-event candidate) and release on the
    following step.  Runs BEFORE fresh-arrival admission: released jobs
    always carry lower ids than jobs arriving now, so the READY drain's
    ascending-tid order serves them first, matching the oracle's
    release-then-arrive event order.

    Also accrues the deferral telemetry: total deferred seconds, release
    count, and a first-order grams-avoided estimate (marginal job energy
    × the carbon-intensity drop between arrival and release)."""
    jobs = state.jobs
    now = state.t
    due = (jobs.admit_at < INF / 2) & (jobs.admit_at <= now)
    K0 = cfg.arrivals_per_step
    # released-job records leave the cond as data; the identity branch
    # hands back an all-invalid chunk
    rel0 = (jnp.zeros((K0,), bool), jnp.full((K0,), -1, jnp.int32),
            jnp.zeros((K0,), jnp.float32), jnp.zeros((K0,), jnp.int32))

    def release(args):
        jobs, therm, _ = args
        farm, sched = state.farm, state.sched
        J = jobs.arrival.shape[0]
        T = cfg.tasks_per_job
        JT = jobs.status.shape[0]
        K = cfg.arrivals_per_step
        jid_b, jvalid, _ = server.compact_mask(due, K)            # (K,)
        jq = jnp.clip(jid_b, 0, J - 1)

        tids = (jq[:, None] * T + jnp.arange(T)[None, :]).reshape(-1)
        gather = jnp.clip(tids, 0, JT - 1)
        valid_t = jnp.repeat(jvalid, T)
        sc = jnp.where(valid_t, tids, JT)
        is_valid = jobs.valid[gather] & valid_t
        # BLOCKED check: only still-parked roots flip READY (a repeated
        # release of an already-processed row must never re-run a task)
        root = is_valid & (jobs.dep_count[gather] <= 0) \
            & (jobs.status[gather] == TaskStatus.BLOCKED)

        # per-job picks against one farm snapshot, with in-batch root
        # commitments as extra load — the same machinery as the score-
        # policy arrival batch (CARBON_AWARE places by load)
        root_k = root.reshape(K, T)
        extra = jnp.zeros((cfg.n_servers,), jnp.float32)
        picks = []
        for k in range(K):                         # static unroll, K small
            srv_k, _ = scheduler.pick_server(farm, cfg, sched,
                                             None, None, extra)
            extra = extra.at[srv_k].add(
                root_k[k].sum().astype(jnp.float32))
            picks.append(srv_k)
        srvs = jnp.repeat(jnp.stack(picks), T)
        server_arr = jobs.server.at[sc].set(
            jnp.where(is_valid, srvs, jobs.server[gather]), mode="drop")
        status = jobs.status.at[sc].set(
            jnp.where(root, TaskStatus.READY, jobs.status[gather]),
            mode="drop")
        admit_at = jobs.admit_at.at[jnp.where(jvalid, jid_b, J)].set(
            INF, mode="drop")
        jobs = replace(jobs, server=server_arr, status=status,
                       admit_at=admit_at)

        tcfg = cfg.thermal
        arr_j = jobs.arrival[jq]
        waited = jnp.where(jvalid, (now - arr_j).astype(jnp.float32), 0.0)
        ci_arr = thermal_mod.carbon_intensity_now(tcfg, arr_j)    # (K,)
        ci_now = thermal_mod.carbon_intensity_now(tcfg, now)
        sp = cfg.server_power
        e_kwh = jobs.service.reshape(-1, T)[jq].sum(axis=1) \
            * jnp.float32((sp.p_core_active - sp.p_core_idle) / 3.6e6)
        avoided = jnp.where(jvalid, (ci_arr - ci_now) * e_kwh, 0.0)
        therm = replace(
            therm,
            defer_seconds=therm.defer_seconds + waited.sum(),
            defer_count=therm.defer_count
            + jvalid.sum().astype(jnp.int32),
            grams_avoided=therm.grams_avoided + avoided.sum())

        return jobs, therm, (jvalid, jid_b, waited, jnp.stack(picks))

    jobs, therm, rel = jax.lax.cond(due.any(), release, lambda a: a,
                                    (jobs, state.thermal, rel0))
    if cfg.trace.enabled:
        jvalid, jid_b, waited, picks_j = rel
        trace_mod.stage(recs, jvalid, TraceKind.RELEASE, -1, jid_b,
                        waited)
        trace_mod.stage(recs, jvalid, TraceKind.ADMIT, picks_j, jid_b,
                        state.farm.q_len[jnp.clip(picks_j, 0)])
    return replace(state, jobs=jobs, thermal=therm)


def _resolve_drops(state: SimState, cfg: SimConfig, dropped, recs=None):
    """Complete the bookkeeping for tasks dropped by a full queue
    (dropped (JT,) bool, already marked DONE by the drain).

    Without this, a drop deadlocks DAG workloads: the task is DONE but its
    children's dep_count never reaches zero, so they stay BLOCKED forever
    and the sim spins to max_events.  A dropped task counts toward job
    completion (finish/job_finish stamped at drop time, flagged via
    farm.dropped) and resolves its DAG edges immediately — it never ran,
    so there are no results to ship and no flows to spawn.

    Gated on dropped.any(): overflow is the exception, and the healthy
    path must not pay the bookkeeping every step.
    """
    now = state.t

    def resolve(jobs):
        finish = jnp.where(dropped, now, jobs.finish)
        # drops were already marked DONE by the drain
        tasks_done, job_finish = _rebuild_job_completion(jobs, cfg, now)

        ch = jobs.children                           # (JT, D)
        ch_valid = (ch >= 0) & dropped[:, None] & ~jobs.edge_sent
        edge_sent = jobs.edge_sent | ch_valid
        dep_count = jobs.dep_count.at[jnp.clip(ch, 0).reshape(-1)].add(
            -ch_valid.reshape(-1).astype(jnp.int32), mode="drop")

        status = _promote_ready(jobs, dep_count, cfg)
        return replace(jobs, status=status, finish=finish,
                       tasks_done=tasks_done, job_finish=job_finish,
                       dep_count=dep_count, edge_sent=edge_sent)

    jobs = jax.lax.cond(dropped.any(), resolve, lambda j: j, state.jobs)
    if cfg.trace.enabled:
        # staged outside the cond: the drop mask and the job table's
        # before/after finish stamps carry everything the records need
        JT = jobs.status.shape[0]
        trace_mod.stage(recs, dropped, TraceKind.DROP, jobs.server,
                        jnp.arange(JT, dtype=jnp.int32))
        new_jf = (state.jobs.job_finish >= INF / 2) \
            & (jobs.job_finish < INF / 2)
        J = jobs.job_finish.shape[0]
        trace_mod.stage(recs, new_jf, TraceKind.JOB_FINISH, -1,
                        jnp.arange(J, dtype=jnp.int32),
                        jobs.job_finish - jobs.arrival)
    return replace(state, jobs=jobs)


def _drain_ready(state: SimState, cfg: SimConfig, recs=None):
    """Enqueue up to cfg.ready_per_step READY tasks at their servers
    (first K in task-id order).  Queue-full drops are resolved afterwards
    (_resolve_drops); their newly-READY children drain on the next step —
    still at the same simulation time, since READY tasks pin t_next to t."""
    if cfg.use_vectorized_hot_loop:
        return _drain_ready_batched(state, cfg, recs)
    return _drain_ready_scalar(state, cfg, recs)


def _drain_ready_batched(state: SimState, cfg: SimConfig, recs=None):
    """One multi-push: the first K READY tasks become QUEUED with FIFO
    stamps written elementwise into their own task rows (no ring-slot
    scatter).  The whole pass is gated on "any READY task" so quiet steps
    stay free."""
    is_ready = state.jobs.status == TaskStatus.READY
    JT0 = state.jobs.status.shape[0]

    def drain(args):
        state, _ = args
        jobs, farm = state.jobs, state.farm
        K = cfg.ready_per_step
        JT = jobs.status.shape[0]
        r = jnp.cumsum(is_ready) - 1                # rank among READY
        sel = is_ready & (r < K)
        # gather selected tids into (K,) batch slots, ascending tid order
        tids = jnp.full((K,), -1, jnp.int32).at[jnp.where(sel, r, K)].set(
            jnp.arange(JT, dtype=jnp.int32), mode="drop")
        valid = tids >= 0
        srv = jnp.where(valid, jobs.server[jnp.clip(tids, 0)], -1)

        farm, ok, seq = server.queue_push_many(farm, cfg, srv, tids, valid)
        dest = jnp.zeros((cfg.n_servers,), bool).at[
            jnp.where(valid, srv, cfg.n_servers)].set(True, mode="drop")
        farm = server.begin_wake_mask(farm, cfg, dest, state.t)

        sc = jnp.where(valid, tids, JT)
        status = jobs.status.at[sc].set(
            jnp.where(ok, TaskStatus.QUEUED, TaskStatus.DONE), mode="drop")
        enq = jobs.enqueue_seq.at[jnp.where(valid & ok, tids, JT)].set(
            seq, mode="drop")
        state = replace(state, jobs=replace(jobs, status=status,
                                            enqueue_seq=enq), farm=farm)
        dropped = jnp.zeros((JT,), bool).at[
            jnp.where(valid & ~ok, tids, JT)].set(True, mode="drop")
        return state, dropped

    # drop resolution happens outside the drain cond so its trace
    # records can be staged (it re-gates itself on dropped.any())
    state, dropped = jax.lax.cond(
        is_ready.any(), drain, lambda a: a,
        (state, jnp.zeros((JT0,), bool)))
    return _resolve_drops(state, cfg, dropped, recs)


def _drain_ready_scalar(state: SimState, cfg: SimConfig, recs=None):
    """Seed reference path: K sequential scalar queue_push + begin_wake."""
    status_before = state.jobs.status

    def one(_, st):
        jobs, farm = st.jobs, st.farm
        is_ready = jobs.status == TaskStatus.READY
        any_ready = is_ready.any()
        tid = jnp.argmax(is_ready)                      # first READY
        srv = jobs.server[tid]

        def do(st):
            jobs, farm = st.jobs, st.farm
            farm2, ok, seq = server.queue_push(farm, cfg, srv, tid)
            farm2 = server.begin_wake(farm2, cfg, srv, st.t)
            status = jobs.status.at[tid].set(
                jnp.where(ok, TaskStatus.QUEUED, TaskStatus.DONE))
            enq = jobs.enqueue_seq.at[tid].set(
                jnp.where(ok, seq, jobs.enqueue_seq[tid]))
            jobs2 = replace(jobs, status=status, enqueue_seq=enq)
            return replace(st, jobs=jobs2, farm=farm2)

        return jax.lax.cond(any_ready, do, lambda s: s, st)

    state = jax.lax.fori_loop(0, cfg.ready_per_step, one, state)
    # READY -> DONE transitions during the loop are exactly the drops
    dropped = (status_before == TaskStatus.READY) \
        & (state.jobs.status == TaskStatus.DONE)
    return _resolve_drops(state, cfg, dropped, recs)


def _start_tasks(state: SimState, cfg: SimConfig, recs=None):
    # throttled servers start work at their reduced effective frequency;
    # freq=None keeps the untrottled scalar expression when thermal is off
    freq = thermal_mod.effective_freq(state.thermal, cfg) \
        if cfg.thermal.throttling else None
    farm, jobs = server.try_start(state.farm, cfg, state.jobs, state.t,
                                  freq)
    if cfg.trace.enabled:
        started = (jobs.status == TaskStatus.RUNNING) \
            & (state.jobs.status == TaskStatus.QUEUED)
        JT = jobs.status.shape[0]
        trace_mod.stage(recs, started, TraceKind.START, jobs.server,
                        jnp.arange(JT, dtype=jnp.int32),
                        jobs.task_end - state.t)
    return replace(state, farm=farm, jobs=jobs)


def _apply_events(state: SimState, cfg: SimConfig, tc, cheap: bool,
                  recs=None):
    """The event-application pipeline at the (already advanced) time
    state.t.  ``cheap`` statically trims the passes the macro-step gating
    guarantees are not needed: flow completions (gated: t < min done_at)
    and the rate recompute (the active-flow set cannot change during a
    cheap event — no spawns, no completions — so rates stay valid).

    ``recs`` collects the pass's flight-recorder records (trace.stage);
    the caller flushes them to the ring in one write after the pipeline.
    """
    # ALWAYS_ON has no srv_state transition path (timer_transitions is
    # the identity, wasp_adjust is WASP-only), so the WAKEUP/SLEEP masks
    # are identically false — skip both sites statically and keep ~1/4
    # of the flush lane space out of the hot loop
    trace_sleep = (cfg.trace.enabled
                   and cfg.sleep_policy != SleepPolicy.ALWAYS_ON)
    if trace_sleep:
        N = cfg.n_servers
        woke = (state.farm.srv_state == SrvState.WAKING) \
            & (state.farm.srv_wake_at <= state.t)
        trace_mod.stage(recs, woke, TraceKind.WAKEUP,
                        jnp.arange(N, dtype=jnp.int32))
    state = replace(state, farm=_apply_wakeups(state.farm, cfg, state.t))
    state = _apply_completions(state, cfg, tc, recs)
    if cfg.has_network and not cheap:
        state = _apply_flow_completions(state, cfg, recs)
    hold = None
    if _deferral_on(cfg):
        # deferred releases admit BEFORE fresh arrivals (lower job ids
        # drain first; see _apply_releases); a step that entered with due
        # releases also HOLDS fresh arrivals until the next same-time
        # step, so the arrival's load snapshot sees the release train
        # fully admitted AND drained (the oracle's event order)
        admit_at = state.jobs.admit_at
        hold = ((admit_at < INF / 2) & (admit_at <= state.t)).any()
        state = _apply_releases(state, cfg, tc, recs)
    state = _apply_arrival(state, cfg, tc, hold, recs)
    state = _drain_ready(state, cfg, recs)
    state = _start_tasks(state, cfg, recs)

    # refresh ACTIVE/IDLE, run local power controllers + pool managers
    if trace_sleep:
        st_before = state.farm.srv_state
    farm = server.refresh_idle_state(state.farm, cfg, state.t)
    active = _active_jobs(state.jobs)
    farm, sched = scheduler.provisioning_adjust(farm, cfg, state.sched,
                                                active)
    farm = scheduler.wasp_adjust(farm, cfg, _pending_jobs(state.jobs),
                                 state.t)
    farm = scheduler.timer_transitions(farm, cfg, state.t)
    state = replace(state, farm=farm, sched=sched)
    if trace_sleep:
        # awake -> sleep edges from the local power controllers
        was_awake = (st_before == SrvState.ACTIVE) \
            | (st_before == SrvState.IDLE)
        asleep = (farm.srv_state == SrvState.PKG_C6) \
            | (farm.srv_state == SrvState.S3) \
            | (farm.srv_state == SrvState.OFF)
        trace_mod.stage(recs, was_awake & asleep, TraceKind.SLEEP,
                        jnp.arange(cfg.n_servers, dtype=jnp.int32), -1,
                        farm.srv_state)

    if cfg.has_network:
        if cheap:
            # the flow set is unchanged (gating), so rates and link_flows
            # stay valid — but ports/linecards still enter LPI on idle
            # timeouts, which is a function of *time*, not of flow events
            net = net_mod.update_switch_states(
                state.net, state.net.link_flows, tc, cfg, state.t)
            state = replace(state, net=net)
        else:
            # rate recomputation is only needed while flows are in flight —
            # gate the (F, H) pass.  The no-flow branch must still ZERO
            # link_flows (recompute_rates would): reusing last step's
            # counts would pin ports ACTIVE forever after the final flow
            # completes.
            flows, link_flows = jax.lax.cond(
                state.flows.active.any(),
                lambda args: net_mod.recompute_rates(args[0], tc, state.t),
                lambda args: (args[0], jnp.zeros_like(args[1])),
                (state.flows, state.net.link_flows))
            net = net_mod.update_switch_states(state.net, link_flows, tc,
                                               cfg, state.t)
            state = replace(state, flows=flows, net=net)
    return state


# ==========================================================================
# the step
# ==========================================================================

def _cheap_gate(state: SimState, cfg: SimConfig):
    """(consume?, t_next) for one cheap event: the pending event time,
    restricted to the sources the cheap core handles (arrival, task
    completion, wakeup, timer, pending READY work).  ``consume`` is False
    whenever the full step is needed first: a flow completes at or before
    t_next, a completing task would resolve network edges (flow spawn +
    rate recompute), a throttle crossing fires, nothing is pending, or
    consuming the event would finish the simulation (the one-event loop
    sets ``done`` in the same step as the last completion and never
    processes trailing sleep-timer events — the last completion must
    therefore reach the full step, which owns the done check)."""
    t_next = _farm_candidates(state, cfg)
    jobs = state.jobs
    will_be_done = (~jobs.valid | (jobs.status == TaskStatus.DONE)
                    | ((jobs.status == TaskStatus.RUNNING)
                       & (jobs.task_end <= t_next))).all() \
        & (_next_arrival(jobs) >= INF)
    if cfg.has_network:
        will_be_done = will_be_done & ~state.flows.active.any()
    ok = (t_next < INF / 2) & ~will_be_done
    if cfg.has_network:
        ok = ok & (t_next < state.flows.done_at.min())
        if cfg.tasks_per_job > 1:
            # a completing task whose unsent edges all resolve locally
            # (same server / zero bytes) is still cheap — the in-core
            # edge resolver handles immediate edges; only an edge that
            # would SPAWN a flow (and force a rate recompute) stops the
            # chew.  Colocating policies (case D) therefore coalesce
            # their chain completions.
            jobs = state.jobs
            will_done = (jobs.status == TaskStatus.RUNNING) \
                & (jobs.task_end <= t_next)
            unsent = (jobs.children >= 0) & ~jobs.edge_sent
            dst = jobs.server[jnp.clip(jobs.children, 0)]     # (JT, D)
            spawns = unsent & (jobs.edge_bytes > 0) \
                & (dst != jobs.server[:, None])
            ok = ok & ~(will_done[:, None] & spawns).any()
    if cfg.thermal.throttling:
        ok = ok & (t_next < thermal_mod.next_crossing(state, cfg))
    return ok, t_next


def _apply_thermal_events(state: SimState, cfg: SimConfig,
                          recs=None) -> SimState:
    """Throttle hysteresis latch + setpoint-controller tick, shared by the
    cheap core and the full step (both run right after the interval
    advance), with their flight-recorder emission."""
    if cfg.thermal.throttling:
        # hysteresis latch + in-flight stretch; cond-gated on "any flip"
        old_thr = state.thermal.throttled
        farm, jobs, therm = thermal_mod.apply_throttle(
            state.farm, state.jobs, state.thermal, cfg, state.t)
        state = replace(state, farm=farm, jobs=jobs, thermal=therm)
        if cfg.trace.enabled:
            trace_mod.stage(recs, therm.throttled != old_thr,
                            TraceKind.THROTTLE_CROSSING,
                            jnp.arange(cfg.n_servers, dtype=jnp.int32),
                            -1, therm.t_srv)
    if cfg.thermal.has_ctrl:
        if cfg.trace.enabled:
            # the tick fires exactly when time reaches ctrl_next (it is a
            # next-event candidate); stage before the controller advances
            trace_mod.stage1(recs, state.t >= state.thermal.ctrl_next,
                             TraceKind.CTRL_TICK)
        # per-rack setpoint controller tick (cond-gated on the period)
        state = replace(state, thermal=thermal_mod.apply_setpoint_ctrl(
            state.thermal, cfg, state.t))
    return state


def _consume_cheap(state: SimState, cfg: SimConfig, tc, t_next):
    # the named_scope tags every equation of the cheap core with region
    # "cheap_core" so the static auditor (analysis/) can budget it
    # separately from the full step
    with jax.named_scope("cheap_core"):
        state = _advance_interval(state, cfg, tc, t_next)
        recs = [] if cfg.trace.enabled else None
        state = _apply_thermal_events(state, cfg, recs)
        state = _apply_events(state, cfg, tc, cheap=True, recs=recs)
        if cfg.trace.enabled:
            state = replace(state, trace=trace_mod.flush(
                state.trace, cfg, state.t, recs))
        return replace(state, events=state.events + 1)


def _macro_chew(state: SimState, cfg: SimConfig, tc):
    """Retire up to events_per_step - 1 cheap events in a bounded inner
    while_loop; stops early when the gate demands the full step."""
    K = cfg.events_per_step - 1

    def cond(carry):
        _, k, ok = carry
        return ok & (k < K)

    def body(carry):
        state, k, _ = carry
        ok, t_next = _cheap_gate(state, cfg)
        state = jax.lax.cond(
            ok, lambda s: _consume_cheap(s, cfg, tc, t_next),
            lambda s: s, state)
        return state, k + 1, ok

    state, _, _ = jax.lax.while_loop(
        cond, body, (state, jnp.zeros((), jnp.int32), jnp.asarray(True)))
    return state


def _full_step(state: SimState, cfg: SimConfig, tc=None) -> SimState:
    with jax.named_scope("full_step"):
        t_next = next_event_time(state, cfg)
        # a t_next at the INF sentinel means "no pending events": freeze
        # time (the done check below will terminate the loop) instead of
        # integrating energy over an unbounded interval
        t_next = jnp.where(t_next >= INF / 2, state.t, t_next)
        state = _advance_interval(state, cfg, tc, t_next)
        recs = [] if cfg.trace.enabled else None
        state = _apply_thermal_events(state, cfg, recs)
        state = _apply_events(state, cfg, tc, cheap=False, recs=recs)
        if cfg.trace.enabled:
            state = replace(state, trace=trace_mod.flush(
                state.trace, cfg, state.t, recs))

        all_done = (~state.jobs.valid
                    | (state.jobs.status == TaskStatus.DONE)).all() \
            & (_next_arrival(state.jobs) >= INF)
        if cfg.has_network:
            all_done = all_done & ~state.flows.active.any()
        return replace(state, events=state.events + 1, done=all_done)


def sim_step(state: SimState, cfg: SimConfig, tc=None) -> SimState:
    """One macro-step: chew up to events_per_step - 1 cheap events, then
    one full step; latency/QoS binning runs once over everything that
    finished since the macro began (the INF -> finite finish transitions
    identify them, independent of which inner step stamped them)."""
    telemetry_on = cfg.telemetry.enabled
    if telemetry_on:
        old_job_finish = state.jobs.job_finish
        old_task_finish = state.jobs.finish

    if cfg.events_per_step > 1:
        state = _macro_chew(state, cfg, tc)
    state = _full_step(state, cfg, tc)
    state = replace(state, steps=state.steps + 1)

    if telemetry_on:
        state = replace(state, telem=telemetry.accumulate_finishes(
            state.telem, cfg, state.jobs, old_job_finish, old_task_finish))
    return state


def init_state(cfg: SimConfig, jobs: JobTable, topo=None,
               racks=None) -> SimState:
    """``racks`` — optional (N,) host array of rack ids for the thermal
    recirculation grouping; defaults to the topology's first-hop-switch
    grouping when a topo is given, else ``i // thermal.rack_size``."""
    if cfg.has_network and topo is None:
        raise ValueError(
            "cfg.has_network=True requires a topology: pass topo= "
            "(flows would silently never route with tc=None)")
    if cfg.n_present > cfg.n_servers:
        raise ValueError(
            f"n_present={cfg.n_present} exceeds n_servers={cfg.n_servers}")
    if cfg.partition.sharded and cfg.thermal.enabled and racks is None \
            and topo is None \
            and cfg.n_servers % max(cfg.thermal.rack_size, 1):
        # unsharded runs handle an uneven last rack via the general
        # one-hot grouping; the rack-major block partition cannot, so the
        # sharded path refuses it up front instead of falling back
        raise ValueError(
            f"n_servers={cfg.n_servers} does not fill whole racks of "
            f"rack_size={cfg.thermal.rack_size}, so the rack-major "
            f"partition cannot cut on rack boundaries; pad the farm with "
            f"farm.pad_to_racks(cfg) (inert filler rows)")
    if cfg.sched_policy == SchedPolicy.THERMAL_AWARE \
            and not cfg.thermal.enabled:
        raise ValueError(
            "SchedPolicy.THERMAL_AWARE requires cfg.thermal.enabled=True "
            "(placement would silently ignore temperatures)")
    if cfg.sched_policy == SchedPolicy.CARBON_AWARE \
            and not cfg.thermal.enabled:
        raise ValueError(
            "SchedPolicy.CARBON_AWARE requires cfg.thermal.enabled=True "
            "(the deferral signal and telemetry live in the thermal/"
            "carbon subsystem)")
    tc = net_mod.topo_consts(topo) if (topo is not None and
                                       cfg.has_network) else None
    if racks is None and topo is not None and cfg.thermal.enabled:
        from . import topology as topo_mod
        racks = topo_mod.rack_of_servers(topo, cfg.thermal.rack_size)
    n_sw = topo.n_switches if topo is not None else 0
    n_ports = topo.n_ports if topo is not None else 1
    n_links = topo.n_links if topo is not None else 1
    n_lc = topo.n_linecards if topo is not None else 1
    state = SimState(
        t=jnp.zeros((), cfg.time_dtype),
        farm=init_farm(cfg),
        jobs=jobs,
        flows=init_flows(cfg),
        net=init_net(n_sw, n_ports, n_links, n_lc, cfg),
        sched=init_sched(cfg),
        telem=telemetry.init_telemetry(cfg),
        thermal=thermal_mod.init_thermal(cfg, racks),
        trace=trace_mod.init_trace(cfg),
        events=jnp.zeros((), jnp.int32),
        steps=jnp.zeros((), jnp.int32),
        done=jnp.zeros((), bool),
    )
    return state, tc


def step_closure(cfg: SimConfig, tc=None):
    """A ``state -> state`` closure over one macro-step, for jaxpr tracing
    by the static auditor (``analysis/``)."""
    def step(state: SimState) -> SimState:
        return sim_step(state, cfg, tc)
    return step


def _layout_key(tree) -> tuple:
    """Hashable (shape, dtype) layout of a pytree of tracers/arrays."""
    return tuple(
        (tuple(x.shape), str(x.dtype)) if hasattr(x, "shape") else repr(x)
        for x in jax.tree_util.tree_leaves(tree))


def _note_trace(tag: str, key) -> None:
    """Trace-time side effect feeding the retrace sentinel (no-op unless
    analysis.retrace has enabled counting)."""
    from ..analysis import retrace
    retrace.note_trace(tag, key)


def loop_cond(cfg: SimConfig):
    """The run-to-completion while-loop predicate, shared by :func:`run`
    and the rack-sharded driver (core/shard_sim.py) so both loops stop on
    exactly the same replicated scalars."""
    def cond(s):
        return (~s.done) & (s.events < cfg.max_events)
    return cond


@functools.partial(jax.jit, static_argnames=("cfg",))
def run(state: SimState, cfg: SimConfig, tc=None) -> SimState:
    """Run to completion (or cfg.max_events) under lax.while_loop.

    With macro-stepping (cfg.events_per_step > 1) the event budget is
    checked between macro-steps, so a run may retire up to
    events_per_step - 1 events past max_events before stopping."""
    # executes only when XLA actually (re)traces this (cfg, layout) key —
    # the retrace sentinel fails if the same key traces twice
    _note_trace("engine.run", (cfg, _layout_key((state, tc))))
    return jax.lax.while_loop(loop_cond(cfg), lambda s: sim_step(s, cfg, tc),
                              state)

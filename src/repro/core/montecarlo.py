"""Replica-parallel simulation sweeps (DESIGN.md §3.3).

The paper runs each configuration "100 times" (Fig 5).  Here replicas
(different seeds / τ values / thresholds) are a vmapped batch dimension,
and the batch is shard_mapped across every mesh axis — thousands of
simulated data centers run in parallel with collectives appearing only in
the final statistics reduction.  This is the axis that scales the simulator
to 1000+ nodes; it also hosts the fault-model Monte Carlo used to size
checkpoint cadence (Young/Daly) for the trainer.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import engine, jobs as jobs_mod, telemetry
from .types import INF, SimConfig


def batched_state(cfg: SimConfig, arrivals_b, specs, taus=None, topo=None):
    """Build R replica states.  arrivals_b (R, J); taus (R,) or (R, N);
    topo — network topology, required for has_network configs (threaded to
    engine.init_state so replica sweeps get real TopoConsts, not tc=None)."""
    R = arrivals_b.shape[0]
    tables = [jobs_mod.build_jobs(cfg, arrivals_b[i], specs)
              for i in range(R)]
    jobs = jax.tree.map(lambda *xs: jnp.stack(xs), *tables)
    state0, tc = engine.init_state(cfg, jax.tree.map(lambda a: a[0], jobs),
                                   topo)
    state_b = jax.vmap(lambda j: dataclasses.replace(state0, jobs=j))(jobs)
    if taus is not None:
        taus = jnp.asarray(taus, cfg.time_dtype)
        if taus.ndim == 1:
            taus = jnp.broadcast_to(taus[:, None], (R, cfg.n_servers))
        farm = dataclasses.replace(state_b.farm, srv_tau=taus)
        state_b = dataclasses.replace(state_b, farm=farm)
    return state_b, tc


def run_replicas(cfg: SimConfig, state_b, tc=None, mesh=None):
    """vmap the engine over the replica axis; optionally shard_map the
    replica batch over the mesh.

    The replica batch maps onto every mesh axis EXCEPT the rack-sharding
    axis (``cfg.partition.axis``, normally "racks"): on a 2-D
    ("replicas", "racks") mesh, Monte Carlo replicas split over the
    orthogonal "replicas" axis while each replica's farm state stays
    whole (replicated) along "racks" — the two parallelism axes compose
    without interfering."""
    runner = jax.vmap(functools.partial(engine.run.__wrapped__, cfg=cfg,
                                        tc=tc))
    if mesh is None:
        return jax.jit(runner)(state_b)
    from jax.sharding import PartitionSpec as P

    from ..sharding.compat import shard_map
    bax = tuple(a for a in mesh.axis_names if a != cfg.partition.axis)
    # prefix spec: replica dim 0 over the non-rack axes
    spec = P(bax) if bax else P()
    fn = shard_map(runner, mesh=mesh, in_specs=(spec,), out_specs=spec,
                   check_vma=False)
    return jax.jit(fn)(state_b)


def replica_stats(state_b, cfg: SimConfig):
    """Host-side per-replica summaries -> dict of numpy arrays.

    Replicas that finish zero jobs get NaN latency stats without tripping
    numpy's all-NaN RuntimeWarnings.  Percentiles come from the device-side
    telemetry histograms (one (R, B) array off-device instead of the (R, J)
    job tables) when telemetry is enabled; otherwise from the exact
    per-job latencies.
    """
    arr = np.asarray(state_b.jobs.arrival)                # (R, J)
    fin = np.asarray(state_b.jobs.job_finish)
    ok = (fin < INF / 2) & (arr < INF / 2)
    finished = ok.sum(axis=1)
    lat_sum = np.where(ok, fin - arr, 0.0).sum(axis=1)
    mean_lat = np.where(finished > 0,
                        lat_sum / np.maximum(finished, 1), np.nan)
    energy = np.asarray(state_b.farm.energy).sum(axis=1)  # (R,)
    sw_energy = np.asarray(state_b.net.sw_energy).sum(axis=1)
    cool = np.asarray(state_b.thermal.cool_energy) if cfg.thermal.enabled \
        else 0.0
    t = np.asarray(state_b.t)

    tcfg = cfg.telemetry
    if tcfg.enabled:
        hist = np.asarray(state_b.telem.job_hist)         # (R, B)
        pct = {q: telemetry.hist_percentile(hist, tcfg.lat_lo,
                                            tcfg.lat_hi, q)
               for q in (50, 95, 99)}
    else:
        def _exact(q):
            return np.asarray([
                np.percentile((fin[r] - arr[r])[ok[r]], q)
                if finished[r] else np.nan
                for r in range(arr.shape[0])])
        pct = {q: _exact(q) for q in (50, 95, 99)}
    out = {
        "mean_latency": mean_lat,
        "p50_latency": pct[50],
        "p95_latency": pct[95],
        "p99_latency": pct[99],
        "energy": energy,
        "sim_time": t,
        # same definition as SimResult.mean_power: IT + switch + cooling
        "mean_power": (energy + sw_energy + cool) / np.maximum(t, 1e-12),
        "events": np.asarray(state_b.events),
        "finished": finished,
        "flows_dropped": np.asarray(state_b.flows.flows_dropped),
    }
    if cfg.trace.enabled:
        # per-replica flight-recorder health: records evicted by wrap
        out["trace_dropped"] = np.asarray(state_b.trace.dropped)
    if cfg.thermal.enabled:
        th = state_b.thermal
        out.update({
            "cooling_energy": np.asarray(th.cool_energy),        # (R,)
            "carbon_g": np.asarray(th.carbon_g),
            "energy_cost": np.asarray(th.cost),
            "peak_temp": np.asarray(th.t_peak).max(axis=1),
            "throttle_seconds": np.asarray(th.throttle_seconds).sum(axis=1),
            "deferred_jobs": np.asarray(th.defer_count),         # (R,)
            "deferred_seconds": np.asarray(th.defer_seconds),
            "carbon_g_avoided_est": np.asarray(th.grams_avoided),
        })
    return out


def poisson_failure_times(mtbf: float, horizon: float, n_nodes: int,
                          seed: int = 0) -> np.ndarray:
    """Fleet-level failure arrivals for checkpoint-cadence studies: a node
    fleet with per-node MTBF produces failures at rate n/mtbf."""
    rng = np.random.default_rng(seed)
    rate = n_nodes / mtbf
    out, t = [], 0.0
    while t < horizon:
        t += rng.exponential(1.0 / rate)
        if t < horizon:
            out.append(t)
    return np.asarray(out)


def young_daly_interval(mtbf_fleet: float, ckpt_cost: float) -> float:
    """Optimal checkpoint interval sqrt(2·δ·MTBF) (Young/Daly)."""
    return float(np.sqrt(2.0 * ckpt_cost * mtbf_fleet))

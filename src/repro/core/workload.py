"""Workload arrival models (paper §III-D).

Three arrival models, matching the paper:
  * Poisson: exponential inter-arrivals at rate ``lam``.
  * MMPP(2): two-state Markov-modulated Poisson process — a bursty state with
    rate ``lam_h`` and a quiet state with rate ``lam_l``; sojourn times are
    exponential with rates ``r_hl`` / ``r_lh``.
  * Trace: replay of absolute arrival timestamps (e.g. a Wikipedia-like
    diurnal trace synthesized by :func:`wiki_like_trace`).

Generation is host-side (numpy) by design: arrival streams are inputs to the
simulation, exactly like the paper feeding the NLANR/Wikipedia traces in, and
keeping RNG off the device keeps the DES engine pure.

The MMPP(2) and diurnal-trace generators are VECTORIZED (batched
exponential draws + thinning over chunked numpy arrays): the seed
implementations were scalar Python while-loops that dominated setup time
at the million-job scale the ROADMAP targets.  Both draw from dedicated
``SeedSequence``-spawned child streams (modulating state / candidate gaps
/ acceptance uniforms), and candidate times are recomputed as one cumsum
over every gap drawn so far, so the output is a pure function of the seed
— bit-identical for every chunk size, including the one-candidate-at-a-
time scalar discipline the regression tests mirror.  (Outputs differ from
the pre-vectorization generators for the same seed; rates, burstiness,
and diurnal shape are unchanged.)
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "poisson_arrivals",
    "mmpp2_arrivals",
    "trace_arrivals",
    "wiki_like_trace",
    "utilization_to_rate",
]


def utilization_to_rate(rho: float, mean_service: float, n_servers: int,
                        n_cores: int) -> float:
    """Paper §III-D: rho = lambda / (mu * nServers * nCores)."""
    mu = 1.0 / mean_service
    return rho * mu * n_servers * n_cores


def poisson_arrivals(lam: float, n_jobs: int, seed: int = 0,
                     t0: float = 0.0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / lam, size=n_jobs)
    return t0 + np.cumsum(gaps)


def _thin(rate_at, lam_max: float, n_jobs: int, gap_rng, acc_rng,
          p_hint: float, chunk: int) -> np.ndarray:
    """Vectorized non-homogeneous Poisson sampling by thinning: candidate
    times from a rate-``lam_max`` homogeneous process, the i-th candidate
    accepted iff ``u_i·lam_max < rate_at(t_i)``.  Gap and acceptance
    draws come from dedicated streams; candidate times are one cumsum
    over ALL gaps drawn so far (np.cumsum accumulates sequentially, so
    the times are bit-identical to a scalar ``t += gap`` loop and
    invariant to chunk size).  ``p_hint`` sizes the first batch near the
    expected acceptance rate so the common case is one round."""
    if n_jobs <= 0:
        return np.empty(0)
    gaps, us = [], []
    n_acc = 0
    while n_acc < n_jobs:
        m = max(chunk, int(1.2 * (n_jobs - n_acc) / max(p_hint, 1e-6)))
        gaps.append(gap_rng.exponential(1.0 / lam_max, size=m))
        us.append(acc_rng.random(m))
        ts = np.cumsum(np.concatenate(gaps))
        acc = ts[np.concatenate(us) * lam_max < rate_at(ts)]
        n_acc = acc.size
    return acc[:n_jobs]


def mmpp2_arrivals(lam_h: float, lam_l: float, r_hl: float, r_lh: float,
                   n_jobs: int, seed: int = 0,
                   chunk: int = 16384) -> np.ndarray:
    """2-state MMPP.  State H emits at ``lam_h`` (bursty), state L at
    ``lam_l``.  ``r_hl`` is the H->L transition rate (so mean burst length is
    1/r_hl) and ``r_lh`` the L->H rate.  Burstiness is tuned via the ratio
    R_a = lam_h/lam_l or the stationary fraction of time in H (paper §III-D).

    Vectorized: the modulating chain is independent of the arrivals, so
    its sojourn trajectory is generated first (standard-exponential draws
    from a dedicated stream, scaled by the per-state rate) and arrivals
    are thinned from a rate-``max(lam_h, lam_l)`` process against the
    piecewise-constant rate.  Output depends on the seed only, not on
    ``chunk``.
    """
    state_rng, gap_rng, acc_rng = [
        np.random.default_rng(s)
        for s in np.random.SeedSequence(seed).spawn(3)]
    start_h = bool(state_rng.random() < r_lh / (r_lh + r_hl))
    lam_max = max(lam_h, lam_l)

    # modulating-state switch times, extended on demand; recomputed from
    # the full raw-draw list each extension so values never depend on how
    # far the trajectory happened to be materialized
    raws = []
    switch = np.empty(0)

    def _extend(tmax):
        nonlocal switch
        while switch.size == 0 or switch[-1] < tmax:
            n0 = sum(r.size for r in raws)
            need = max(64, int(1.2 * (tmax * 0.5 * (r_hl + r_lh) - n0)))
            raws.append(state_rng.exponential(1.0, size=need))
            raw = np.concatenate(raws)
            k = np.arange(raw.size)
            in_h = (k % 2 == 0) == start_h          # state during sojourn k
            switch = np.cumsum(raw * np.where(in_h, 1.0 / r_hl, 1.0 / r_lh))

    def rate_at(ts):
        _extend(ts[-1])
        idx = np.searchsorted(switch, ts, side="right")
        in_h = (idx % 2 == 0) == start_h
        return np.where(in_h, lam_h, lam_l)

    pi_h = r_lh / (r_lh + r_hl)
    p_hint = (pi_h * lam_h + (1.0 - pi_h) * lam_l) / lam_max
    return _thin(rate_at, lam_max, n_jobs, gap_rng, acc_rng, p_hint, chunk)


def trace_arrivals(timestamps, n_jobs: int | None = None,
                   rate_scale: float = 1.0) -> np.ndarray:
    """Replay absolute timestamps; optionally truncate and rescale rate."""
    ts = np.asarray(timestamps, dtype=np.float64)
    ts = np.sort(ts) / rate_scale
    if n_jobs is not None:
        ts = ts[:n_jobs]
    return ts


def wiki_like_trace(n_jobs: int, mean_rate: float, period: float = 600.0,
                    swing: float = 0.6, seed: int = 0,
                    chunk: int = 16384) -> np.ndarray:
    """Synthetic diurnal-fluctuation trace in the spirit of the Wikipedia
    trace [59] used by the paper's case studies: a non-homogeneous Poisson
    process whose rate follows ``mean_rate * (1 + swing*sin(2*pi*t/period))``
    (vectorized thinning; output depends on the seed only, not ``chunk``)."""
    gap_rng, acc_rng = [np.random.default_rng(s)
                        for s in np.random.SeedSequence(seed).spawn(2)]
    lam_max = mean_rate * (1.0 + swing)

    def rate_at(ts):
        return mean_rate * (1.0 + swing * np.sin(2.0 * np.pi * ts / period))

    p_hint = 1.0 / (1.0 + swing)
    return _thin(rate_at, lam_max, n_jobs, gap_rng, acc_rng, p_hint, chunk)

"""Workload arrival models (paper §III-D).

Three arrival models, matching the paper:
  * Poisson: exponential inter-arrivals at rate ``lam``.
  * MMPP(2): two-state Markov-modulated Poisson process — a bursty state with
    rate ``lam_h`` and a quiet state with rate ``lam_l``; sojourn times are
    exponential with rates ``r_hl`` / ``r_lh``.
  * Trace: replay of absolute arrival timestamps (e.g. a Wikipedia-like
    diurnal trace synthesized by :func:`wiki_like_trace`).

Generation is host-side (numpy) by design: arrival streams are inputs to the
simulation, exactly like the paper feeding the NLANR/Wikipedia traces in, and
keeping RNG off the device keeps the DES engine pure.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "poisson_arrivals",
    "mmpp2_arrivals",
    "trace_arrivals",
    "wiki_like_trace",
    "utilization_to_rate",
]


def utilization_to_rate(rho: float, mean_service: float, n_servers: int,
                        n_cores: int) -> float:
    """Paper §III-D: rho = lambda / (mu * nServers * nCores)."""
    mu = 1.0 / mean_service
    return rho * mu * n_servers * n_cores


def poisson_arrivals(lam: float, n_jobs: int, seed: int = 0,
                     t0: float = 0.0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / lam, size=n_jobs)
    return t0 + np.cumsum(gaps)


def mmpp2_arrivals(lam_h: float, lam_l: float, r_hl: float, r_lh: float,
                   n_jobs: int, seed: int = 0) -> np.ndarray:
    """2-state MMPP.  State H emits at ``lam_h`` (bursty), state L at
    ``lam_l``.  ``r_hl`` is the H->L transition rate (so mean burst length is
    1/r_hl) and ``r_lh`` the L->H rate.  Burstiness is tuned via the ratio
    R_a = lam_h/lam_l or the stationary fraction of time in H (paper §III-D).
    """
    rng = np.random.default_rng(seed)
    out = np.empty(n_jobs)
    t = 0.0
    state_h = rng.random() < r_lh / (r_lh + r_hl)  # stationary start
    # time remaining in current modulating state
    t_switch = rng.exponential(1.0 / (r_hl if state_h else r_lh))
    i = 0
    while i < n_jobs:
        lam = lam_h if state_h else lam_l
        gap = rng.exponential(1.0 / lam)
        if gap < t_switch:
            t += gap
            t_switch -= gap
            out[i] = t
            i += 1
        else:
            t += t_switch
            state_h = not state_h
            t_switch = rng.exponential(1.0 / (r_hl if state_h else r_lh))
    return out


def trace_arrivals(timestamps, n_jobs: int | None = None,
                   rate_scale: float = 1.0) -> np.ndarray:
    """Replay absolute timestamps; optionally truncate and rescale rate."""
    ts = np.asarray(timestamps, dtype=np.float64)
    ts = np.sort(ts) / rate_scale
    if n_jobs is not None:
        ts = ts[:n_jobs]
    return ts


def wiki_like_trace(n_jobs: int, mean_rate: float, period: float = 600.0,
                    swing: float = 0.6, seed: int = 0) -> np.ndarray:
    """Synthetic diurnal-fluctuation trace in the spirit of the Wikipedia
    trace [59] used by the paper's case studies: a non-homogeneous Poisson
    process whose rate follows ``mean_rate * (1 + swing*sin(2*pi*t/period))``
    (thinning method)."""
    rng = np.random.default_rng(seed)
    lam_max = mean_rate * (1.0 + swing)
    out = np.empty(n_jobs)
    t, i = 0.0, 0
    while i < n_jobs:
        t += rng.exponential(1.0 / lam_max)
        lam_t = mean_rate * (1.0 + swing * np.sin(2.0 * np.pi * t / period))
        if rng.random() < lam_t / lam_max:
            out[i] = t
            i += 1
    return out

"""Device-side event flight recorder.

A fixed-capacity ring buffer living in ``SimState.trace``, appended to
from inside the jitted event loop — both the cheap macro-step core and
the full step — so the recorded stream is identical for every
``events_per_step``.  Each record is (kind, time, server, tid, aux);
see ``types.TraceKind`` for the kind vocabulary and per-kind payloads.

Emission is two-phase to keep the hot loop fast.  XLA CPU scatter costs
~60ns per update ROW regardless of the target size, so per-site masked
scatters (13 sites x 5 field arrays, mostly-empty entity-wide masks)
dominate the step.  Instead every site :func:`stage`\\ s its records —
a Python-level list of (mask, kind, payload) tuples, zero device work —
and the step :func:`flush`\\ es once per event pass:

  1. concatenate the staged masks into one (L,) lane vector and pack it
     into int32 words (fusable elementwise work),
  2. locate the first W set lanes with popcount/cumsum/searchsorted
     plus a (W, 32) bit-rank matrix — no sort, no L-row scatter,
  3. map each lane back to its staged segment (static boundaries) and
     gather the payload for just those W rows, then write them with ONE
     W-row scatter into the packed (cap, 5) ring.

Payloads are never concatenated into L-wide columns — materializing an
(L, 5) update matrix costs ~12ns per lane per pass, several times the
whole budget at L ≈ 4000.  All O(L) work is the 1-bit mask pipeline.

A pass emitting more than W records (mass sleep/drop storms) falls back
to the exact L-row scatter under a ``lax.cond`` — correctness never
depends on W.  Every site is guarded by a Python-level
``if cfg.trace.enabled:`` so a disabled recorder is statically absent
from the traced computation (bit-identical dynamics, zero per-step
cost).

The write pointer is monotonic: slot = ptr % capacity, and records
overwritten by wrap-around are counted in ``TraceState.dropped`` so a
truncated recording is loud rather than silently partial.  Host-side
decoding/export lives in ``core/traceio.py``.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from .types import SimConfig, TraceState

__all__ = ["init_trace", "stage", "stage1", "flush"]

# batch width: records written per ring scatter.  Small on purpose —
# scatter cost is ~60ns/row and the (W, 32) rank matrix scales with W,
# while a typical event pass retires only a handful of records; bursts
# just take more loop iterations and stay exact.
_W = 16


def _buf_dtype(cfg: SimConfig):
    return jnp.promote_types(cfg.time_dtype, jnp.float32)


def init_trace(cfg: SimConfig) -> TraceState:
    """Fresh ring buffer; (1, 5) placeholder when disabled."""
    cap = cfg.trace.capacity if cfg.trace.enabled else 1
    return TraceState(
        buf=jnp.full((cap, 5), -1.0, _buf_dtype(cfg)),
        ptr=jnp.zeros((), jnp.int32),
        dropped=jnp.zeros((), jnp.int32),
    )


def stage(records: list, mask, kind: int, server=None, tid=None,
          aux=None) -> None:
    """Queue one record per set bit of ``mask`` (shape (M,)) for the
    pass's flush.  ``server``/``tid``/``aux`` may be (M,) arrays or
    scalars (broadcast at flush time).  Pure Python bookkeeping — no
    device ops until :func:`flush`.

    Records land in the ring in stage-call order, ascending lane within
    each call — the same deterministic order the per-site scatters
    produced, which the oracle mirrors.  ``kind`` must be a static int.
    """
    records.append((jnp.asarray(mask), int(kind), server, tid, aux))


def stage1(records: list, pred, kind: int, server=-1, tid=-1,
           aux=0.0) -> None:
    """Queue a single record when the scalar ``pred`` holds."""
    stage(records, jnp.asarray(pred).reshape((1,)), kind,
          jnp.asarray(server).reshape((1,)),
          jnp.asarray(tid).reshape((1,)),
          jnp.asarray(aux).reshape((1,)))


def _columns(records, cfg: SimConfig, t):
    """Staged records -> (mask (L,), update matrix (L, 5)) in lane
    order.  Kind is a compile-time constant column; time is the shared
    scalar ``t`` (every record in a pass carries the pass's event
    time).  Only used on the small-L direct path — the batched path
    assembles W rows lazily with :func:`_lane_rows`."""
    dt = _buf_dtype(cfg)
    masks, kinds, srvs, tids, auxs = [], [], [], [], []
    for mask, kind, server, tid, aux in records:
        m = mask.shape[0]
        masks.append(mask)
        kinds.append(jnp.full((m,), kind, dt))
        srvs.append(jnp.broadcast_to(
            jnp.asarray(-1 if server is None else server, dt), (m,)))
        tids.append(jnp.broadcast_to(
            jnp.asarray(-1 if tid is None else tid, dt), (m,)))
        auxs.append(jnp.broadcast_to(
            jnp.asarray(0.0 if aux is None else aux, dt), (m,)))
    mask = jnp.concatenate(masks)
    upd = jnp.stack(
        [jnp.concatenate(kinds),
         jnp.broadcast_to(t.astype(dt), mask.shape),
         jnp.concatenate(srvs), jnp.concatenate(tids),
         jnp.concatenate(auxs)], axis=1)
    return mask, upd


def _lane_field(records, field, seg, lane, starts, dt, default):
    """One payload column for W extracted lanes: per-segment gather (W
    elements each) merged by segment id — O(W * segments) instead of
    materializing an L-wide concatenated column.  Trace-time constants
    get special cases: a scalar equal to the column default needs no
    select at all, and an ``arange`` payload (the ubiquitous
    entity-index column) is just ``lane - start`` — elementwise, no
    gather."""
    import numpy as np

    out = jnp.full(lane.shape, default, dt)
    arange_segs = []
    for s, (rec, st) in enumerate(zip(records, starts)):
        p = rec[field]
        if p is None:
            continue
        try:                      # concrete (trace-time constant) payload?
            p_np = np.asarray(p)
        except Exception:         # tracer — runtime value
            p_np = None
        p = jnp.asarray(p)
        if p.ndim == 0:
            if p_np is not None and float(p_np) == default:
                continue
            out = jnp.where(seg == s, p.astype(dt), out)
        elif p_np is not None and np.array_equal(
                p_np, np.arange(p_np.shape[0])):
            arange_segs.append(s)             # folded into one select
        else:
            local = jnp.clip(lane - st, 0, p.shape[0] - 1)
            out = jnp.where(seg == s, p[local].astype(dt), out)
    if arange_segs:
        # entity-index columns (the dominant payload) all read
        # lane - segment_start: one select over an is-arange table
        # instead of a where per segment
        is_ar = np.zeros((len(records),), bool)
        is_ar[arange_segs] = True
        out = jnp.where(jnp.asarray(is_ar)[seg],
                        (lane - starts[seg]).astype(dt), out)
    return out


def _lane_rows(records, cfg: SimConfig, t, lane, starts, kinds_arr):
    """(W, 5) update rows for the extracted lanes."""
    dt = _buf_dtype(cfg)
    seg = jnp.searchsorted(starts, lane, side="right").astype(
        jnp.int32) - 1
    return jnp.stack(
        [kinds_arr[seg],
         jnp.broadcast_to(t.astype(dt), lane.shape),
         _lane_field(records, 2, seg, lane, starts, dt, -1.0),
         _lane_field(records, 3, seg, lane, starts, dt, -1.0),
         _lane_field(records, 4, seg, lane, starts, dt, 0.0)], axis=1)


def flush(tr: TraceState, cfg: SimConfig, t, records: list) -> TraceState:
    """Write one event pass's staged records to the ring.  Callers must
    hold ``cfg.trace.enabled`` true — emission sites are statically
    gated, so this function never sees a placeholder ring.

    The write loops over W-record batches: zero iterations on a quiet
    pass, one for any normal pass (a pass rarely retires more than a
    couple of records), more only for mass bursts (sleep/drop storms) —
    so bursts stay exact without an L-row scatter on the common path.
    A ``lax.cond`` fallback would be wrong here even though bursts are
    rare: XLA CPU inserts a defensive copy of the ring around the
    conditional (~the whole flush budget per pass), while the
    while_loop carry aliases in place."""
    if not records:
        return tr
    cap = cfg.trace.capacity
    sizes = [r[0].shape[0] for r in records]
    L = sum(sizes)

    if L <= _W:
        # narrow lane space: one L-row scatter, no rank search.  k-th
        # set bit -> slot (ptr + k) % cap; unset lanes scatter to the
        # out-of-bounds sentinel `cap` and are dropped.
        mask, upd = _columns(records, cfg, t)
        n = mask.sum().astype(jnp.int32)
        new_ptr = tr.ptr + n
        over = (jnp.maximum(new_ptr - cap, 0)
                - jnp.maximum(tr.ptr - cap, 0))
        idx = tr.ptr + jnp.cumsum(mask.astype(jnp.int32)) - 1
        slot = jnp.where(mask, idx % cap, cap)
        buf = tr.buf.at[slot].set(upd, mode="drop")
        return TraceState(buf=buf, ptr=new_ptr, dropped=tr.dropped + over)

    # pack the mask into words once; each batch locates its W lanes by
    # rank arithmetic (popcount cumsum + searchsorted + a (W, 32) bit
    # matrix) — a sort or an L-row scatter would cost more than the
    # whole flush budget, this is all fusable elementwise work.  The
    # pad to a word multiple rides along in the concat (a dynamic
    # update slice into a zeroed (B*32,) buffer would copy the whole
    # lane vector again), and n comes from the popcount cumsum rather
    # than a second L-wide reduction.
    dt = _buf_dtype(cfg)
    off0 = 0
    starts_py = []
    for sz in sizes:
        starts_py.append(off0)
        off0 += sz
    starts = jnp.asarray(starts_py, jnp.int32)
    kinds_arr = jnp.asarray([r[1] for r in records], dt)
    B = -(-L // 32)
    if all(sz % 8 == 0 for sz in sizes):
        # byte-aligned segments: pack each next to its producer (the
        # packbits fuses with the mask's comparison chain) and
        # concatenate 1/8th of the data instead of the bool lane vector
        packed = jnp.concatenate(
            [jnp.packbits(r[0], bitorder="little") for r in records]
            + ([jnp.zeros((B * 4 - L // 8,), jnp.uint8)]
               if B * 4 > L // 8 else []))
    else:
        segs = [r[0] for r in records]
        if B * 32 > L:
            segs.append(jnp.zeros((B * 32 - L,), bool))
        packed = jnp.packbits(jnp.concatenate(segs), bitorder="little")
    words = lax.bitcast_convert_type(
        packed.reshape(B, 4), jnp.uint32).reshape(B)
    pc = lax.population_count(words).astype(jnp.int32)
    cum = jnp.cumsum(pc)                                    # inclusive
    n = cum[-1]
    new_ptr = tr.ptr + n
    over = jnp.maximum(new_ptr - cap, 0) - jnp.maximum(tr.ptr - cap, 0)
    k = jnp.arange(_W, dtype=jnp.int32)
    shifts = jnp.arange(32, dtype=jnp.uint32)

    def write_batch(carry):
        buf, off = carry
        rank = off + k                              # global record ranks
        # word containing each rank: first word whose cumulative
        # popcount exceeds it
        wsel = jnp.searchsorted(cum, rank, side="right").astype(jnp.int32)
        wq = jnp.clip(wsel, 0, B - 1)
        word_k = words[wq]                                      # (W,)
        j = rank - (cum[wq] - pc[wq])               # rank within word
        wbits = ((word_k[:, None] >> shifts[None, :]) & 1).astype(
            jnp.int32)                                       # (W, 32)
        within = jnp.cumsum(wbits, axis=1)
        bitpos = jnp.argmax((wbits == 1) & (within == j[:, None] + 1),
                            axis=1).astype(jnp.int32)
        lane = jnp.clip(wq * 32 + bitpos, 0, L - 1)
        slot = jnp.where(rank < n, (tr.ptr + rank) % cap, cap)
        buf = buf.at[slot].set(
            _lane_rows(records, cfg, t, lane, starts, kinds_arr),
            mode="drop")
        return buf, off + _W

    buf, _ = lax.while_loop(lambda c: c[1] < n, write_batch,
                            (tr.buf, jnp.zeros((), jnp.int32)))
    return TraceState(buf=buf, ptr=new_ptr, dropped=tr.dropped + over)

"""Job / task DAG modeling (paper §III-C).

Each job j is a DAG G^j(V^j, E^j); task v has a service-time requirement
w^j_v and each edge carries a transfer size D^j_l.  We store the whole job
table as dense padded arrays (J*T flat task ids) so the engine can resolve
dependencies with pure vector ops.

DAG *templates* provided (all used by the paper's case studies):
  * ``single``   — one task per job (case studies A-C).
  * ``chain``    — sequential pipeline, e.g. web tier -> DB tier (§III-C).
  * ``fanout``   — scatter/gather: root -> k parallel -> join (search-style).
  * ``random``   — layered random DAG with given width/depth.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from .types import INF, JobTable, SimConfig, TaskStatus

__all__ = ["build_jobs", "dag_single", "dag_chain", "dag_fanout", "dag_random",
           "JobSpec"]


@dataclasses.dataclass
class JobSpec:
    """Host-side job description before padding into a JobTable."""

    service: np.ndarray          # (T,) per-task service times
    edges: list                  # list of (parent, child, bytes)
    sla: float = INF             # latency deadline (sec); INF = no SLA
    # carbon-aware control plane (SchedPolicy.CARBON_AWARE): a deferrable
    # job arriving in a high-carbon/price window is held unadmitted until
    # the signal's down-crossing or until arrival + defer_slack seconds,
    # whichever comes first
    deferrable: bool = False
    defer_slack: float = INF     # seconds past arrival before admission
                                 # is forced (INF = wait for the crossing)


def dag_single(service: float, sla: float = INF, deferrable: bool = False,
               defer_slack: float = INF) -> JobSpec:
    return JobSpec(service=np.asarray([service]), edges=[], sla=sla,
                   deferrable=deferrable, defer_slack=defer_slack)


def dag_chain(services, edge_bytes: float = 0.0) -> JobSpec:
    sv = np.asarray(services, dtype=np.float64)
    edges = [(i, i + 1, edge_bytes) for i in range(len(sv) - 1)]
    return JobSpec(service=sv, edges=edges)


def dag_fanout(root: float, leaves, join: float,
               edge_bytes: float = 0.0) -> JobSpec:
    lv = np.asarray(leaves, dtype=np.float64)
    k = len(lv)
    sv = np.concatenate([[root], lv, [join]])
    edges = [(0, 1 + i, edge_bytes) for i in range(k)]
    edges += [(1 + i, 1 + k, edge_bytes) for i in range(k)]
    return JobSpec(service=sv, edges=edges)


def dag_random(n_tasks: int, mean_service: float, edge_prob: float,
               edge_bytes: float, rng: np.random.Generator) -> JobSpec:
    sv = rng.exponential(mean_service, size=n_tasks)
    edges = []
    for child in range(1, n_tasks):
        # guarantee connectivity: at least one parent among predecessors
        parents = [p for p in range(child) if rng.random() < edge_prob]
        if not parents:
            parents = [int(rng.integers(0, child))]
        for p in parents:
            edges.append((p, child, edge_bytes))
    return JobSpec(service=sv, edges=edges)


def build_jobs(cfg: SimConfig, arrivals: np.ndarray,
               specs: list) -> JobTable:
    """Pad a list of JobSpecs (one per arrival) into a dense JobTable."""
    J, T, D = cfg.max_jobs, cfg.tasks_per_job, cfg.max_children
    if cfg.n_tasks >= np.iinfo(np.int32).max:
        # int32 indexing/FIFO-stamp guard: enqueue_seq stamps are bounded
        # by the task-table width (each task enqueues at most once), so a
        # table below 2^31 rows keeps every stamp comparison wrap-free
        # regardless of max_events (server.try_start compares stamps as
        # wrap-safe int32 diffs as a second line of defense)
        raise ValueError(
            f"max_jobs*tasks_per_job = {cfg.n_tasks} overflows int32 task "
            f"ids / FIFO stamps (limit {np.iinfo(np.int32).max})")
    n = min(len(arrivals), J, len(specs))

    arr = np.full((J,), INF)
    service = np.zeros((J, T))
    valid = np.zeros((J, T), bool)
    dep_count = np.zeros((J, T), np.int32)
    children = np.full((J, T, D), -1, np.int32)
    edge_bytes = np.zeros((J, T, D))
    sla = np.full((J,), INF)
    deferrable = np.zeros((J,), bool)
    deadline = np.full((J,), INF)

    for j in range(n):
        spec = specs[j]
        t = len(spec.service)
        if t > T:
            raise ValueError(f"job {j}: {t} tasks > tasks_per_job={T}")
        arr[j] = arrivals[j]
        sla[j] = getattr(spec, "sla", INF)
        deferrable[j] = getattr(spec, "deferrable", False)
        slack = getattr(spec, "defer_slack", INF)
        deadline[j] = arr[j] + slack if slack < INF / 2 else INF
        service[j, :t] = spec.service
        valid[j, :t] = True
        slot = np.zeros(T, np.int32)
        for (p, c, b) in spec.edges:
            dep_count[j, c] += 1
            k = slot[p]
            if k >= D:
                raise ValueError(f"job {j}: task {p} fanout > max_children={D}")
            children[j, p, k] = j * T + c      # flat child id
            edge_bytes[j, p, k] = b
            slot[p] += 1

    status = np.where(valid, TaskStatus.BLOCKED, TaskStatus.INVALID)
    return JobTable(
        arrival=jnp.asarray(arr, cfg.time_dtype),
        arr_ptr=jnp.zeros((), jnp.int32),
        service=jnp.asarray(service.reshape(-1), jnp.float32),
        valid=jnp.asarray(valid.reshape(-1)),
        dep_count=jnp.asarray(dep_count.reshape(-1)),
        children=jnp.asarray(children.reshape(J * T, D)),
        edge_bytes=jnp.asarray(edge_bytes.reshape(J * T, D), jnp.float32),
        status=jnp.asarray(status.reshape(-1), jnp.int32),
        edge_sent=jnp.asarray(children.reshape(J * T, D) < 0),
        server=jnp.full((J * T,), -1, jnp.int32),
        enqueue_seq=jnp.zeros((J * T,), jnp.int32),
        task_end=jnp.full((J * T,), INF, cfg.time_dtype),
        start_at=jnp.full((J * T,), INF, cfg.time_dtype),
        finish=jnp.full((J * T,), INF, cfg.time_dtype),
        job_finish=jnp.full((J,), INF, cfg.time_dtype),
        tasks_done=jnp.zeros((J,), jnp.int32),
        sla=jnp.asarray(sla, jnp.float32),
        deferrable=jnp.asarray(deferrable),
        deadline=jnp.asarray(deadline, cfg.time_dtype),
        admit_at=jnp.full((J,), INF, cfg.time_dtype),
    )

"""Thermal / cooling / carbon-cost subsystem.

HolDCSim's thesis is *holistic* co-simulation; this module carries the
simulation past the electrical boundary: the power accounted by
``power.py`` becomes heat, heat becomes cooling load, and both become
grams of CO2 and dollars — with two couplings back into behavior
(temperature-triggered throttling and thermal-aware placement).

Model
-----
Per-server thermal RC dynamics (cf. rack thermal models in
energy-aware-DC literature, e.g. Buyya et al. arXiv:1006.0308):

    T' = (P·r_th − (T − T_inlet)) / tau_th

Between DES events power is piecewise constant, so the ODE has the exact
closed-form update

    T += (P·r_th + T_inlet − T) · (1 − exp(−dt/tau_th))

which slots into the engine's accrual phase with zero discretization
error — the same trick the exact energy integration uses.  Rack-level
recirculation couples a server's inlet to its rack's mean excess
temperature; the inlet is held piecewise constant per interval
(recomputed from the pre-interval temperatures at every event), the
standard operator split for coupled RC networks in a DES.

CRAC/PUE: cooling power = P_IT / COP(T_setpoint) with the classic
quadratic chilled-water COP curve (cop_a·T² + cop_b·T + cop_c).  With one
static setpoint COP folds to a python constant at trace time; the control
plane (``t_setpoint`` / the setpoint controller) turns the setpoints into
per-rack *state* (``ThermalState.t_set``), each rack's IT load cooled at
its own in-trace quadratic COP, and an optional controller walks the
setpoints toward a target peak temperature on a control period (a real
event source).  A diurnal ambient sinusoid (``ambient_swing``) rides on
the supply temperature — held piecewise constant per event interval, the
same operator split as the recirculation, so the RC update stays exact.

Carbon & cost: grid carbon intensity (gCO2/kWh) and electricity price
($/kWh) follow diurnal sinusoids integrated in CLOSED FORM over each
event interval (∫ base·(1+swing·sin(2π(t+φ)/period)) dt), so the
accumulated grams/dollars are exact, not sampled.

Throttling: a server at/above ``t_throttle`` latches into a throttled
state (released below ``t_release`` — hysteresis) where its effective
core frequency is ``core_freq·throttle_freq``; in-flight work stretches
(``core_busy_until``/``task_end`` rescaled about *now*) and active-core
power scales by ``throttle_power_scale``.  Threshold crossings between
events are real events: :func:`next_crossing` solves the exponential for
the crossing time, so the engine advances exactly to the flip.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from . import power
from .types import (INF, SimConfig, TaskStatus, ThermalConfig, ThermalState,
                    replace)

__all__ = ["init_thermal", "inlet_temps", "advance", "apply_throttle",
           "next_crossing", "effective_freq", "cooling_power", "cop_at",
           "ambient", "apply_setpoint_ctrl", "defer_signal_now",
           "next_release_time", "rate_integral", "TEMP_TOL"]

# flip tolerance (°C): crossings land within f32 rounding of the
# threshold, so the hysteresis predicate accepts T >= t_throttle - TOL
TEMP_TOL = 1.0e-3
# relative overshoot applied to solved crossing times so the integrated
# temperature robustly lands past the threshold (cf. the delay-timer
# livelock note in scheduler.timer_transitions)
_CROSS_EPS = 1.0e-5


def init_thermal(cfg: SimConfig, racks=None) -> ThermalState:
    """Zeroed thermal pytree.  ``racks`` is an optional (N,) host array of
    rack ids (e.g. :func:`topology.rack_of_servers`); default grouping is
    ``i // cfg.thermal.rack_size``.  Minimal (1,)-sized arrays when the
    subsystem is disabled so the off path carries no cost."""
    tcfg = cfg.thermal
    if not tcfg.enabled:
        z = jnp.zeros((1,), jnp.float32)
        zs = jnp.zeros((), jnp.float32)
        return ThermalState(
            t_srv=z, throttled=jnp.zeros((1,), bool),
            rack_id=jnp.zeros((1,), jnp.int32),
            rack_onehot=jnp.zeros((1, 1), jnp.float32),
            rack_inv=z, t_set=z,
            ctrl_next=jnp.asarray(INF, cfg.time_dtype),
            t_peak=z, throttle_seconds=z,
            cool_energy=zs, carbon_g=zs, cost=zs,
            defer_seconds=zs, defer_count=jnp.zeros((), jnp.int32),
            grams_avoided=zs)

    N = cfg.n_servers
    if racks is None:
        racks = np.arange(N) // max(tcfg.rack_size, 1)
    racks = np.asarray(racks, np.int64)
    if racks.shape != (N,):
        raise ValueError(f"racks must be ({N},), got {racks.shape}")
    _, dense = np.unique(racks, return_inverse=True)   # 0..R-1, dense
    R = int(dense.max()) + 1
    counts = np.bincount(dense, minlength=R)
    # contiguous equal-size blocks (the i // rack_size default and every
    # built-in topology grouping) reduce by reshape — O(N) instead of the
    # (R, N) one-hot matmul, which at 20K servers would mean ~200 MB of
    # constant state and a ~50M-MAC pass per event.  The empty (0, 0)
    # onehot is the static marker for the fast path (inlet_temps).
    contiguous = N % R == 0 and (counts == N // R).all() \
        and (dense == np.arange(N) // (N // R)).all()
    if contiguous:
        onehot = np.zeros((0, 0), np.float32)
    else:
        onehot = (dense[None, :]
                  == np.arange(R)[:, None]).astype(np.float32)
    sp = tcfg.t_inlet if tcfg.t_setpoint is None else tcfg.t_setpoint
    try:
        t_set = np.broadcast_to(np.asarray(sp, np.float32), (R,))
    except ValueError:
        raise ValueError(
            f"t_setpoint must be a scalar or length-{R} (one per rack) "
            f"sequence, got {np.asarray(sp).shape}")
    # servers start at their own rack's supply temperature (the cold-aisle
    # fixed point of an unloaded rack, like the old uniform t_inlet)
    t0 = t_set[dense] + np.float32(ambient_host(tcfg, 0.0))
    ctrl_next = tcfg.ctrl_period if tcfg.has_ctrl else INF
    zs = jnp.zeros((), jnp.float32)
    return ThermalState(
        t_srv=jnp.asarray(t0, jnp.float32),
        throttled=jnp.zeros((N,), bool),
        rack_id=jnp.asarray(dense, jnp.int32),
        rack_onehot=jnp.asarray(onehot),
        rack_inv=jnp.asarray(1.0 / counts, jnp.float32),
        t_set=jnp.asarray(t_set, jnp.float32),
        ctrl_next=jnp.asarray(ctrl_next, cfg.time_dtype),
        t_peak=jnp.asarray(t0, jnp.float32),
        throttle_seconds=jnp.zeros((N,), jnp.float32),
        cool_energy=zs, carbon_g=zs, cost=zs,
        defer_seconds=zs, defer_count=jnp.zeros((), jnp.int32),
        grams_avoided=zs)


# ==========================================================================
# continuous models
# ==========================================================================

def ambient_host(tcfg: ThermalConfig, t: float) -> float:
    """Host-side diurnal ambient offset at time ``t`` (°C)."""
    if tcfg.ambient_swing == 0.0:
        return 0.0
    w = 2.0 * math.pi / tcfg.ambient_period
    return tcfg.ambient_swing * math.sin(w * (t + tcfg.ambient_phase))


def ambient(tcfg: ThermalConfig, t) -> jnp.ndarray:
    """In-trace diurnal ambient offset at time ``t`` (scalar, °C)."""
    w = 2.0 * math.pi / tcfg.ambient_period
    tf = t.astype(jnp.float32) if hasattr(t, "astype") else jnp.float32(t)
    return jnp.float32(tcfg.ambient_swing) \
        * jnp.sin(w * (tf + tcfg.ambient_phase))


def _rack_sums(therm: ThermalState, vals):
    """(R,) per-rack sums of a per-server vector.  Contiguous equal-size
    racks (the empty-onehot marker, set at init) reduce by reshape in
    O(N); irregular groupings fall back to the one-hot matmul, which
    still beats a segment-sum scatter on XLA:CPU."""
    R = therm.rack_inv.shape[0]
    if therm.rack_onehot.size == 0:                # contiguous fast path
        return vals.reshape(R, -1).sum(axis=1)
    return therm.rack_onehot @ vals


def inlet_temps(therm: ThermalState, tcfg: ThermalConfig,
                t=None) -> jnp.ndarray:
    """(N,) per-server inlet: rack supply temperature + recirc·rack-mean
    excess.  The supply temperature is the static ``t_inlet`` constant on
    the uniform path, or per-rack ``t_set`` (+ the diurnal ambient at
    ``t``) when the control plane is active — held piecewise constant per
    event interval (the operator split the RC exactness relies on)."""
    if not tcfg.per_rack and not tcfg.ambient_on:
        # static path, bit-identical to the pre-control-plane expression
        excess = therm.t_srv - tcfg.t_inlet
        mean = _rack_sums(therm, excess) * therm.rack_inv          # (R,)
        return tcfg.t_inlet + tcfg.recirc * mean[therm.rack_id]
    base_r = therm.t_set                                           # (R,)
    if tcfg.ambient_on:
        base_r = base_r + ambient(tcfg, t)
    base = base_r[therm.rack_id]                                   # (N,)
    excess = therm.t_srv - base
    mean = _rack_sums(therm, excess) * therm.rack_inv
    return base + tcfg.recirc * mean[therm.rack_id]


def cop_at(tcfg: ThermalConfig, t_sup):
    """In-trace quadratic COP at supply temperature(s) ``t_sup``."""
    return tcfg.cop_a * t_sup * t_sup + tcfg.cop_b * t_sup + tcfg.cop_c


def cooling_power(p_srv, p_sw, therm: ThermalState, tcfg: ThermalConfig):
    """CRAC power (W) for the per-server IT load ``p_srv`` (N,) plus
    switch load ``p_sw``.  Uniform setpoints fold COP to the static
    python constant; per-rack setpoints cool each rack's load at its own
    in-trace quadratic COP (switch load is cooled at the mean setpoint's
    COP — switches sit outside the rack model)."""
    if not tcfg.per_rack:
        return (p_srv.sum() + p_sw) / tcfg.cop
    rack_p = _rack_sums(therm, p_srv)                              # (R,)
    return (rack_p / cop_at(tcfg, therm.t_set)).sum() \
        + p_sw / cop_at(tcfg, therm.t_set.mean())


def rate_integral(base: float, swing: float, period: float, phase: float,
                  t1, t2):
    """∫_{t1}^{t2} base·(1 + swing·sin(2π(t+phase)/period)) dt, closed
    form — exact accumulation of the diurnal carbon/price series."""
    w = 2.0 * math.pi / period
    t1f = t1.astype(jnp.float32) if hasattr(t1, "astype") else jnp.float32(t1)
    t2f = t2.astype(jnp.float32) if hasattr(t2, "astype") else jnp.float32(t2)
    lin = t2f - t1f
    osc = (jnp.cos(w * (t1f + phase)) - jnp.cos(w * (t2f + phase))) / w
    return base * (lin + swing * osc)


def carbon_price_integrals(tcfg: ThermalConfig, t, dt):
    """(∫ci dt, ∫price dt) over [t, t+dt) — the window-exact series."""
    ci = rate_integral(tcfg.carbon_base, tcfg.carbon_swing,
                       tcfg.carbon_period, tcfg.carbon_phase, t, t + dt)
    pr = rate_integral(tcfg.price_base, tcfg.price_swing,
                       tcfg.price_period, tcfg.price_phase, t, t + dt)
    return ci, pr


def effective_freq(therm: ThermalState, cfg: SimConfig) -> jnp.ndarray:
    """(N,) effective core frequency under the throttle latch."""
    return jnp.where(therm.throttled,
                     jnp.float32(cfg.core_freq * cfg.thermal.throttle_freq),
                     jnp.float32(cfg.core_freq))


# ==========================================================================
# in-loop updates
# ==========================================================================

def advance(therm: ThermalState, cfg: SimConfig, p_srv, p_sw, t,
            dt, t_new=None, p_cool=None) -> ThermalState:
    """Integrate temperatures, cooling energy, carbon, and cost over the
    piecewise-constant interval [t, t+dt).  ``p_srv`` (N,) is the
    per-server power of the PRE-advance state (throttle-scaled), ``p_sw``
    the total switch power.  ``t_new`` / ``p_cool`` optionally supply the
    already computed end-of-interval temperatures and CRAC power (the
    engine's advance shares one RC + COP evaluation with the telemetry
    window columns)."""
    tcfg = cfg.thermal
    dtf = dt.astype(jnp.float32)
    if t_new is None:
        target = p_srv * tcfg.r_th + inlet_temps(therm, tcfg, t)
        alpha = 1.0 - jnp.exp(-dtf / tcfg.tau_th)
        t_new = therm.t_srv + (target - therm.t_srv) * alpha
    # temperature is monotone toward target within the interval, so the
    # endpoint max tracks the true running peak exactly
    t_peak = jnp.maximum(therm.t_peak, t_new)
    throttle_s = therm.throttle_seconds \
        + therm.throttled.astype(jnp.float32) * dtf

    p_it = p_srv.sum() + p_sw
    if p_cool is None:
        p_cool = cooling_power(p_srv, p_sw, therm, tcfg)
    p_tot = p_it + p_cool
    ici, ipr = carbon_price_integrals(tcfg, t, dt)
    kw = p_tot * jnp.float32(1.0e-3)
    return replace(
        therm, t_srv=t_new, t_peak=t_peak, throttle_seconds=throttle_s,
        cool_energy=therm.cool_energy + p_cool * dtf,
        carbon_g=therm.carbon_g + kw * ici / 3600.0,
        cost=therm.cost + kw * ipr / 3600.0)


def apply_throttle(farm, jobs, therm: ThermalState, cfg: SimConfig, now):
    """Hysteresis latch update + in-flight work stretch at time ``now``.

    Servers crossing ``t_throttle`` upward engage, servers cooled to the
    release threshold disengage; on any flip the remaining service of
    in-flight tasks rescales about *now* by the frequency ratio —
    elementwise in core space (``core_busy_until``) and, with the same
    expression, elementwise in task space (``task_end`` via each task's
    assigned server), so completion bookkeeping stays scatter-free and
    bit-consistent.  Returns (farm, jobs, therm)."""
    tcfg = cfg.thermal
    thr = tcfg.t_throttle
    rel = min(tcfg.t_release, tcfg.t_throttle)
    t = therm.t_srv
    engage = ~therm.throttled & (t >= thr - TEMP_TOL)
    release = therm.throttled & (t <= rel + TEMP_TOL)
    new_throttled = (therm.throttled | engage) & ~release
    changed = new_throttled != therm.throttled

    def stretch(args):
        farm, jobs = args
        tf = jnp.float32(tcfg.throttle_freq)
        f_old = jnp.where(therm.throttled, tf, jnp.float32(1.0))
        f_new = jnp.where(new_throttled, tf, jnp.float32(1.0))
        ratio = f_old / f_new                                   # (N,)
        bu = farm.core_busy_until
        in_flight = (bu < INF) & (bu > now) & changed[:, None]
        bu = jnp.where(in_flight, now + (bu - now) * ratio[:, None], bu)
        farm = replace(farm, core_busy_until=bu)

        srv = jnp.clip(jobs.server, 0)
        te = jobs.task_end
        run = (jobs.status == TaskStatus.RUNNING) & (te < INF) \
            & (te > now) & changed[srv] & (jobs.server >= 0)
        te = jnp.where(run, now + (te - now) * ratio[srv], te)
        return farm, replace(jobs, task_end=te)

    farm, jobs = jax.lax.cond(changed.any(), stretch, lambda a: a,
                              (farm, jobs))
    return farm, jobs, replace(therm, throttled=new_throttled)


def next_crossing(state, cfg: SimConfig) -> jnp.ndarray:
    """Earliest throttle engage/release threshold crossing (scalar; INF if
    none) — a real event source: solving T(t) = threshold on the
    exponential keeps throttling exact instead of checked-at-events.

    The solve (a power evaluation + rack recirculation + masked logs,
    ~4 dense passes) is cond-gated on "any server within
    ``crossing_guard`` °C of its pending threshold" — far from the
    thresholds the candidate is INF without touching the farm arrays,
    which removes the throttling event source's per-step cost from the
    common no-crossing-imminent regime.  Servers outside the band engage
    at the next ordinary event (apply_throttle checks every step) rather
    than at the exact crossing instant; crossing_guard=INF restores the
    always-solve exact behavior.  The numpy oracle mirrors the band."""
    tcfg = cfg.thermal
    therm = state.thermal
    t = therm.t_srv
    thr = tcfg.t_throttle
    rel = min(tcfg.t_release, tcfg.t_throttle)
    guard = tcfg.crossing_guard
    near_up = ~therm.throttled & (t >= thr - guard)
    near_dn = therm.throttled & (t <= rel + guard)

    def solve_all(_):
        p_srv, _b = power.server_power(state.farm, cfg,
                                       throttled=therm.throttled)
        # the inlet (incl. the diurnal ambient) is evaluated at state.t
        # and held constant — exactly the piecewise-constant-inlet target
        # the interval integrator uses, so the solved crossing is exact
        # w.r.t. the dynamics actually integrated
        target = p_srv * tcfg.r_th + inlet_temps(therm, tcfg, state.t)

        def solve(valid, num, den):
            arg = jnp.where(valid, num / den, jnp.float32(2.0))
            return jnp.where(valid & (arg > 1.0),
                             tcfg.tau_th * jnp.log(arg), INF)

        up = near_up & (t < thr - TEMP_TOL) & (target > thr)
        dt_up = solve(up, target - t, target - thr)
        dn = near_dn & (t > rel + TEMP_TOL) & (target < rel)
        dt_dn = solve(dn, t - target, rel - target)
        return jnp.minimum(dt_up, dt_dn).min()

    dt_min = jax.lax.cond((near_up | near_dn).any(), solve_all,
                          lambda _: jnp.float32(INF), None)
    t_cross = (state.t + dt_min * (1.0 + _CROSS_EPS) + 1.0e-9) \
        .astype(cfg.time_dtype)
    # at large t a small solved dt can round t_cross back onto state.t in
    # the time dtype (ulp(86400 f32) ~ 8 ms), freezing time while the
    # identical crossing is re-solved every step until max_events burns:
    # force at least one representable tick of progress — the tiny-dt
    # integration still moves T through the TEMP_TOL band in a step or two
    t_cross = jnp.maximum(
        t_cross, jnp.nextafter(state.t.astype(cfg.time_dtype),
                               jnp.asarray(INF, cfg.time_dtype)))
    return jnp.where(dt_min < INF / 2, t_cross, INF).astype(cfg.time_dtype)


# ==========================================================================
# control plane: setpoint controller + carbon-aware deferral
# ==========================================================================

def apply_setpoint_ctrl(therm: ThermalState, cfg: SimConfig,
                        now) -> ThermalState:
    """Per-rack setpoint controller tick at time ``now`` (no-op until
    ``therm.ctrl_next``).  Each rack whose hottest server exceeds
    ``ctrl_target`` lowers its supply setpoint by ``ctrl_step`` (colder
    air, worse COP); racks sitting below ``ctrl_target − ctrl_band``
    raise it (cheaper cooling), clipped into [ctrl_min, ctrl_max].  Only
    traced when ``cfg.thermal.has_ctrl``."""
    tcfg = cfg.thermal

    def tick(therm):
        R = therm.rack_inv.shape[0]
        if therm.rack_onehot.size == 0:
            rack_max = therm.t_srv.reshape(R, -1).max(axis=1)
        else:
            rack_max = jnp.where(therm.rack_onehot > 0,
                                 therm.t_srv[None, :],
                                 -jnp.float32(INF)).max(axis=1)
        down = rack_max > tcfg.ctrl_target
        up = ~down & (rack_max < tcfg.ctrl_target - tcfg.ctrl_band)
        step = jnp.float32(tcfg.ctrl_step)
        t_set = jnp.clip(
            therm.t_set - jnp.where(down, step, 0.0)
            + jnp.where(up, step, 0.0),
            jnp.float32(tcfg.ctrl_min), jnp.float32(tcfg.ctrl_max))
        # at least one representable tick of progress (cf. next_crossing:
        # a period below ulp(now) would freeze the event clock)
        nxt = jnp.maximum(
            (therm.ctrl_next + tcfg.ctrl_period).astype(cfg.time_dtype),
            jnp.nextafter(now.astype(cfg.time_dtype),
                          jnp.asarray(INF, cfg.time_dtype)))
        return replace(therm, t_set=t_set, ctrl_next=nxt)

    return jax.lax.cond(now >= therm.ctrl_next, tick, lambda th: th, therm)


def _defer_params(tcfg: ThermalConfig):
    """(base, swing, period, phase) of the deferral signal sinusoid."""
    if tcfg.defer_signal == "price":
        return (tcfg.price_base, tcfg.price_swing, tcfg.price_period,
                tcfg.price_phase)
    if tcfg.defer_signal != "carbon":
        raise ValueError(f"defer_signal must be 'carbon' or 'price', "
                         f"got {tcfg.defer_signal!r}")
    return (tcfg.carbon_base, tcfg.carbon_swing, tcfg.carbon_period,
            tcfg.carbon_phase)


def _sinusoid_now(base, swing, period, phase, t):
    w = 2.0 * math.pi / period
    tf = t.astype(jnp.float32) if hasattr(t, "astype") else jnp.float32(t)
    return jnp.float32(base) * (1.0 + swing * jnp.sin(w * (tf + phase)))


def defer_signal_now(tcfg: ThermalConfig, t) -> jnp.ndarray:
    """Instantaneous deferral signal (carbon gCO2/kWh or price $/kWh)."""
    return _sinusoid_now(*_defer_params(tcfg), t)


def carbon_intensity_now(tcfg: ThermalConfig, t) -> jnp.ndarray:
    """Instantaneous grid carbon intensity (gCO2/kWh) at time ``t`` —
    the grams-avoided estimator reads this regardless of which signal
    drives the deferral decision."""
    return _sinusoid_now(tcfg.carbon_base, tcfg.carbon_swing,
                         tcfg.carbon_period, tcfg.carbon_phase, t)


def next_release_time(tcfg: ThermalConfig, t):
    """Earliest t' >= t where the deferral signal sits at/below
    ``defer_threshold`` — the solved DOWN-crossing of the sinusoid
    (scalar; INF when the signal never crosses down, i.e. the threshold
    sits below the trough, in which case only deadlines admit).  All the
    trigonometry except the mod-2π shift is host-side constants; the
    traced shift runs in ``t``'s own dtype, so a float64 event clock
    (x64 mode) keeps float64 release times instead of collapsing to f32
    ulps at large t (with the default f32 clock the result carries the
    same ulp error as every other event time)."""
    base, swing, period, phase = _defer_params(tcfg)
    thr = tcfg.defer_threshold
    if base <= 0.0 or swing == 0.0 or thr >= INF / 2:
        return jnp.float32(INF)
    s = (thr / base - 1.0) / swing
    if s >= 1.0:       # signal never exceeds thr: deferral never triggers
        return jnp.float32(INF)
    if s <= -1.0:      # signal always above thr: no down-crossing exists
        return jnp.float32(INF)
    w = 2.0 * math.pi / period
    theta_dn = math.pi - math.asin(s)    # sin decreasing through s
    dt_t = t.dtype if hasattr(t, "dtype") else jnp.float32
    tf = jnp.asarray(t, dt_t)
    k = jnp.ceil((w * (tf + phase) - theta_dn) / (2.0 * math.pi))
    return ((theta_dn + 2.0 * math.pi * k) / w - phase).astype(dt_t)

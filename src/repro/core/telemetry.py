"""Device-side telemetry: streaming histograms, windowed time series, and
QoS/SLA tracking inside the jitted DES loop.

The paper's case studies (§IV) all read *distributions* — per-job latency
percentiles (Fig 5-6), power-state residency over time (Fig 8), energy-delay
trade-offs — not just end-of-run scalars.  This module accumulates them on
device, entirely inside ``lax.while_loop``:

  * **Latency histograms** — fixed-bin log-spaced histograms at job and task
    granularity.  p50/p95/p99 are recovered host-side from the bins
    (:func:`hist_percentile`) with at most one-bin-width error, so a vmapped
    replica sweep ships (R, B) histograms instead of (R, J) job tables.
  * **Windowed time series** — per-bucket time-weighted sums of active jobs,
    awake servers, queue depth, server/switch power, and per-power-state
    server counts.  A DES interval [t, t_next) is piecewise constant, so
    ``metric * dt`` scattered into the window containing the interval
    midpoint integrates the series exactly up to window-boundary rounding.
  * **QoS/SLA counters** — deadline misses against a per-job ``sla`` field
    and tail-latency violations against a global threshold.

The hot accumulation path has two interchangeable backends: the fused Pallas
kernel (``kernels/telemetry_bin.py`` — histogram binning + window bucketing
in one VMEM pass) and its pure-jnp oracle (``kernels/ref.py``), selected by
``TelemetryConfig.use_kernel``.  Off-TPU the kernel runs in interpret mode.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from . import power
from . import thermal as thermal_mod
from .types import (INF, SimConfig, SrvState, TaskStatus, Telemetry,
                    TelemetryConfig, replace)

__all__ = ["init_telemetry", "window_values", "accumulate_finishes",
           "summarize", "hist_percentile", "hist_mean", "bin_edges",
           "TelemetrySummary", "WIN_COLS"]

# ``Telemetry.win`` column layout.  Columns up to WIN_MAX_TEMP are
# time-weighted sums (column WIN_OCC accumulates dt itself, i.e. the
# occupancy used to normalize the others back to time averages); the
# columns from WIN_CI on are *exact interval integrals* (already
# time-integrated in closed form, no normalization by dt).  The thermal
# block stays zero when cfg.thermal.enabled=False.
WIN_OCC = 0          # sum of dt landing in this window
WIN_ACTIVE_JOBS = 1  # tasks in flight (READY|QUEUED|RUNNING) · dt
WIN_AWAKE = 2        # servers in ACTIVE|IDLE · dt
WIN_QDEPTH = 3       # local + global queue occupancy · dt
WIN_SRV_POWER = 4    # total server power (W) · dt  == joules per window
WIN_SW_POWER = 5     # total switch power (W) · dt
WIN_STATE0 = 6       # server count in SrvState s · dt, s = 0..NUM-1
WIN_COOL_POWER = WIN_STATE0 + SrvState.NUM   # CRAC power (W) · dt
WIN_MEAN_TEMP = WIN_COOL_POWER + 1           # farm-mean temperature · dt
WIN_MAX_TEMP = WIN_MEAN_TEMP + 1             # farm-max temperature · dt
WIN_CI = WIN_MAX_TEMP + 1                    # ∫ carbon intensity dt
WIN_PRICE = WIN_CI + 1                       # ∫ electricity price dt
WIN_CARBON_G = WIN_PRICE + 1                 # grams CO2 in this window
WIN_COST = WIN_CARBON_G + 1                  # $ in this window
WIN_COLS = WIN_COST + 1


# ==========================================================================
# state init
# ==========================================================================

def init_telemetry(cfg: SimConfig) -> Telemetry:
    """Zeroed telemetry pytree; minimal (1-sized) arrays when disabled so
    the disabled path carries no per-step cost and ~no memory."""
    tcfg = cfg.telemetry
    B = tcfg.n_bins if tcfg.enabled else 1
    W = tcfg.n_windows if tcfg.enabled else 1
    return Telemetry(
        job_hist=jnp.zeros((B,), jnp.float32),
        task_hist=jnp.zeros((B,), jnp.float32),
        win=jnp.zeros((W, WIN_COLS), jnp.float32),
        sla_miss=jnp.zeros((), jnp.int32),
        sla_total=jnp.zeros((), jnp.int32),
        tail_viol=jnp.zeros((), jnp.int32),
        win_overflow=jnp.zeros((), jnp.float32),
    )


# ==========================================================================
# in-loop accumulation
# ==========================================================================

def window_values(state, cfg: SimConfig, dt, p_busy=None,
                  onehot=None, thermal_ctx=None) -> jnp.ndarray:
    """(WIN_COLS,) metric·dt vector for the piecewise-constant interval
    [t, t+dt) — computed from the PRE-advance state, matching the exact
    energy integration in power.accrue_server_energy.  The carbon/price
    columns are closed-form interval integrals (not rate·dt samples), so
    window sums reproduce the accumulated grams/dollars exactly.

    ``p_busy`` / ``onehot`` optionally supply the precomputed per-server
    (power, busy-count) pair and (N, NUM) state one-hot, and
    ``thermal_ctx`` the (target, alpha, t_end, p_cool) RC/CRAC pieces — the engine's
    advance shares one evaluation between energy accrual, these window
    columns, and the thermal integrator instead of recomputing the power
    select, state comparisons, and RC exponential in each subsystem."""
    farm = state.farm
    tcfg = cfg.thermal
    dtf = dt.astype(jnp.float32)
    s = state.jobs.status
    active = ((s == TaskStatus.READY) | (s == TaskStatus.QUEUED)
              | (s == TaskStatus.RUNNING)).sum().astype(jnp.float32)
    qdepth = (farm.q_len.sum() + state.sched.gq_len).astype(jnp.float32)
    throttled = state.thermal.throttled if tcfg.enabled else None
    if p_busy is None:
        p_busy = power.server_power(farm, cfg, throttled)
    if onehot is None:
        onehot = (farm.srv_state[:, None]
                  == jnp.arange(SrvState.NUM)[None, :]).astype(jnp.float32)
    p_srv = p_busy[0].sum().astype(jnp.float32)
    if cfg.has_network:
        p_sw = power.switch_power(state.net, cfg).sum().astype(jnp.float32)
    else:
        p_sw = jnp.float32(0.0)
    # padded filler rows (farm.pad_to_racks) are telemetry-inert: they sit
    # OFF forever, so the static suffix slice keeps them out of the
    # per-state counts (the padding is a suffix by construction)
    per_state = onehot[:cfg.present].sum(axis=0) if cfg.has_padding \
        else onehot.sum(axis=0)
    awake = per_state[SrvState.ACTIVE] + per_state[SrvState.IDLE]
    head = jnp.stack([jnp.float32(1.0), active, awake, qdepth, p_srv, p_sw])
    if tcfg.enabled:
        t_srv = state.thermal.t_srv
        ici, ipr = thermal_mod.carbon_price_integrals(tcfg, state.t, dt)
        # temperature varies exponentially WITHIN the interval, so the
        # mean column integrates the closed form (∫T dt = target·dt +
        # (T0−target)·τ·(1−e^{−dt/τ}), averaged over servers) and the max
        # column uses the endpoint max (trajectories are monotone toward
        # their targets) — same exactness as the energy/carbon columns
        if thermal_ctx is None:
            p_vec = p_busy[0]
            target = p_vec * tcfg.r_th \
                + thermal_mod.inlet_temps(state.thermal, tcfg, state.t)
            alpha = 1.0 - jnp.exp(-dtf / tcfg.tau_th)
            t_end = t_srv + (target - t_srv) * alpha
            p_cool = thermal_mod.cooling_power(p_vec, p_sw,
                                               state.thermal, tcfg)
        else:
            target, alpha, t_end, p_cool = thermal_ctx
        kw = (p_srv + p_sw + p_cool) * jnp.float32(1.0e-3)
        if cfg.has_padding:
            # padded rows idle at the cold-aisle temperature; keep them
            # out of the farm mean/max columns (suffix padding -> slice)
            np_ = cfg.present
            target, t_srv_m, t_end_m = (target[:np_], t_srv[:np_],
                                        t_end[:np_])
        else:
            t_srv_m, t_end_m = t_srv, t_end
        mean_int = target.mean() * dtf \
            + (t_srv_m - target).mean() * tcfg.tau_th * alpha
        max_interval = jnp.maximum(t_srv_m, t_end_m).max()
        therm_cols = jnp.stack([
            p_cool * dtf, mean_int, max_interval * dtf,
            ici, ipr, kw * ici / 3600.0, kw * ipr / 3600.0])
    else:
        therm_cols = jnp.zeros((7,), jnp.float32)
    base = jnp.concatenate([head, per_state.astype(jnp.float32)]) * dtf
    return jnp.concatenate([base, therm_cols])


def _compact_finishes(mask, vals, K: int, fill: float):
    """Gather the first K True entries of ``mask`` into a (K,) batch of
    (values, weights) via top_k — scatter-free (XLA:CPU serializes
    scatters, which is exactly the cost this compaction removes from the
    binning).  Padding slots carry ``fill`` at weight 0, so the weighted
    histogram of the batch equals the dense masked histogram whenever
    mask.sum() <= K (counts are exact in f32 well past 2^24)."""
    w, idx = jax.lax.top_k(mask.astype(jnp.float32), K)
    out = jnp.where(w > 0, vals[idx], jnp.float32(fill))
    return out, w


def window_index(t, dt, tcfg: TelemetryConfig) -> jnp.ndarray:
    """Window containing the interval midpoint, clamped into range."""
    mid = t.astype(jnp.float32) + 0.5 * dt.astype(jnp.float32)
    return jnp.clip((mid / tcfg.window_dt).astype(jnp.int32),
                    0, tcfg.n_windows - 1)


def window_spill(t, dt, tcfg: TelemetryConfig) -> jnp.ndarray:
    """Seconds of this interval that window_index clamped into the last
    window because its midpoint lies past the n_windows·window_dt horizon.
    Conservation is deliberately preserved (the seconds still land in the
    last window) — the accumulated spill lets summarize flag/NaN the
    contaminated last-window time-averages instead of silently skewing
    them on runs longer than the horizon."""
    mid = t.astype(jnp.float32) + 0.5 * dt.astype(jnp.float32)
    horizon = jnp.float32(tcfg.n_windows * tcfg.window_dt)
    return jnp.where(mid >= horizon, dt.astype(jnp.float32), 0.0)


def accumulate_finishes(telem: Telemetry, cfg: SimConfig, jobs,
                        old_job_finish, old_task_finish) -> Telemetry:
    """Bin the latencies of every job/task that finished since the finish
    arrays were captured, and bump the QoS counters.

    ``old_*_finish`` are the finish arrays captured before the macro-step
    began — the INF -> finite transition identifies new completions, so
    one binning pass per macro-step covers every inner event (the bin a
    latency lands in does not depend on WHEN it is binned).  Window
    accrual is separate (the engine adds each interval's metric·dt inside
    its advance, exactly like the energy integral)."""
    tcfg = cfg.telemetry
    T = cfg.tasks_per_job
    new_job = (old_job_finish >= INF / 2) & (jobs.job_finish < INF / 2)
    new_task = (old_task_finish >= INF / 2) & (jobs.finish < INF / 2)

    def bin_finishes(args):
        # everything latency-shaped lives INSIDE the gate: quiet steps
        # must not pay the (J,)/(J·T,) latency/QoS passes
        jh0, th0 = args
        job_lat = jnp.maximum(jobs.job_finish - jobs.arrival, 0.0)
        jw = new_job.astype(jnp.float32)
        # task latency = finish - its job's arrival (sojourn to this stage)
        arr_t = jnp.repeat(jobs.arrival, T)
        task_lat = jnp.maximum(jobs.finish - arr_t, 0.0)
        tw = new_task.astype(jnp.float32)

        has_sla = jobs.sla < INF / 2
        miss = (new_job & has_sla
                & (job_lat > jobs.sla)).sum().astype(jnp.int32)
        tot = (new_job & has_sla).sum().astype(jnp.int32)
        tail = (new_job
                & (job_lat > tcfg.tail_thresh)).sum().astype(jnp.int32)

        from ..kernels import ref
        if tcfg.use_kernel:
            from ..kernels import telemetry_bin
            interp = jax.default_backend() != "tpu"
            # the fused kernel bins histograms and buckets windows in one
            # pass; windows accrue separately per interval now, so feed
            # it a single dummy row with a zero add (the kernel shapes
            # off win, so this keeps the dead window pass at one row)
            zwin = jnp.zeros((telem.win.shape[1],), jnp.float32)
            jh, th, _ = telemetry_bin.telemetry_accum(
                job_lat, jw, task_lat, tw, jh0, th0, telem.win[:1],
                jnp.zeros((), jnp.int32), zwin,
                tcfg.lat_lo, tcfg.lat_hi, interpret=interp)
            return jh, th, miss, tot, tail

        def dense(args):
            jh0, th0 = args
            B = jh0.shape[0]
            jh = jh0.at[ref.log_bin(job_lat, tcfg.lat_lo, tcfg.lat_hi,
                                    B)].add(jw)
            th = th0.at[ref.log_bin(task_lat, tcfg.lat_lo, tcfg.lat_hi,
                                    B)].add(tw)
            return jh, th

        Kc = tcfg.compact
        if Kc <= 0 or Kc >= job_lat.shape[0]:
            return (*dense(args), miss, tot, tail)

        # most finishing steps complete only a handful of jobs/tasks
        # (bounded by free cores + drop resolution): gather them into a
        # (Kc,)-batch so the log-binning stops paying (J)+(J·T)-wide
        # work, falling back to the dense pass on mass-finish steps
        def compact(args):
            jv, jww = _compact_finishes(new_job, job_lat, Kc, tcfg.lat_lo)
            tv, tww = _compact_finishes(new_task, task_lat, Kc, tcfg.lat_lo)
            jh0, th0 = args
            B = jh0.shape[0]
            jh = jh0.at[ref.log_bin(jv, tcfg.lat_lo, tcfg.lat_hi,
                                    B)].add(jww)
            th = th0.at[ref.log_bin(tv, tcfg.lat_lo, tcfg.lat_hi,
                                    B)].add(tww)
            return jh, th

        small = (new_job.sum() <= Kc) & (new_task.sum() <= Kc)
        jh, th = jax.lax.cond(small, compact, dense, args)
        return jh, th, miss, tot, tail

    def no_finishes(args):
        jh0, th0 = args
        zero = jnp.zeros((), jnp.int32)
        return jh0, th0, zero, zero, zero

    jh, th, miss, tot, tail = jax.lax.cond(
        new_job.any() | new_task.any(), bin_finishes, no_finishes,
        (telem.job_hist, telem.task_hist))

    return replace(telem, job_hist=jh, task_hist=th,
                   sla_miss=telem.sla_miss + miss,
                   sla_total=telem.sla_total + tot,
                   tail_viol=telem.tail_viol + tail)


# ==========================================================================
# host-side summarization
# ==========================================================================

def bin_edges(tcfg: TelemetryConfig) -> np.ndarray:
    """(B+1,) log-spaced histogram bin edges in seconds."""
    return tcfg.lat_lo * (tcfg.lat_hi / tcfg.lat_lo) ** (
        np.arange(tcfg.n_bins + 1) / tcfg.n_bins)


def _centers(lo: float, hi: float, n_bins: int) -> np.ndarray:
    # geometric bin centers of the log-spaced grid
    return lo * (hi / lo) ** ((np.arange(n_bins) + 0.5) / n_bins)


def hist_percentile(hist, lo: float, hi: float, q: float) -> np.ndarray:
    """Percentile(s) recovered from log-spaced histogram(s).

    ``hist`` is (..., B); returns (...) — the geometric center of the first
    bin whose CDF reaches q%.  Error vs the exact percentile is at most one
    bin width.  Empty histograms return NaN (no warnings).
    """
    h = np.asarray(hist, np.float64)
    B = h.shape[-1]
    total = h.sum(axis=-1)
    cdf = np.cumsum(h, axis=-1)
    target = (q / 100.0) * total[..., None]
    idx = np.clip((cdf < target).sum(axis=-1), 0, B - 1)
    vals = _centers(lo, hi, B)[idx]
    return np.where(total > 0, vals, np.nan)


def hist_mean(hist, lo: float, hi: float) -> np.ndarray:
    """Mean latency estimated from log-spaced histogram(s) (..., B)."""
    h = np.asarray(hist, np.float64)
    total = h.sum(axis=-1)
    est = (h * _centers(lo, hi, h.shape[-1])).sum(axis=-1)
    return np.where(total > 0, est / np.maximum(total, 1.0), np.nan)


@dataclasses.dataclass
class TelemetrySummary:
    """Host-side view of one run's Telemetry (numpy)."""

    # histogram-derived latency percentiles (seconds)
    job_p50: float
    job_p95: float
    job_p99: float
    task_p50: float
    task_p95: float
    task_p99: float
    mean_latency: float             # histogram-estimated
    jobs_binned: int
    tasks_binned: int
    # QoS / SLA
    sla_miss: int
    sla_total: int
    tail_violations: int
    # energy·delay product (J·s): total energy × histogram mean latency
    energy_delay_product: float
    # windowed time series (time-averaged per window; NaN where empty)
    times: np.ndarray               # (W,) window centers (sec)
    occupancy: np.ndarray           # (W,) seconds of sim time per window
    active_jobs: np.ndarray         # (W,)
    awake_servers: np.ndarray       # (W,)
    queue_depth: np.ndarray         # (W,)
    server_power: np.ndarray        # (W,) watts
    switch_power: np.ndarray        # (W,) watts
    state_residency: np.ndarray     # (W, SrvState.NUM) seconds
    n_windows_used: int
    # thermal/carbon/cost series (zeros unless cfg.thermal.enabled)
    cooling_power: np.ndarray = None    # (W,) watts, time-averaged
    mean_temp: np.ndarray = None        # (W,) °C, farm mean
    max_temp: np.ndarray = None         # (W,) °C, farm max
    carbon_intensity: np.ndarray = None  # (W,) gCO2/kWh, time-averaged
    price: np.ndarray = None            # (W,) $/kWh, time-averaged
    carbon_per_window: np.ndarray = None  # (W,) grams CO2 (raw integral)
    cost_per_window: np.ndarray = None    # (W,) $ (raw integral)
    # seconds of sim time clamped into the last window because the run
    # outlived the n_windows·window_dt horizon; > 0 means the last
    # window's time-averaged series were NaN-ed out as contaminated
    # (raw integrals — occupancy, residency, carbon/cost — are kept)
    win_overflow: float = 0.0

    @property
    def last_window_contaminated(self) -> bool:
        return self.win_overflow > 0.0

    @property
    def sla_miss_rate(self) -> float:
        return self.sla_miss / max(self.sla_total, 1)


def summarize(state, cfg: SimConfig) -> TelemetrySummary:
    """Summarize a finished SimState's device telemetry on the host."""
    tcfg = cfg.telemetry
    if not tcfg.enabled:
        raise ValueError("telemetry was disabled for this run "
                         "(cfg.telemetry.enabled=False)")
    telem = state.telem
    jh = np.asarray(telem.job_hist)
    th = np.asarray(telem.task_hist)
    win = np.asarray(telem.win, np.float64)
    lo, hi = tcfg.lat_lo, tcfg.lat_hi

    occ = win[:, WIN_OCC]
    norm = np.where(occ > 0, occ, np.nan)
    used = int((occ > 0).sum())
    overflow = float(telem.win_overflow)
    if overflow > 0.0:
        # the run outlived the window horizon: the last window absorbed
        # the clamped tail, so its time-averages mix in-horizon and
        # post-horizon state — NaN them out rather than report a skewed
        # value (the raw integral columns are left intact)
        norm[-1] = np.nan
    energy = float(np.asarray(state.farm.energy).sum()
                   + np.asarray(state.net.sw_energy).sum())
    mean_lat = float(hist_mean(jh, lo, hi))
    return TelemetrySummary(
        job_p50=float(hist_percentile(jh, lo, hi, 50)),
        job_p95=float(hist_percentile(jh, lo, hi, 95)),
        job_p99=float(hist_percentile(jh, lo, hi, 99)),
        task_p50=float(hist_percentile(th, lo, hi, 50)),
        task_p95=float(hist_percentile(th, lo, hi, 95)),
        task_p99=float(hist_percentile(th, lo, hi, 99)),
        mean_latency=mean_lat,
        jobs_binned=int(jh.sum()),
        tasks_binned=int(th.sum()),
        sla_miss=int(telem.sla_miss),
        sla_total=int(telem.sla_total),
        tail_violations=int(telem.tail_viol),
        energy_delay_product=energy * mean_lat if mean_lat == mean_lat
        else float("nan"),
        times=(np.arange(tcfg.n_windows) + 0.5) * tcfg.window_dt,
        occupancy=occ,
        active_jobs=win[:, WIN_ACTIVE_JOBS] / norm,
        awake_servers=win[:, WIN_AWAKE] / norm,
        queue_depth=win[:, WIN_QDEPTH] / norm,
        server_power=win[:, WIN_SRV_POWER] / norm,
        switch_power=win[:, WIN_SW_POWER] / norm,
        state_residency=win[:, WIN_STATE0:WIN_STATE0 + SrvState.NUM],
        n_windows_used=used,
        cooling_power=win[:, WIN_COOL_POWER] / norm,
        mean_temp=win[:, WIN_MEAN_TEMP] / norm,
        max_temp=win[:, WIN_MAX_TEMP] / norm,
        carbon_intensity=win[:, WIN_CI] / norm,
        price=win[:, WIN_PRICE] / norm,
        carbon_per_window=win[:, WIN_CARBON_G],
        cost_per_window=win[:, WIN_COST],
        win_overflow=overflow,
    )

"""Rack-sharded execution of the event engine: one engine, N devices.

``SimState``'s per-server axes are stored rack-major (server ``i`` lives
in rack ``i // rack_size``), so a contiguous block partition along the
server axis cuts exactly on rack boundaries.  :func:`run_sharded` keeps
those axes sharded across the ``"racks"`` mesh axis *at rest* — each
device holds N/K servers' worth of farm + thermal state — and runs the
whole ``lax.while_loop`` under ``shard_map``.

The macro-step splits into two phases:

  * **thin collective phase** — at the top of each macro-step the rack
    shards are gathered (one tiled ``all_gather`` per sharded leaf, the
    ONLY collectives in the program);
  * **collective-free event core** — the unmodified ``engine.sim_step``
    (including its cheap-event chew loop) runs on the gathered arrays,
    retiring up to ``events_per_step`` events with zero collectives, and
    the updated rack block is sliced back out at the bottom.

Because the gathered arrays and the step computation are *identical* to
the unsharded engine's, the sharded trajectory — every state leaf,
including the trace ring — is **bit-identical** to ``engine.run`` on one
device, for any device count.  A mesh of 1 is literally today's engine
plus a no-op reshard.  (``tests/test_sharding.py`` pins this.)

Replicated-by-construction state (jobs/flows/net/sched/telemetry/trace
and every scalar) is updated identically on all devices: the gathered
inputs are identical, the program is deterministic, and the while-loop
predicate is a replicated scalar, so the devices stay in lockstep and
``check_vma=False`` out-specs can take any copy.
"""
from __future__ import annotations

import functools

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from ..sharding import partition as mesh_lib
from ..sharding.compat import shard_map
from . import engine
from .types import SimConfig

__all__ = ["make_mesh", "n_sharded_leaves", "run_sharded",
           "sharded_step_jaxpr", "validate_sharding"]


def make_mesh(n_shards: int, axis: str = mesh_lib.SIM_AXIS):
    """A 1-D device mesh for rack sharding (first n_shards devices)."""
    devs = jax.devices()
    if len(devs) < n_shards:
        raise ValueError(
            f"partition.n_shards={n_shards} but only {len(devs)} device(s) "
            f"are visible; on CPU, launch with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_shards}")
    return jax.make_mesh((n_shards,), (axis,),
                         devices=np.asarray(devs[:n_shards]))


def validate_sharding(cfg: SimConfig, n_shards: int, state=None) -> None:
    """Fail fast on layouts shard_map cannot cut on rack boundaries."""
    if cfg.n_servers % n_shards:
        raise ValueError(
            f"n_servers={cfg.n_servers} is not divisible by "
            f"n_shards={n_shards}; pad the farm first (farm.pad_to_racks)")
    if cfg.thermal.enabled:
        if state is not None and state.thermal.rack_onehot.size:
            raise ValueError(
                "sharded runs need a contiguous equal-size rack grouping "
                "(the i // rack_size default or a block topology); this "
                "state uses the general one-hot grouping")
        if state is not None:
            R = int(state.thermal.t_set.shape[0])
            if R % n_shards:
                raise ValueError(
                    f"{R} racks do not split over {n_shards} shards; pad "
                    f"the farm to a rack multiple of n_shards "
                    f"(farm.pad_to_racks)")


def _gather_leaves(leaves, specs, axis):
    """all_gather every rack-sharded leaf back to its full (N, ...) shape
    — the macro-step's entire collective phase."""
    return [jax.lax.all_gather(x, axis, axis=0, tiled=True)
            if (len(sp) and sp[0] == axis) else x
            for x, sp in zip(leaves, specs)]


def _slice_leaves(leaves, specs, axis, n_shards):
    """Take this device's rack block back out of the full arrays (a local
    dynamic_slice — no communication)."""
    out = []
    idx = None
    for x, sp in zip(leaves, specs):
        if len(sp) and sp[0] == axis:
            if idx is None:
                idx = jax.lax.axis_index(axis)
            blk = x.shape[0] // n_shards
            out.append(jax.lax.dynamic_slice_in_dim(x, idx * blk, blk, 0))
        else:
            out.append(x)
    return out


def _sharded_step_fn(cfg: SimConfig, tc, specs, treedef, axis, n_shards):
    """One macro-step over locally-sharded leaves: gather -> sim_step ->
    re-slice.  Shared by run_sharded's loop body and the jaxpr probe."""
    def step(*local_leaves):
        full = _gather_leaves(list(local_leaves), specs, axis)
        state = jax.tree.unflatten(treedef, full)
        state = engine.sim_step(state, cfg, tc)
        out = jax.tree.leaves(state)
        return tuple(_slice_leaves(out, specs, axis, n_shards))
    return step


@functools.lru_cache(maxsize=32)
def _runner_for(cfg: SimConfig, mesh, axis, treedef, specs, n_state):
    """The jitted shard-mapped run-to-completion loop for one
    (cfg, mesh, pytree layout).  Cached so repeat calls (bench warm runs,
    replica sweeps, simulate(profile=True)) reuse the compiled
    executable instead of retracing a fresh closure each time.

    ``treedef`` flattens the ``(state, tc)`` pair; the trailing
    ``len - n_state`` leaves are the loop-invariant topology constants,
    passed through shard_map replicated."""
    n_shards = int(mesh.shape[axis])
    state_specs = specs[:n_state]
    cond = engine.loop_cond(cfg)

    def loop(*all_leaves):
        # trace-time side effect: lru_cache hits skip this entirely, so a
        # second firing for the same key means the compile cache leaked
        # (pinned by the analysis/retrace sentinel)
        engine._note_trace(
            "shard_sim.loop",
            (cfg, str(mesh), axis, str(treedef), str(specs), n_state))
        tc_leaves = list(all_leaves[n_state:])

        def body(lv):
            full = _gather_leaves(list(lv), state_specs, axis)
            state, tc = jax.tree.unflatten(treedef, full + tc_leaves)
            state = engine.sim_step(state, cfg, tc)
            out = jax.tree.leaves(state)
            return tuple(_slice_leaves(out, state_specs, axis, n_shards))

        def cond_lv(lv):
            state, _ = jax.tree.unflatten(treedef, list(lv) + tc_leaves)
            return cond(state)

        return jax.lax.while_loop(cond_lv, body,
                                  tuple(all_leaves[:n_state]))

    fn = shard_map(loop, mesh=mesh, in_specs=specs,
                   out_specs=state_specs, check_vma=False)
    return jax.jit(fn)


def run_sharded(state, cfg: SimConfig, tc=None, mesh=None):
    """Run to completion like :func:`engine.run`, with the rack-major
    state axes sharded over ``mesh`` (built from ``cfg.partition`` when
    None).  Bit-identical to the single-device engine by construction."""
    axis = cfg.partition.axis
    if mesh is None:
        mesh = make_mesh(cfg.partition.n_shards, axis)
    n_shards = int(mesh.shape[axis])
    validate_sharding(cfg, n_shards, state)
    state_specs = mesh_lib.sim_state_specs(state, cfg, mesh, axis)
    n_state = len(state_specs)
    leaves, treedef = jax.tree.flatten((state, tc))
    specs = state_specs + (P(),) * (len(leaves) - n_state)
    fn = _runner_for(cfg, mesh, axis, treedef, specs, n_state)
    out = fn(*leaves)
    return jax.tree.unflatten(jax.tree.structure(state), list(out))


# ==========================================================================
# shard-efficiency introspection (bench_engine, analysis/)
# ==========================================================================


def sharded_step_jaxpr(state, cfg: SimConfig, tc=None, mesh=None):
    """The jaxpr of ONE shard-mapped macro-step (gather + event core +
    re-slice) — the unit the collective count is quoted per."""
    axis = cfg.partition.axis
    if mesh is None:
        mesh = make_mesh(cfg.partition.n_shards, axis)
    n_shards = int(mesh.shape[axis])
    validate_sharding(cfg, n_shards, state)
    specs = mesh_lib.sim_state_specs(state, cfg, mesh, axis)
    leaves, treedef = jax.tree.flatten(state)
    step = _sharded_step_fn(cfg, tc, specs, treedef, axis, n_shards)
    fn = shard_map(step, mesh=mesh, in_specs=specs, out_specs=specs,
                   check_vma=False)
    return jax.make_jaxpr(fn)(*leaves)


def n_sharded_leaves(state, cfg: SimConfig, mesh=None) -> int:
    """How many state leaves the rack partition actually shards — the
    expected ``all_gather`` count per macro-step (one per sharded leaf;
    counting lives in ``analysis.jaxpr_audit``)."""
    axis = cfg.partition.axis
    if mesh is None:
        mesh = make_mesh(cfg.partition.n_shards, axis)
    specs = mesh_lib.sim_state_specs(state, cfg, mesh, axis)
    return sum(1 for sp in specs if len(sp) and sp[0] == axis)

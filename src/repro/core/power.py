"""ACPI-hierarchy power accounting (paper §III-F).

Energy is accrued *exactly* between events: state is piecewise constant in a
DES, so ``E += P(state) * dt`` integrates the power curve with no
discretization error.  Server power follows the paper's hierarchy — G/S
system states, package C-states, per-core C-states, P-state frequency —
and switch power follows chassis + linecard + port (LPI-capable) structure
calibrated to the paper's measured Cisco WS-C2960 profile.
"""
from __future__ import annotations

import jax.numpy as jnp

from .types import (INF, LinecardState, NetState, PortState, ServerFarm,
                    SimConfig, SrvState, replace)

__all__ = ["server_power", "accrue_server_energy", "accrue_switch_energy",
           "switch_power", "total_power"]


def server_power(farm: ServerFarm, cfg: SimConfig, throttled=None):
    """Instantaneous per-server power draw (N,) given current states.

    ``throttled`` (N,) bool — thermal subsystem: active-core power on
    throttled servers scales by ``cfg.thermal.throttle_power_scale``
    (linear-DVFS approximation).  None keeps the seed formula bit-exact.
    """
    sp = cfg.server_power
    C = cfg.n_cores
    busy = (farm.core_busy_until < INF).sum(axis=1).astype(jnp.float32)
    p_act = sp.p_core_active
    if throttled is not None:
        p_act = jnp.where(throttled,
                          jnp.float32(p_act * cfg.thermal.throttle_power_scale),
                          jnp.float32(p_act))
    p_on = sp.p_base + busy * p_act + (C - busy) * sp.p_core_idle
    # state-indexed power table; ACTIVE/IDLE share the S0 formula
    p = jnp.select(
        [farm.srv_state == SrvState.ACTIVE,
         farm.srv_state == SrvState.IDLE,
         farm.srv_state == SrvState.PKG_C6,
         farm.srv_state == SrvState.S3,
         farm.srv_state == SrvState.OFF,
         farm.srv_state == SrvState.WAKING],
        [p_on, p_on, sp.p_pkg_c6, sp.p_s3, 0.0, sp.p_wake],
        default=0.0,
    )
    return p, busy


def accrue_server_energy(farm: ServerFarm, cfg: SimConfig, dt,
                         p_busy=None, onehot=None) -> ServerFarm:
    """Exact interval accrual.  ``p_busy`` optionally supplies a
    precomputed (power, busy) pair and ``onehot`` a precomputed (N, NUM)
    state one-hot (the engine's advance computes both once and shares
    them with the telemetry windows and the thermal RC integrator)."""
    p, busy = server_power(farm, cfg) if p_busy is None else p_busy
    dtf = dt.astype(jnp.float32)
    energy = farm.energy + p * dtf
    # one-hot add, not .at[arange(N), state].add: XLA:CPU lowers scatters
    # to a scalar update loop (~30us for 512 rows) while the (N, NUM)
    # elementwise form stays vectorized
    if onehot is None:
        onehot = (farm.srv_state[:, None]
                  == jnp.arange(SrvState.NUM)[None, :]).astype(jnp.float32)
    residency = farm.residency + onehot * dtf
    busy_s = farm.busy_core_seconds + busy * dtf
    return replace(farm, energy=energy, residency=residency,
                   busy_core_seconds=busy_s)


def switch_power(net: NetState, cfg: SimConfig):
    """Instantaneous per-switch power (W,)."""
    swp = cfg.switch_power
    chassis = jnp.where(net.sw_awake, swp.p_chassis,
                        0.1 * swp.p_chassis)          # dozing switch ~10%
    port_p = jnp.select(
        [net.port_state == PortState.ACTIVE,
         net.port_state == PortState.LPI,
         net.port_state == PortState.OFF],
        [swp.p_port_active, swp.p_port_lpi, swp.p_port_off], 0.0)
    lc_p = jnp.where(net.lc_state == LinecardState.ACTIVE,
                     swp.p_linecard_active, swp.p_linecard_sleep)
    return chassis + port_p.sum(axis=1) + lc_p.sum(axis=1)


def total_power(farm: ServerFarm, net: NetState, cfg: SimConfig,
                throttled=None):
    """Instantaneous fleet-wide (server_total, switch_total) watts — the
    power signal sampled by the telemetry windows (core/telemetry.py)."""
    p_srv = server_power(farm, cfg, throttled)[0].sum()
    if cfg.has_network:
        p_sw = switch_power(net, cfg).sum()
    else:
        p_sw = jnp.float32(0.0)
    return p_srv.astype(jnp.float32), p_sw.astype(jnp.float32)


def accrue_switch_energy(net: NetState, cfg: SimConfig, dt) -> NetState:
    p = switch_power(net, cfg)
    dtf = dt.astype(jnp.float32)
    onehot = (net.port_state[..., None]
              == jnp.arange(PortState.NUM)[None, None, :]).astype(jnp.float32)
    pr = net.port_residency + onehot * dtf
    return replace(net, sw_energy=net.sw_energy + p * dtf, port_residency=pr)

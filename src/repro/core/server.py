"""Server model primitives (paper §III-A).

Each server: C cores (one task per core, paper's processing-unit model), a
local FIFO queue, and a hierarchical ACPI power state.  All operations are
dense/masked over the whole farm — no per-server control flow.

Queue representation is TASK-MAJOR: a queued task is simply a task with
``status == QUEUED``; its FIFO position is the global ``enqueue_seq`` stamp
it received on push, and the farm only keeps a per-server occupancy counter
(``q_len``) plus the global stamp counter (``q_seq``).  Pushes stamp
sequence numbers elementwise in task space and starts resolve FIFO order by
ranking queued tasks per server — there is no (N, Q) ring to scatter slots
into or gather task ids out of, which removes the two core->task scatters
per starting step and all ring-buffer state from the hot loop.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .types import (INF, JobTable, ServerFarm, SimConfig, SrvState,
                    TaskStatus, replace)

__all__ = ["queue_push", "queue_push_many", "queued_rank", "compact_mask",
           "try_start", "wake_latency", "begin_wake", "begin_wake_mask",
           "refresh_idle_state"]


def queue_push(farm: ServerFarm, cfg: SimConfig, server, tid):
    """Push one task onto ``server``'s queue (scalar args; the seed
    reference drain path).  Returns (farm, ok, seq): ``seq`` is the FIFO
    stamp the caller writes into ``jobs.enqueue_seq[tid]`` when ok."""
    full = farm.q_len[server] >= cfg.local_q
    q_len = farm.q_len.at[server].add(jnp.where(full, 0, 1))
    q_seq = farm.q_seq + jnp.where(full, 0, 1).astype(jnp.int32)
    dropped = farm.dropped + jnp.where(full, 1, 0).astype(jnp.int32)
    return (replace(farm, q_len=q_len, q_seq=q_seq, dropped=dropped),
            ~full, farm.q_seq)


def queue_push_many(farm: ServerFarm, cfg: SimConfig, servers, tids, valid):
    """Push up to K tasks onto their servers' queues in one pass.

    servers/tids (K,) int32, valid (K,) bool.  Tasks destined to the same
    server take FIFO stamps in position order (matching K sequential
    queue_push calls); once a queue fills, later same-server tasks drop.
    Returns (farm, ok (K,) bool, seq (K,) int32 — the stamp for each
    accepted task, garbage where ~ok).
    """
    K = tids.shape[0]
    N, Q = cfg.n_servers, cfg.local_q
    s = jnp.clip(servers, 0)
    # rank among earlier valid tasks bound for the same server
    pos = jnp.arange(K)
    same = valid[None, :] & valid[:, None] & (s[None, :] == s[:, None])
    rank = jnp.sum(same & (pos[None, :] < pos[:, None]), axis=1)
    # sequential equivalence: drops only start once the queue is full
    ok = valid & (farm.q_len[s] + rank < Q)
    seq = farm.q_seq + jnp.cumsum(ok.astype(jnp.int32)) - 1
    row = jnp.where(ok, s, N)                       # drop-sentinel row
    q_len = farm.q_len.at[row].add(1, mode="drop")
    q_seq = farm.q_seq + ok.sum().astype(jnp.int32)
    dropped = farm.dropped + (valid & ~ok).sum().astype(jnp.int32)
    return (replace(farm, q_len=q_len, q_seq=q_seq, dropped=dropped),
            ok, seq)


def wake_latency(cfg: SimConfig, state):
    sp = cfg.server_power
    table = jnp.asarray([0.0, 0.0, sp.t_wake_pkg_c6, sp.t_wake_s3,
                         sp.t_wake_off, 0.0], cfg.time_dtype)
    return table[state]


def begin_wake(farm: ServerFarm, cfg: SimConfig, server, now):
    """Start waking ``server`` if it is in a sleep state (idempotent)."""
    st = farm.srv_state[server]
    sleeping = (st == SrvState.PKG_C6) | (st == SrvState.S3) | (st == SrvState.OFF)
    lat = wake_latency(cfg, st)
    srv_state = farm.srv_state.at[server].set(
        jnp.where(sleeping, SrvState.WAKING, st))
    srv_wake_at = farm.srv_wake_at.at[server].set(
        jnp.where(sleeping, now + lat, farm.srv_wake_at[server]))
    wake_count = farm.wake_count.at[server].add(
        jnp.where(sleeping, 1, 0).astype(jnp.int32))
    return replace(farm, srv_state=srv_state, srv_wake_at=srv_wake_at,
                   wake_count=wake_count)


def begin_wake_mask(farm: ServerFarm, cfg: SimConfig, mask, now):
    """Masked whole-farm begin_wake: start waking every sleeping server in
    ``mask`` (N,).  Idempotent like the scalar version."""
    st = farm.srv_state
    sleeping = mask & ((st == SrvState.PKG_C6) | (st == SrvState.S3)
                       | (st == SrvState.OFF))
    lat = wake_latency(cfg, st)
    return replace(
        farm,
        srv_state=jnp.where(sleeping, SrvState.WAKING, st),
        srv_wake_at=jnp.where(sleeping, now + lat, farm.srv_wake_at),
        wake_count=farm.wake_count + sleeping.astype(jnp.int32))


def queued_rank(jobs: JobTable, cfg: SimConfig, queued, q_seq):
    """(JT,) FIFO rank of each queued task among the queued tasks of ITS
    server (0 = head), by enqueue_seq; garbage where ~queued.

    One argsort by (server, seq) makes same-server tasks contiguous in
    FIFO order, so the rank is position minus the server run's first
    position — O(JT log JT) in task space, independent of N and with only
    JT-row scatters (vs the (N, Q) ring's core-space gathers/scatters).

    Stamps sort by their wrap-safe int32 distance to the farm's CURRENT
    counter ``q_seq``: live stamps were issued within the last JT < 2^31
    pushes (a task enqueues at most once — build_jobs guards the table
    width), so ``stamp - q_seq`` is a strictly negative int32 even when
    the counter has wrapped, and FIFO order survives wrap-around instead
    of silently inverting at the 2^31 boundary.
    """
    JT = queued.shape[0]
    N = cfg.n_servers
    srv = jnp.clip(jobs.server, 0)
    # lexicographic (server, seq) sort via two STABLE argsorts — a fused
    # srv*(JT+1)+seq key would overflow int32 once n_servers·JT passes
    # 2^31 (a 20K-server farm with a ~100K-task table); seq (< JT) and
    # srv (< N) are individually safe
    imax = jnp.iinfo(jnp.int32).max
    rel_seq = jobs.enqueue_seq - q_seq          # wrap-safe, < 0 for live
    by_seq = jnp.argsort(jnp.where(queued, rel_seq, imax))
    order = by_seq[jnp.argsort(
        jnp.where(queued[by_seq], srv[by_seq], imax), stable=True)]
    srv_o = jnp.where(queued[order], srv[order], N)     # sentinel last
    first = jnp.full((N,), JT, jnp.int32).at[srv_o].min(
        jnp.arange(JT, dtype=jnp.int32), mode="drop")
    rank_o = jnp.arange(JT, dtype=jnp.int32) \
        - first[jnp.clip(srv_o, 0, N - 1)]
    return jnp.zeros((JT,), jnp.int32).at[order].set(rank_o)


def compact_mask(mask, K: int):
    """Gather the first K set task ids of ``mask`` (JT,) into a (K,)
    batch in ascending-tid order: one cumsum + one K-slot scatter.
    Returns (tids (K,), valid (K,), covered — True iff mask.sum() <= K,
    i.e. the batch holds EVERY set task)."""
    JT = mask.shape[0]
    r = jnp.cumsum(mask) - 1
    sel = mask & (r < K)
    tids = jnp.full((K,), -1, jnp.int32).at[jnp.where(sel, r, K)].set(
        jnp.arange(JT, dtype=jnp.int32), mode="drop")
    return tids, tids >= 0, r[-1] < K


# compact-batch size for start resolution: when at most this many tasks
# are QUEUED farm-wide (the overwhelmingly common case — drains are
# bounded by ready_per_step and starts immediately consume what they
# push), FIFO ranks come from a pairwise comparison inside a compacted
# batch; only genuinely congested steps pay the full-JT argsort rank
COMPACT_Q = 128


def try_start(farm: ServerFarm, cfg: SimConfig, jobs: JobTable, now,
              freq=None):
    """Start as many queued tasks as there are free cores, FIFO per
    server, in one task-space pass.

    Task-side bookkeeping (status -> RUNNING, task_end stamp) is fully
    elementwise in task space: a queued task starts iff its per-server
    FIFO rank is below its server's free-core count.  The core array is
    rebuilt from a (server, rank) -> task table (one small scatter)
    instead of the seed's (N, Q) ring gather + two (N·C)-row core->task
    scatters, which serialized on XLA:CPU.

    FIFO ranks normally come from a COMPACT_Q-wide gathered batch via a
    pairwise count (queues are near-empty in steady state); steps with
    more queued tasks than that fall back to the full argsort rank.
    Both paths define the identical rank, so the runtime choice never
    changes the dynamics.

    ``freq`` (N,) optionally overrides the scalar cfg.core_freq with a
    per-server effective frequency (thermal throttling); None keeps the
    untrottled expression bit-exact.

    Returns (farm, jobs).
    """
    N, C = cfg.n_servers, cfg.n_cores
    JT = jobs.status.shape[0]
    awake = (farm.srv_state == SrvState.ACTIVE) \
        | (farm.srv_state == SrvState.IDLE)
    free = farm.core_busy_until >= INF                          # (N, C)
    n_free = free.sum(axis=1, dtype=jnp.int32)
    n_start = jnp.where(awake, jnp.minimum(n_free, farm.q_len), 0)

    def apply_start(farm, jobs, rank):
        queued = jobs.status == TaskStatus.QUEUED
        srv = jnp.clip(jobs.server, 0)
        # task side: elementwise
        start_t = queued & (rank < n_start[srv])                # (JT,)
        if freq is None:
            svc = jobs.service / cfg.core_freq
        else:
            svc = jobs.service / freq[srv]
        end_t = (now + svc).astype(jobs.task_end.dtype)
        status = jnp.where(start_t, TaskStatus.RUNNING, jobs.status)
        task_end = jnp.where(start_t, end_t, jobs.task_end)
        start_at = jnp.where(
            start_t, jnp.asarray(now, jobs.start_at.dtype), jobs.start_at)
        jobs = replace(jobs, status=status, task_end=task_end,
                       start_at=start_at)

        # core side: the r-th starting task of server s takes the r-th
        # free core; build the (s, r) -> task table with one small
        # scatter, then fill cores elementwise (the busy_until expression
        # is the same float math as end_t, so task_end stays bit-equal)
        row = jnp.where(start_t, srv, N)
        col = jnp.clip(jnp.where(start_t, rank, 0), 0, C - 1)
        tid_at = jnp.full((N, C), JT, jnp.int32).at[row, col].set(
            jnp.arange(JT, dtype=jnp.int32), mode="drop")
        fr = jnp.cumsum(free, axis=1) - 1                       # free rank
        start_c = free & (fr < n_start[:, None])                # (N, C)
        tid_c = jnp.take_along_axis(tid_at, jnp.clip(fr, 0, C - 1), axis=1)
        if freq is None:
            svc_c = jobs.service[jnp.clip(tid_c, 0, JT - 1)] / cfg.core_freq
        else:
            svc_c = jobs.service[jnp.clip(tid_c, 0, JT - 1)] / freq[:, None]
        busy_until = (now + svc_c).astype(farm.core_busy_until.dtype)
        farm = replace(
            farm,
            core_busy_until=jnp.where(start_c, busy_until,
                                      farm.core_busy_until),
            q_len=farm.q_len - n_start)
        return farm, jobs

    def do(args):
        farm, jobs = args
        queued = jobs.status == TaskStatus.QUEUED

        def dense(args):
            farm, jobs = args
            return apply_start(farm, jobs,
                               queued_rank(jobs, cfg, queued, farm.q_seq))

        if JT <= COMPACT_Q:
            return dense(args)

        tids, valid, covered = compact_mask(queued, COMPACT_Q)

        def compact(args):
            farm, jobs = args
            srv = jnp.clip(jobs.server, 0)
            tq = jnp.clip(tids, 0)
            srv_b = jnp.where(valid, srv[tq], N)
            seq_b = jobs.enqueue_seq[tq]
            # pairwise FIFO rank inside the batch — equal to the dense
            # rank because the batch covers every queued task; the
            # compare is the wrap-safe int32 diff (see queued_rank)
            same = valid[None, :] & valid[:, None] \
                & (srv_b[None, :] == srv_b[:, None])
            rank_b = jnp.sum(
                same & ((seq_b[None, :] - seq_b[:, None]) < 0),
                axis=1).astype(jnp.int32)
            rank = jnp.zeros((JT,), jnp.int32).at[
                jnp.where(valid, tids, JT)].set(rank_b, mode="drop")
            return apply_start(farm, jobs, rank)

        return jax.lax.cond(covered, compact, dense, args)

    return jax.lax.cond((n_start > 0).any(), do, lambda a: a, (farm, jobs))


def refresh_idle_state(farm: ServerFarm, cfg: SimConfig, now):
    """Recompute ACTIVE/IDLE for awake servers; stamp idle_since on the
    ACTIVE->IDLE edge (the delay timer anchor, paper §IV-B)."""
    busy = (farm.core_busy_until < INF).any(axis=1)
    awake = (farm.srv_state == SrvState.ACTIVE) | (farm.srv_state == SrvState.IDLE)
    new_state = jnp.where(
        awake, jnp.where(busy, SrvState.ACTIVE, SrvState.IDLE), farm.srv_state)
    went_idle = awake & (farm.srv_state == SrvState.ACTIVE) & ~busy
    idle_since = jnp.where(went_idle, now, farm.srv_idle_since)
    return replace(farm, srv_state=new_state, srv_idle_since=idle_since)

"""Server model primitives (paper §III-A).

Each server: C cores (one task per core, paper's processing-unit model), a
local FIFO ring queue, and a hierarchical ACPI power state.  All operations
are dense/masked over the whole farm — no per-server control flow.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .types import INF, CoreState, ServerFarm, SimConfig, SrvState, replace

__all__ = ["queue_push", "try_start", "wake_latency", "begin_wake",
           "refresh_idle_state"]


def queue_push(farm: ServerFarm, cfg: SimConfig, server, tid):
    """Push one task id onto ``server``'s local ring queue.  Returns
    (farm, ok).  Scalar server/tid (engine drains READY tasks K per step)."""
    Q = cfg.local_q
    full = farm.q_len[server] >= Q
    slot = (farm.q_head[server] + farm.q_len[server]) % Q
    q_tasks = farm.q_tasks.at[server, slot].set(
        jnp.where(full, farm.q_tasks[server, slot], tid))
    q_len = farm.q_len.at[server].add(jnp.where(full, 0, 1))
    dropped = farm.dropped + jnp.where(full, 1, 0).astype(jnp.int32)
    return replace(farm, q_tasks=q_tasks, q_len=q_len, dropped=dropped), ~full


def wake_latency(cfg: SimConfig, state):
    sp = cfg.server_power
    table = jnp.asarray([0.0, 0.0, sp.t_wake_pkg_c6, sp.t_wake_s3,
                         sp.t_wake_off, 0.0], cfg.time_dtype)
    return table[state]


def begin_wake(farm: ServerFarm, cfg: SimConfig, server, now):
    """Start waking ``server`` if it is in a sleep state (idempotent)."""
    st = farm.srv_state[server]
    sleeping = (st == SrvState.PKG_C6) | (st == SrvState.S3) | (st == SrvState.OFF)
    lat = wake_latency(cfg, st)
    srv_state = farm.srv_state.at[server].set(
        jnp.where(sleeping, SrvState.WAKING, st))
    srv_wake_at = farm.srv_wake_at.at[server].set(
        jnp.where(sleeping, now + lat, farm.srv_wake_at[server]))
    wake_count = farm.wake_count.at[server].add(
        jnp.where(sleeping, 1, 0).astype(jnp.int32))
    return replace(farm, srv_state=srv_state, srv_wake_at=srv_wake_at,
                   wake_count=wake_count)


def _pop_one(farm: ServerFarm, cfg: SimConfig, service, now):
    """One vectorized round: every awake server with a free core and a
    non-empty queue starts its queue-head task.  Called C times (statically
    unrolled) from try_start, so a server can fill all cores in one step."""
    N, C, Q = cfg.n_servers, cfg.n_cores, cfg.local_q
    awake = (farm.srv_state == SrvState.ACTIVE) | (farm.srv_state == SrvState.IDLE)
    free_core = farm.core_busy_until >= INF                     # (N, C)
    has_free = free_core.any(axis=1)
    # first free core per server
    core_idx = jnp.argmax(free_core, axis=1)                    # (N,)
    can = awake & has_free & (farm.q_len > 0)                   # (N,)

    head_tid = farm.q_tasks[jnp.arange(N), farm.q_head % Q]     # (N,)
    svc = service[jnp.clip(head_tid, 0)] / cfg.core_freq
    busy_until = now + svc.astype(farm.core_busy_until.dtype)

    rows = jnp.arange(N)
    new_busy = farm.core_busy_until.at[rows, core_idx].set(
        jnp.where(can, busy_until, farm.core_busy_until[rows, core_idx]))
    new_task = farm.core_task.at[rows, core_idx].set(
        jnp.where(can, head_tid, farm.core_task[rows, core_idx]))
    q_head = jnp.where(can, (farm.q_head + 1) % Q, farm.q_head)
    q_len = jnp.where(can, farm.q_len - 1, farm.q_len)
    started = jnp.where(can, head_tid, -1)                      # (N,)
    farm = replace(farm, core_busy_until=new_busy, core_task=new_task,
                   q_head=q_head, q_len=q_len)
    return farm, started


def try_start(farm: ServerFarm, cfg: SimConfig, service, now):
    """Start as many queued tasks as there are free cores.  Returns
    (farm, started_tids (C, N)) so the engine can flip task statuses."""
    started = []
    for _ in range(cfg.n_cores):
        farm, s = _pop_one(farm, cfg, service, now)
        started.append(s)
    return farm, jnp.stack(started)


def refresh_idle_state(farm: ServerFarm, cfg: SimConfig, now):
    """Recompute ACTIVE/IDLE for awake servers; stamp idle_since on the
    ACTIVE->IDLE edge (the delay timer anchor, paper §IV-B)."""
    busy = (farm.core_busy_until < INF).any(axis=1)
    awake = (farm.srv_state == SrvState.ACTIVE) | (farm.srv_state == SrvState.IDLE)
    new_state = jnp.where(
        awake, jnp.where(busy, SrvState.ACTIVE, SrvState.IDLE), farm.srv_state)
    went_idle = awake & (farm.srv_state == SrvState.ACTIVE) & ~busy
    idle_since = jnp.where(went_idle, now, farm.srv_idle_since)
    return replace(farm, srv_state=new_state, srv_idle_since=idle_since)

"""Server model primitives (paper §III-A).

Each server: C cores (one task per core, paper's processing-unit model), a
local FIFO ring queue, and a hierarchical ACPI power state.  All operations
are dense/masked over the whole farm — no per-server control flow.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .types import INF, CoreState, ServerFarm, SimConfig, SrvState, replace

__all__ = ["queue_push", "queue_push_many", "try_start", "wake_latency",
           "begin_wake", "begin_wake_mask", "refresh_idle_state"]


def queue_push(farm: ServerFarm, cfg: SimConfig, server, tid):
    """Push one task id onto ``server``'s local ring queue.  Returns
    (farm, ok).  Scalar server/tid (engine drains READY tasks K per step)."""
    Q = cfg.local_q
    full = farm.q_len[server] >= Q
    slot = (farm.q_head[server] + farm.q_len[server]) % Q
    q_tasks = farm.q_tasks.at[server, slot].set(
        jnp.where(full, farm.q_tasks[server, slot], tid))
    q_len = farm.q_len.at[server].add(jnp.where(full, 0, 1))
    dropped = farm.dropped + jnp.where(full, 1, 0).astype(jnp.int32)
    return replace(farm, q_tasks=q_tasks, q_len=q_len, dropped=dropped), ~full


def queue_push_many(farm: ServerFarm, cfg: SimConfig, servers, tids, valid):
    """Push up to K tasks onto their servers' ring queues in one scatter.

    servers/tids (K,) int32, valid (K,) bool.  Tasks destined to the same
    server land in q slots in position order (matching K sequential
    queue_push calls); once a queue fills, later same-server tasks drop.
    Returns (farm, ok (K,) bool).
    """
    K = tids.shape[0]
    N, Q = cfg.n_servers, cfg.local_q
    s = jnp.clip(servers, 0)
    # rank among earlier valid tasks bound for the same server
    pos = jnp.arange(K)
    same = valid[None, :] & valid[:, None] & (s[None, :] == s[:, None])
    rank = jnp.sum(same & (pos[None, :] < pos[:, None]), axis=1)
    # sequential equivalence: drops only start once the queue is full, so
    # accepted ranks are contiguous and slots need no compaction
    ok = valid & (farm.q_len[s] + rank < Q)
    slot = (farm.q_head[s] + farm.q_len[s] + rank) % Q
    row = jnp.where(ok, s, N)                       # drop-sentinel row
    q_tasks = farm.q_tasks.at[row, slot].set(tids, mode="drop")
    q_len = farm.q_len.at[row].add(1, mode="drop")
    dropped = farm.dropped + (valid & ~ok).sum().astype(jnp.int32)
    return replace(farm, q_tasks=q_tasks, q_len=q_len, dropped=dropped), ok


def wake_latency(cfg: SimConfig, state):
    sp = cfg.server_power
    table = jnp.asarray([0.0, 0.0, sp.t_wake_pkg_c6, sp.t_wake_s3,
                         sp.t_wake_off, 0.0], cfg.time_dtype)
    return table[state]


def begin_wake(farm: ServerFarm, cfg: SimConfig, server, now):
    """Start waking ``server`` if it is in a sleep state (idempotent)."""
    st = farm.srv_state[server]
    sleeping = (st == SrvState.PKG_C6) | (st == SrvState.S3) | (st == SrvState.OFF)
    lat = wake_latency(cfg, st)
    srv_state = farm.srv_state.at[server].set(
        jnp.where(sleeping, SrvState.WAKING, st))
    srv_wake_at = farm.srv_wake_at.at[server].set(
        jnp.where(sleeping, now + lat, farm.srv_wake_at[server]))
    wake_count = farm.wake_count.at[server].add(
        jnp.where(sleeping, 1, 0).astype(jnp.int32))
    return replace(farm, srv_state=srv_state, srv_wake_at=srv_wake_at,
                   wake_count=wake_count)


def begin_wake_mask(farm: ServerFarm, cfg: SimConfig, mask, now):
    """Masked whole-farm begin_wake: start waking every sleeping server in
    ``mask`` (N,).  Idempotent like the scalar version."""
    st = farm.srv_state
    sleeping = mask & ((st == SrvState.PKG_C6) | (st == SrvState.S3)
                       | (st == SrvState.OFF))
    lat = wake_latency(cfg, st)
    return replace(
        farm,
        srv_state=jnp.where(sleeping, SrvState.WAKING, st),
        srv_wake_at=jnp.where(sleeping, now + lat, farm.srv_wake_at),
        wake_count=farm.wake_count + sleeping.astype(jnp.int32))


def try_start(farm: ServerFarm, cfg: SimConfig, service, now, freq=None):
    """Start as many queued tasks as there are free cores, in ONE masked
    pass: the r-th free core of each awake server takes the r-th queue
    entry, for r < min(free cores, queue length).  Identical to the seed's
    C sequential pop rounds but with zero scatters — the core arrays are
    rebuilt with elementwise where (XLA:CPU scatters serialize).

    ``freq`` (N,) optionally overrides the scalar cfg.core_freq with a
    per-server effective frequency (thermal throttling); None keeps the
    seed expression bit-exact.

    Returns (farm, started_tids (N, C), -1 where no start) so the engine
    can flip task statuses."""
    N, C, Q = cfg.n_servers, cfg.n_cores, cfg.local_q
    awake = (farm.srv_state == SrvState.ACTIVE) \
        | (farm.srv_state == SrvState.IDLE)
    free = farm.core_busy_until >= INF                          # (N, C)
    fr = jnp.cumsum(free, axis=1) - 1                           # free rank
    n_start = jnp.where(awake,
                        jnp.minimum(free.sum(axis=1), farm.q_len), 0)
    start = free & (fr < n_start[:, None])                      # (N, C)
    qpos = (farm.q_head[:, None] + fr) % Q                      # (N, C)
    tid = jnp.take_along_axis(farm.q_tasks, qpos, axis=1)       # (N, C)
    if freq is None:
        svc = service[jnp.clip(tid, 0)] / cfg.core_freq
    else:
        svc = service[jnp.clip(tid, 0)] / freq[:, None]
    busy_until = now + svc.astype(farm.core_busy_until.dtype)

    farm = replace(
        farm,
        core_busy_until=jnp.where(start, busy_until, farm.core_busy_until),
        core_task=jnp.where(start, tid, farm.core_task),
        q_head=(farm.q_head + n_start) % Q,
        q_len=farm.q_len - n_start)
    return farm, jnp.where(start, tid, -1)


def refresh_idle_state(farm: ServerFarm, cfg: SimConfig, now):
    """Recompute ACTIVE/IDLE for awake servers; stamp idle_since on the
    ACTIVE->IDLE edge (the delay timer anchor, paper §IV-B)."""
    busy = (farm.core_busy_until < INF).any(axis=1)
    awake = (farm.srv_state == SrvState.ACTIVE) | (farm.srv_state == SrvState.IDLE)
    new_state = jnp.where(
        awake, jnp.where(busy, SrvState.ACTIVE, SrvState.IDLE), farm.srv_state)
    went_idle = awake & (farm.srv_state == SrvState.ACTIVE) & ~busy
    idle_since = jnp.where(went_idle, now, farm.srv_idle_since)
    return replace(farm, srv_state=new_state, srv_idle_since=idle_since)

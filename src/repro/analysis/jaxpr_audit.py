"""Recursive jaxpr auditor: per-primitive inventory with region provenance,
plus the clock-dtype taint interpreter.

The walker descends through every higher-order primitive (``pjit``,
``while``, ``cond``, ``scan``, ``shard_map``, custom-call wrappers) and
tracks *region provenance*: ``jax.named_scope`` tags recorded on each
equation's name stack are inherited downward into sub-jaxprs, so a rule can
target "the cheap-core body" (``engine._consume_cheap`` runs under
``named_scope("cheap_core")``) separately from "the full step".

Two analyses share the walk:

* :func:`audit` -- an :class:`Inventory` of every equation: primitive name,
  region, and user source location.  Scatter/gather/collective/callback/
  dynamic-slice counts and per-region histograms come from it.
* :func:`clock_audit` -- a forward taint propagation from the declared
  time-valued state leaves.  A downcast of a time value below
  ``cfg.time_dtype`` *outside* a ``named_scope(F32_DOMAIN)`` block marks the
  result DEGRADED; a DEGRADED value reaching a time-valued output leaf is a
  clock-precision leak (the PR 5 ``next_release_time`` bug class), reported
  with the originating downcast's source line.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Sequence

import jax
import numpy as np

# Region tag marking intentional exits from the time domain: values
# downcast inside this scope are f32 physics (energy, temperatures,
# telemetry weights), not clocks, and do not carry degraded-clock taint.
F32_DOMAIN = "f32_domain"

SCATTER_PRIMS = frozenset(
    {"scatter", "scatter-add", "scatter-mul", "scatter-min", "scatter-max"}
)
GATHER_PRIMS = frozenset({"gather"})
DYNAMIC_SLICE_PRIMS = frozenset({"dynamic_slice", "dynamic_update_slice"})
COLLECTIVE_PRIMS = frozenset(
    {
        "all_gather",
        "all_gather_invariant",
        "psum",
        "psum2",
        "pmin",
        "pmax",
        "all_to_all",
        "ppermute",
        "pbroadcast",
        "reduce_scatter",
        "pgather",
        "all_reduce",
    }
)
CALLBACK_PRIMS = frozenset(
    {"pure_callback", "io_callback", "debug_callback", "callback"}
)


@dataclasses.dataclass(frozen=True)
class Site:
    """One equation occurrence: primitive, region provenance, source."""

    prim: str
    region: str  # "/"-joined named-scope components ("" = outer)
    src: str  # user source location "file:line (fn)"

    def in_region(self, region: str) -> bool:
        return region in self.region.split("/")


def _source_of(eqn) -> str:
    try:
        from jax._src import source_info_util

        return source_info_util.summarize(eqn.source_info)
    except Exception:
        return "<unknown>"


def _sub_jaxprs(value) -> Iterator:
    """Yield every (open) jaxpr buried in an eqn param value."""
    if isinstance(value, jax.core.ClosedJaxpr):
        yield value.jaxpr
    elif isinstance(value, jax.core.Jaxpr):
        yield value
    elif isinstance(value, (tuple, list)):
        for item in value:
            yield from _sub_jaxprs(item)


def _region_of(eqn, inherited: tuple) -> tuple:
    stack = str(eqn.source_info.name_stack)
    comps = tuple(c for c in stack.split("/") if c)
    return inherited + comps


def iter_eqns(jaxpr, region: tuple = ()) -> Iterator:
    """Yield ``(eqn, region_components)`` over ``jaxpr`` and every
    sub-jaxpr, with named-scope components inherited downward."""
    for eqn in jaxpr.eqns:
        reg = _region_of(eqn, region)
        yield eqn, reg
        for param in eqn.params.values():
            for sub in _sub_jaxprs(param):
                yield from iter_eqns(sub, reg)


@dataclasses.dataclass
class Inventory:
    """Flat per-primitive inventory of one traced program."""

    sites: list
    n_eqns: int

    def count(self, prims, region: Optional[str] = None) -> int:
        if isinstance(prims, str):
            prims = {prims}
        return sum(
            1
            for s in self.sites
            if s.prim in prims and (region is None or s.in_region(region))
        )

    def sites_of(self, prims, region: Optional[str] = None) -> list:
        if isinstance(prims, str):
            prims = {prims}
        return [
            s
            for s in self.sites
            if s.prim in prims and (region is None or s.in_region(region))
        ]

    def histogram(self) -> dict:
        """``{region: {prim: count}}`` with the full region path as key."""
        out: dict = {}
        for s in self.sites:
            reg = out.setdefault(s.region, {})
            reg[s.prim] = reg.get(s.prim, 0) + 1
        return {r: dict(sorted(v.items())) for r, v in sorted(out.items())}

    def summary(self) -> dict:
        return {
            "eqns": self.n_eqns,
            "scatter": self.count(SCATTER_PRIMS),
            "scatter_cheap_core": self.count(SCATTER_PRIMS, "cheap_core"),
            "gather": self.count(GATHER_PRIMS),
            "dynamic_slice": self.count(DYNAMIC_SLICE_PRIMS),
            "collectives": {
                p: self.count(p)
                for p in sorted(COLLECTIVE_PRIMS)
                if self.count(p)
            },
            "callbacks": self.count(CALLBACK_PRIMS),
        }


def audit(closed_jaxpr) -> Inventory:
    """Walk a (closed) jaxpr into a flat :class:`Inventory`."""
    jaxpr = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    sites = [
        Site(prim=eqn.primitive.name, region="/".join(reg), src=_source_of(eqn))
        for eqn, reg in iter_eqns(jaxpr)
    ]
    return Inventory(sites=sites, n_eqns=len(sites))


# ==========================================================================
# clock-dtype taint propagation
# ==========================================================================

# taint lattice: NONE < CLEAN (a time value) < DEGRADED (a time value that
# went through a sub-time_dtype float outside F32_DOMAIN)
NONE, CLEAN, DEGRADED = 0, 1, 2

# state leaves that carry absolute simulation times (suffix-matched on
# jax.tree_util.keystr paths of SimState)
TIME_LEAVES = (
    ".t",
    ".farm.core_busy_until",
    ".farm.srv_wake_at",
    ".farm.srv_idle_since",
    ".farm.srv_tau",
    ".jobs.arrival",
    ".jobs.task_end",
    ".jobs.start_at",
    ".jobs.finish",
    ".jobs.job_finish",
    ".jobs.admit_at",
    ".jobs.deadline",
    ".flows.done_at",
    ".flows.extra",
    ".net.port_idle_since",
    ".thermal.ctrl_next",
)


def time_leaf_mask(tree) -> list:
    """Per-leaf bool: is this flattened leaf a declared clock array?"""
    leaves_with_path, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [
        any(jax.tree_util.keystr(path).endswith(s) for s in TIME_LEAVES)
        for path, _ in leaves_with_path
    ]


def time_leaf_names(tree) -> list:
    leaves_with_path, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(path) for path, _ in leaves_with_path]


def _is_float(aval) -> bool:
    return hasattr(aval, "dtype") and np.issubdtype(aval.dtype, np.floating)


def _float_bits(aval) -> int:
    return np.dtype(aval.dtype).itemsize * 8


def _join(a: tuple, b: tuple) -> tuple:
    return a if a[0] >= b[0] else b


_NO_TAINT = (NONE, None)


class _TaintEnv:
    """Var -> (level, origin site) with Literal inputs always NONE."""

    def __init__(self):
        self._env: dict = {}

    def read(self, var) -> tuple:
        if isinstance(var, jax.core.Literal):
            return _NO_TAINT
        return self._env.get(var, _NO_TAINT)

    def write(self, var, taint: tuple) -> None:
        if taint[0] != NONE:
            self._env[var] = taint


@dataclasses.dataclass
class ClockReport:
    """Result of :func:`clock_audit`."""

    time_dtype: str
    # {leaf name: dtype} for declared time leaves, inputs and outputs
    in_census: dict
    out_census: dict
    # [(leaf name, downcast site)] time outputs reconstructed from a value
    # that lost precision outside F32_DOMAIN
    degraded_leaves: list
    # every downcast site that created degraded taint (for diagnostics)
    downcast_sites: list

    @property
    def census_violations(self) -> list:
        bad = []
        for census, tag in ((self.in_census, "input"), (self.out_census, "output")):
            for name, dtype in census.items():
                if dtype != self.time_dtype:
                    bad.append((name, tag, dtype))
        return bad


def clock_audit(closed_jaxpr, state_template, time_dtype) -> ClockReport:
    """Propagate clock taint through ``closed_jaxpr`` (traced from a
    ``state -> state`` step over ``state_template``'s pytree layout)."""
    time_dtype = np.dtype(time_dtype)
    tbits = time_dtype.itemsize * 8
    jaxpr = closed_jaxpr.jaxpr
    mask = time_leaf_mask(state_template)
    names = time_leaf_names(state_template)
    n_leaves = len(mask)
    if len(jaxpr.invars) < n_leaves or len(jaxpr.outvars) < n_leaves:
        raise ValueError(
            f"jaxpr arity ({len(jaxpr.invars)} in / {len(jaxpr.outvars)} out)"
            f" smaller than the state template's {n_leaves} leaves"
        )

    downcasts: list = []

    def run(jx, in_taints: Sequence, region: tuple) -> list:
        env = _TaintEnv()
        for var, taint in zip(jx.invars, in_taints):
            env.write(var, taint)

        for eqn in jx.eqns:
            reg = _region_of(eqn, region)
            ins = [env.read(v) for v in eqn.invars]
            outs = _apply(eqn, ins, reg)
            for var, taint in zip(eqn.outvars, outs):
                env.write(var, taint)
        return [env.read(v) for v in jx.outvars]

    def _default(eqn, ins) -> list:
        joined = _NO_TAINT
        for t in ins:
            joined = _join(joined, t)
        return [joined if _is_float(v.aval) else _NO_TAINT for v in eqn.outvars]

    def _apply(eqn, ins, region) -> list:
        name = eqn.primitive.name
        if name == "convert_element_type":
            (taint,) = ins
            out = eqn.outvars[0]
            if not _is_float(out.aval):
                return [_NO_TAINT]
            if taint[0] == NONE:
                return [_NO_TAINT]
            if F32_DOMAIN in region:
                # declared exit into the f32 physics domain: the result is
                # no longer a clock
                return [_NO_TAINT]
            if _float_bits(out.aval) < tbits:
                site = _source_of(eqn)
                downcasts.append(site)
                return [(DEGRADED, site)]
            return [taint]
        if name == "while":
            return _run_while(eqn, ins, region)
        if name == "scan":
            return _run_scan(eqn, ins, region)
        if name == "cond":
            return _run_cond(eqn, ins, region)
        sub = [j for p in eqn.params.values() for j in _sub_jaxprs(p)]
        if sub:
            if len(sub) == 1 and len(sub[0].invars) == len(eqn.invars):
                # pjit / shard_map / closed_call / custom-call wrappers:
                # positional pass-through
                outs = run(sub[0], ins, _region_of(eqn, region))
                if len(outs) == len(eqn.outvars):
                    return outs
            # unknown higher-order primitive: conservative join-all
            return _default(eqn, ins)
        return _default(eqn, ins)

    def _run_while(eqn, ins, region):
        reg = _region_of(eqn, region)
        nc = eqn.params["cond_nconsts"]
        nb = eqn.params["body_nconsts"]
        body = eqn.params["body_jaxpr"].jaxpr
        body_consts = ins[nc : nc + nb]
        carry = list(ins[nc + nb :])
        for _ in range(8):  # lattice height bounds convergence well below
            outs = run(body, list(body_consts) + carry, reg)
            new = [_join(c, o) for c, o in zip(carry, outs)]
            if new == carry:
                break
            carry = new
        return carry

    def _run_scan(eqn, ins, region):
        reg = _region_of(eqn, region)
        nc = eqn.params["num_consts"]
        ncarry = eqn.params["num_carry"]
        body = eqn.params["jaxpr"].jaxpr
        consts = list(ins[:nc])
        carry = list(ins[nc : nc + ncarry])
        xs = list(ins[nc + ncarry :])
        ys = [_NO_TAINT] * (len(eqn.outvars) - ncarry)
        for _ in range(8):
            outs = run(body, consts + carry + xs, reg)
            new = [_join(c, o) for c, o in zip(carry, outs[:ncarry])]
            ys = [_join(y, o) for y, o in zip(ys, outs[ncarry:])]
            if new == carry:
                break
            carry = new
        return carry + ys

    def _run_cond(eqn, ins, region):
        reg = _region_of(eqn, region)
        outs = [_NO_TAINT] * len(eqn.outvars)
        for branch in eqn.params["branches"]:
            bouts = run(branch.jaxpr, ins[1:], reg)
            outs = [_join(a, b) for a, b in zip(outs, bouts)]
        return outs

    in_taints = [_NO_TAINT] * len(jaxpr.invars)
    for i, is_time in enumerate(mask):
        if is_time:
            in_taints[i] = (CLEAN, None)
    out_taints = run(jaxpr, in_taints, ())

    in_census = {
        names[i]: str(np.dtype(jaxpr.invars[i].aval.dtype))
        for i in range(n_leaves)
        if mask[i]
    }
    out_census = {
        names[i]: str(np.dtype(jaxpr.outvars[i].aval.dtype))
        for i in range(n_leaves)
        if mask[i]
    }
    degraded = [
        (names[i], out_taints[i][1] or "<unknown>")
        for i in range(n_leaves)
        if mask[i] and out_taints[i][0] == DEGRADED
    ]
    return ClockReport(
        time_dtype=str(time_dtype),
        in_census=in_census,
        out_census=out_census,
        degraded_leaves=degraded,
        downcast_sites=sorted(set(downcasts)),
    )

"""``python -m repro.analysis.simlint`` — run the audit matrix, write a
JSON report, and diff it against the committed baseline.

Usage::

    python -m repro.analysis.simlint --update ANALYSIS_BASELINE.json
    python -m repro.analysis.simlint --check ANALYSIS_BASELINE.json
    python -m repro.analysis.simlint --configs policy_load_balance,trace_on

``--check`` exits non-zero on any violation (rule name + source line are
printed); ``--update`` regenerates the pinned counts while preserving
hand-written waivers.  The sharded cases need 8 virtual devices — the CLI
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` itself when
jax has not been imported yet.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

# must precede the first jax import (device count is fixed at backend init)
_N_VIRTUAL_DEVICES = 8


def _ensure_devices() -> None:
    # importing jax does NOT initialize the backend; the flag takes effect
    # as long as no devices have been queried yet (runpy imports the
    # analysis package — and thus jax — before main() runs)
    flag = f"--xla_force_host_platform_device_count={_N_VIRTUAL_DEVICES}"
    prev = os.environ.get("XLA_FLAGS", "")
    if "host_platform_device_count" not in prev:
        os.environ["XLA_FLAGS"] = f"{prev} {flag}".strip()


def _rules_for(case, baseline_entry, advisory):
    """The named rule set one case must satisfy."""
    from . import rules
    from .jaxpr_audit import (CALLBACK_PRIMS, COLLECTIVE_PRIMS,
                              SCATTER_PRIMS)

    rs = [
        rules.ForbidPrimitive(
            name="no-host-callbacks", prims=CALLBACK_PRIMS,
            why="host round-trips stall the device loop"),
        rules.ExactCount(
            name="cheap-core-scatter-free", prims=SCATTER_PRIMS,
            region="cheap_core", expect="scatter_cheap_core",
            why="XLA:CPU serializes scatters; the cheap-core budget is "
                "pinned and must not grow"),
        rules.NoNewPrimitives(advisory=advisory),
    ]
    if case.kind == "sharded":
        rs.append(rules.ExactCount(
            name="one-all-gather-per-sharded-leaf",
            prims=frozenset({"all_gather"}), expect=case.n_sharded,
            why="the macro-step's whole collective phase is the "
                "top-of-step gather"))
        rs.append(rules.ForbidPrimitive(
            name="no-other-collectives",
            prims=COLLECTIVE_PRIMS - {"all_gather"},
            why="any second collective kind per step breaks the thin "
                "collective-phase contract"))
    else:
        rs.append(rules.ForbidPrimitive(
            name="no-collectives-single-device", prims=COLLECTIVE_PRIMS,
            why="the unsharded engine must stay communication-free"))
    if not case.thermal_on:
        rs.append(rules.ForbidPrimitive(
            name="thermal-off-statically-absent",
            prims=frozenset({"exp", "cos", "sin"}),
            why="disabled thermal must contribute zero equations "
                "(transcendentals are its static signature)"))
    if not case.trace_on:
        rs.append(rules.ForbidPrimitive(
            name="trace-off-statically-absent",
            prims=frozenset({"population_count"}),
            why="disabled tracing must contribute zero equations "
                "(packbits' population_count is its static signature)"))
    return rs


def _audit_one(name, baseline_cases, advisory):
    from . import costmodel, jaxpr_audit, matrix, rules

    case = matrix.build_case(name)
    inv = jaxpr_audit.audit(case.closed_jaxpr)
    clock = jaxpr_audit.clock_audit(
        case.closed_jaxpr, case.state_template, case.time_dtype)
    cost = costmodel.cost_of(case.closed_jaxpr)

    entry = baseline_cases.get(name)
    violations = []
    for rule in _rules_for(case, entry, advisory):
        violations.extend(rule.check(name, inv, entry))
    violations.extend(rules.DtypePolicy().check_clock(name, clock))

    report = {
        "summary": inv.summary(),
        "cost": cost.to_json(),
        "clock": {
            "time_dtype": clock.time_dtype,
            "out_census": clock.out_census,
            "degraded_leaves": clock.degraded_leaves,
            # time-derived values exiting to lower precision outside the
            # tagged f32_domain scopes (benign while degraded_leaves is
            # empty: they feed physics, not clocks)
            "time_downcast_sites": clock.downcast_sites,
        },
        "violations": [v.render() for v in violations],
    }
    if case.n_sharded is not None:
        report["n_sharded_leaves"] = case.n_sharded
    return case, inv, violations, report


def _retrace_check():
    """Run the engine + sharded paths twice each under the sentinel: any
    key traced more than once is a no-retrace violation."""
    from . import retrace, rules
    from ..core import farm as farm_mod
    from ..core import shard_sim, workload
    from ..core.jobs import dag_single
    from ..core.types import SimConfig

    cfg = SimConfig(n_servers=8, n_cores=2, max_jobs=32, max_events=5_000)
    arr = workload.poisson_arrivals(40.0, 10, seed=3)
    specs = [dag_single(0.02) for _ in range(10)]
    mesh = shard_sim.make_mesh(1)
    violations = []
    with retrace.retrace_guard() as retraced:
        for m in (None, mesh):  # engine.run path, then run_sharded path
            farm_mod.simulate(cfg, arr, specs, mesh=m)
            farm_mod.simulate(cfg, arr, specs, mesh=m)  # must hit the cache
        for hit in retraced():
            violations.append(rules.Violation(
                rule="no-retrace", config=hit["tag"],
                message=(f"program key traced {hit['traces']}x — the "
                         f"compile cache leaked: {hit['key'][:200]}")))
        events = retrace.trace_events()
    seen_tags = {e["tag"] for e in events}
    for tag in ("engine.run", "shard_sim.loop"):
        if tag not in seen_tags:
            violations.append(rules.Violation(
                rule="no-retrace", config="sentinel",
                message=f"sentinel saw no '{tag}' trace — the note_trace "
                        f"hook is disconnected"))
    return violations, {"traces": events,
                        "violations": [v.render() for v in violations]}


def main(argv=None) -> int:
    _ensure_devices()
    ap = argparse.ArgumentParser(prog="repro.analysis.simlint")
    ap.add_argument("--out", default="simlint_report.json",
                    help="JSON report path")
    ap.add_argument("--check", metavar="BASELINE",
                    help="diff against a committed baseline; exit 1 on "
                         "violations")
    ap.add_argument("--update", metavar="BASELINE",
                    help="write/refresh the baseline (waivers preserved)")
    ap.add_argument("--configs", default="",
                    help="comma-separated case subset (default: full "
                         "matrix)")
    ap.add_argument("--no-retrace", action="store_true",
                    help="skip the retrace sentinel (it executes small "
                         "simulations)")
    args = ap.parse_args(argv)

    import jax

    from . import costmodel, matrix, rules

    names = matrix.case_names(len(jax.devices()))
    if args.configs:
        want = args.configs.split(",")
        unknown = [w for w in want if w not in names]
        if unknown:
            ap.error(f"unknown configs {unknown}; known: {names}")
        names = [n for n in names if n in want]

    baseline = {}
    if args.check:
        baseline = rules.load_baseline(args.check)
    elif args.update and os.path.exists(args.update):
        baseline = rules.load_baseline(args.update)
    baseline_cases = baseline.get("cases", {})
    advisory = bool(baseline) and baseline.get("jax") != jax.__version__
    if advisory:
        print(f"note: baseline jax {baseline.get('jax')} != runtime "
              f"{jax.__version__}; histogram drift demoted to advisory")

    report = {"jax": jax.__version__, "cases": {}}
    new_cases = {}
    all_violations = []
    for name in names:
        if matrix.needs_x64(name):
            jax.config.update("jax_enable_x64", True)
        try:
            case, inv, violations, case_report = _audit_one(
                name, baseline_cases, advisory)
        finally:
            if matrix.needs_x64(name):
                jax.config.update("jax_enable_x64", False)
        report["cases"][name] = case_report
        new_cases[name] = rules.merge_baseline_entry(
            baseline_cases.get(name), rules.baseline_entry_from(inv))
        all_violations.extend(violations)
        s = case_report["summary"]
        print(f"{name:<26} eqns={s['eqns']:<5} "
              f"scatter={s['scatter']:<3} "
              f"(cheap_core={s['scatter_cheap_core']}) "
              f"collectives={sum(s['collectives'].values())} "
              f"violations={len(violations)}")

    if not args.no_retrace:
        retrace_violations, retrace_report = _retrace_check()
        report["retrace"] = retrace_report
        all_violations.extend(retrace_violations)
        print(f"{'retrace-sentinel':<26} "
              f"traces={len(retrace_report['traces'])} "
              f"violations={len(retrace_violations)}")

    footprints = {label: matrix.footprint_of(cfg)
                  for label, cfg in matrix.state_footprint_cases().items()}
    report["footprints"] = footprints
    print("\nstate footprint (HBM budget):")
    print(costmodel.footprint_table(footprints))
    print("\nlargest fields, farm_65536:")
    print(costmodel.field_table(footprints["farm_65536"]))

    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2, sort_keys=True)
    print(f"\nreport written to {args.out}")

    if args.update:
        rules.save_baseline(args.update, {
            "jax": jax.__version__,
            "cases": new_cases,
        })
        print(f"baseline written to {args.update}")
        return 0

    hard = [v for v in all_violations
            if not (advisory and v.rule == "no-new-primitives")]
    if all_violations:
        print(f"\n{len(all_violations)} violation(s):")
        for v in all_violations:
            print(v.render())
    if args.check:
        missing = [n for n in names if n not in baseline_cases]
        if missing:
            print(f"\nno baseline entry for {missing} — run --update")
            return 1
        return 1 if hard else 0
    return 0


if __name__ == "__main__":
    sys.exit(main())

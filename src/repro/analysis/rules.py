"""Declarative rules over jaxpr inventories, diffed against a committed
baseline.

A rule is a named check over one audited program.  Violations carry the rule
name and the offending equation's source line, so a CI failure reads::

    [cheap-core-scatter-free] policy_load_balance: 19 scatter eqns in region
    'cheap_core', baseline pins 18
        new site: scatter-add at repro/core/engine.py:412 (_apply_events)

Baselines (``ANALYSIS_BASELINE.json``) pin the exact counts the current
engine earns; ``NoNewPrimitives`` additionally pins the full per-region
primitive histogram so *any* structural drift is loud.  Intentional drift is
recorded as a waiver entry ``{config, region, prim, reason}`` (``"*"``
wildcards allowed) rather than silently regenerating the baseline.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional

from . import jaxpr_audit


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    config: str
    message: str
    sites: tuple = ()  # source locations backing the message

    def render(self) -> str:
        lines = [f"[{self.rule}] {self.config}: {self.message}"]
        lines += [f"    at {s}" for s in self.sites]
        return "\n".join(lines)


class Rule:
    """Base: ``check(config_name, inventory, baseline_entry) -> [Violation]``."""

    def check(self, config, inv, baseline_entry):
        raise NotImplementedError


@dataclasses.dataclass
class ForbidPrimitive(Rule):
    """Named primitives must not appear (optionally only within a region)."""

    name: str
    prims: frozenset
    region: Optional[str] = None
    why: str = ""

    def check(self, config, inv, baseline_entry):
        sites = inv.sites_of(self.prims, self.region)
        if not sites:
            return []
        where = f" in region '{self.region}'" if self.region else ""
        found = sorted({s.prim for s in sites})
        return [
            Violation(
                rule=self.name,
                config=config,
                message=(
                    f"{len(sites)} forbidden eqn(s) {found}{where}"
                    + (f" — {self.why}" if self.why else "")
                ),
                sites=tuple(f"{s.prim} at {s.src}" for s in sites[:8]),
            )
        ]


@dataclasses.dataclass
class ExactCount(Rule):
    """A primitive set must appear exactly ``expect`` times.

    ``expect`` may be an int, or the name of a baseline field to read the
    pinned count from (so budgets live in ANALYSIS_BASELINE.json, not code).
    """

    name: str
    prims: frozenset
    expect: object  # int | str (baseline field)
    region: Optional[str] = None
    why: str = ""

    def check(self, config, inv, baseline_entry):
        expect = self.expect
        if isinstance(expect, str):
            if baseline_entry is None or expect not in baseline_entry:
                return [
                    Violation(
                        rule=self.name,
                        config=config,
                        message=f"baseline field '{expect}' missing — run --update",
                    )
                ]
            expect = baseline_entry[expect]
        got = inv.count(self.prims, self.region)
        if got == expect:
            return []
        where = f" in region '{self.region}'" if self.region else ""
        sites = inv.sites_of(self.prims, self.region)
        return [
            Violation(
                rule=self.name,
                config=config,
                message=(
                    f"{got} eqn(s) of {sorted(self.prims)}{where},"
                    f" expected exactly {expect}"
                    + (f" — {self.why}" if self.why else "")
                ),
                sites=tuple(f"{s.prim} at {s.src}" for s in sites[:8]),
            )
        ]


@dataclasses.dataclass
class DtypePolicy(Rule):
    """Clock discipline: declared time leaves keep ``time_dtype`` end to
    end, and no time value is rebuilt from a lossy downcast outside the
    declared ``f32_domain`` regions."""

    name: str = "clock-dtype-policy"

    def check_clock(self, config, report):
        out = []
        for leaf, where, dtype in report.census_violations:
            out.append(
                Violation(
                    rule=self.name,
                    config=config,
                    message=(
                        f"time leaf '{leaf}' ({where}) has dtype {dtype},"
                        f" policy requires {report.time_dtype}"
                    ),
                )
            )
        for leaf, site in report.degraded_leaves:
            out.append(
                Violation(
                    rule=self.name,
                    config=config,
                    message=(
                        f"time leaf '{leaf}' reconstructed from a value"
                        f" downcast below {report.time_dtype} outside"
                        f" '{jaxpr_audit.F32_DOMAIN}'"
                    ),
                    sites=(f"downcast at {site}",),
                )
            )
        return out

    def check(self, config, inv, baseline_entry):
        return []  # clock checks run via check_clock with a ClockReport


@dataclasses.dataclass
class NoNewPrimitives(Rule):
    """The per-region primitive histogram must match the committed baseline
    exactly, modulo explicit waivers."""

    name: str = "no-new-primitives"
    advisory: bool = False  # demoted when the jax version drifted

    def check(self, config, inv, baseline_entry):
        if baseline_entry is None or "histogram" not in baseline_entry:
            return [
                Violation(
                    rule=self.name,
                    config=config,
                    message="no committed histogram for this config — run --update",
                )
            ]
        want = baseline_entry["histogram"]
        got = inv.histogram()
        waivers = baseline_entry.get("waivers", [])
        out = []
        regions = sorted(set(want) | set(got))
        for region in regions:
            wh = want.get(region, {})
            gh = got.get(region, {})
            for prim in sorted(set(wh) | set(gh)):
                w, g = wh.get(prim, 0), gh.get(prim, 0)
                if w == g or _waived(waivers, config, region, prim):
                    continue
                sites = inv.sites_of(prim)
                sites = [s for s in sites if s.region == region][:4]
                out.append(
                    Violation(
                        rule=self.name,
                        config=config,
                        message=(
                            f"region '{region or '<outer>'}': {prim} count"
                            f" {g} != baseline {w}"
                            + (" (advisory: jax version drift)" if self.advisory else "")
                        ),
                        sites=tuple(f"{s.prim} at {s.src}" for s in sites),
                    )
                )
        return out


def _waived(waivers, config, region, prim) -> bool:
    def hit(pat, val):
        return pat == "*" or pat == val

    return any(
        hit(w.get("config", "*"), config)
        and hit(w.get("region", "*"), region)
        and hit(w.get("prim", "*"), prim)
        for w in waivers
    )


# ==========================================================================
# baseline file handling
# ==========================================================================


def load_baseline(path) -> dict:
    with open(path) as fh:
        return json.load(fh)


def save_baseline(path, baseline: dict) -> None:
    with open(path, "w") as fh:
        json.dump(baseline, fh, indent=2, sort_keys=True)
        fh.write("\n")


def baseline_entry_from(inv) -> dict:
    """Build the committed entry for one config from its inventory."""
    return {
        "histogram": inv.histogram(),
        "scatter_cheap_core": inv.count(
            jaxpr_audit.SCATTER_PRIMS, "cheap_core"
        ),
        "scatter_total": inv.count(jaxpr_audit.SCATTER_PRIMS),
        "eqns": inv.n_eqns,
        "waivers": [],
    }


def merge_baseline_entry(old: Optional[dict], new: dict) -> dict:
    """Regenerate counts but keep hand-written waivers."""
    if old and old.get("waivers"):
        new = dict(new)
        new["waivers"] = old["waivers"]
    return new

"""simlint: static analysis over the traced jaxprs the engine actually runs.

The hot loop earned a set of structural contracts across PRs 2-7 (scatter
budgets, one ``all_gather`` per sharded leaf, disabled features statically
absent, f64-clean clocks, no host callbacks, no retraces).  This package
machine-checks them at trace time:

* :mod:`.jaxpr_audit` -- recursive jaxpr walker with region provenance
  (``cheap_core`` / ``full_step`` named scopes) producing a per-primitive
  inventory, plus the clock-dtype taint interpreter.
* :mod:`.rules` -- declarative rules (``ForbidPrimitive``, ``ExactCount``,
  ``DtypePolicy``, ``NoNewPrimitives``) diffed against a committed
  ``ANALYSIS_BASELINE.json`` with explicit waivers.
* :mod:`.costmodel` -- per-equation bytes/FLOPs estimator and the static
  state-footprint (HBM budget) report.
* :mod:`.retrace` -- the compile-cache sentinel: a second trace for an
  identical ``(cfg, mesh, layout)`` key is a failure.
* :mod:`.matrix` -- the audited config matrix (every SchedPolicy, thermal
  off/tracking/throttling, trace on/off, sharded 1/8 devices, the vmapped
  Monte Carlo replica step, f64-clock twins).
* :mod:`.simlint` -- the ``python -m repro.analysis.simlint`` CLI.
"""

from . import costmodel, jaxpr_audit, retrace, rules

__all__ = ["costmodel", "jaxpr_audit", "retrace", "rules"]

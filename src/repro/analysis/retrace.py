"""Retrace sentinel: a second trace for an identical program key is a bug.

PR 7 made ``farm.simulate`` / ``shard_sim.run_sharded`` reuse compiled
programs across calls (jit cache keyed on ``(cfg, state layout)``, an
``lru_cache`` over ``(cfg, mesh, axis, layout, specs)`` for the sharded
loop).  A silent cache miss — e.g. a config object that stops hashing
stably, or a state layout that drifts between calls — costs a full retrace
+ recompile per call and the benchmarks only see it as noise.

The engine's traced entry points call :func:`note_trace` at *trace time*
(a Python side effect inside the jitted body runs only when XLA actually
retraces).  :func:`retrace_guard` scopes the bookkeeping: run the same
simulation twice inside the guard and any key traced more than once is a
named violation.
"""

from __future__ import annotations

import collections
import contextlib

# (tag, key) -> number of traces observed. Module-level so engine/shard_sim
# can call note_trace without importing analysis machinery at trace time.
_TRACE_COUNTS: collections.Counter = collections.Counter()
_ENABLED = False


def note_trace(tag: str, key) -> None:
    """Record one trace of ``tag`` for program ``key``.  Call from inside
    a jitted body (runs only when the tracer actually runs)."""
    if _ENABLED:
        _TRACE_COUNTS[(tag, _freeze(key))] += 1


def _freeze(key):
    if isinstance(key, dict):
        return tuple(sorted((k, _freeze(v)) for k, v in key.items()))
    if isinstance(key, (list, tuple)):
        return tuple(_freeze(v) for v in key)
    return key


def retraced_keys() -> list:
    """Keys traced more than once since the guard was entered."""
    return [
        {"tag": tag, "key": repr(key), "traces": n}
        for (tag, key), n in sorted(_TRACE_COUNTS.items(), key=lambda kv: repr(kv[0]))
        if n > 1
    ]


def trace_events() -> list:
    return [
        {"tag": tag, "key": repr(key), "traces": n}
        for (tag, key), n in sorted(_TRACE_COUNTS.items(), key=lambda kv: repr(kv[0]))
    ]


@contextlib.contextmanager
def retrace_guard():
    """Enable trace counting within the block; yields a callable that
    returns the retraced keys observed so far."""
    global _ENABLED
    _TRACE_COUNTS.clear()
    _ENABLED = True
    try:
        yield retraced_keys
    finally:
        _ENABLED = False

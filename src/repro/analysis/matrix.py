"""The audited config matrix: the compiled programs we actually run.

Each case traces one step program — ``engine.sim_step`` for a
(SchedPolicy × thermal × trace) config, the shard-mapped macro-step on
1 / 8 virtual devices, or the vmapped Monte Carlo replica step — and
packages the closed jaxpr plus everything the rules need (state template
for the clock audit, the sharded-leaf count, static feature flags).

Builders are lazy: :func:`build_case` traces on demand so the CLI can
select configs and sequence the f64 twins after the f32 cases (enabling
``jax_enable_x64`` mid-process must not precede any f32 trace).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class AuditCase:
    """One traced program plus the facts the rules consume."""

    name: str
    closed_jaxpr: object
    state_template: object  # pytree matching the jaxpr's positional leaves
    time_dtype: object
    thermal_on: bool
    trace_on: bool
    n_sharded: Optional[int] = None  # sharded cases: expected all_gathers
    kind: str = "engine"  # engine | sharded | vmap


def _small(n_servers=8, **kw):
    from ..core.types import SimConfig

    base = dict(n_servers=n_servers, n_cores=2, max_jobs=64,
                max_events=20_000)
    base.update(kw)
    return SimConfig(**base)


def _workload(n_jobs=20, lam=40.0, seed=3, defer_slack=None):
    from ..core import workload
    from ..core.jobs import dag_single

    rng = np.random.default_rng(seed)
    arr = workload.poisson_arrivals(lam, n_jobs, seed=seed)
    kw = {} if defer_slack is None else {"defer_slack": defer_slack}
    specs = [dag_single(rng.exponential(0.02), **kw) for _ in range(n_jobs)]
    return arr, specs


def _built_state(cfg, n_jobs=20, topo=None, **wkw):
    from ..core import engine
    from ..core import jobs as jobs_mod

    arr, specs = _workload(n_jobs=n_jobs, **wkw)
    jt = jobs_mod.build_jobs(cfg, np.asarray(arr), specs)
    return engine.init_state(cfg, jt, topo)


def _thermal(**kw):
    from ..core.types import ThermalConfig

    base = dict(enabled=True, r_th=0.5, tau_th=2.0, t_inlet=22.0,
                recirc=0.2, rack_size=2)
    base.update(kw)
    return ThermalConfig(**base)


# --------------------------------------------------------------------------
# config builders: (cfg, topo, workload kwargs)
# --------------------------------------------------------------------------


def _cfg_round_robin():
    from ..core.types import SchedPolicy, SleepPolicy

    return _small(sched_policy=SchedPolicy.ROUND_ROBIN,
                  sleep_policy=SleepPolicy.ALWAYS_ON), None, {}


def _cfg_load_balance():
    from ..core.types import SchedPolicy, SleepPolicy

    return _small(sched_policy=SchedPolicy.LOAD_BALANCE,
                  sleep_policy=SleepPolicy.SINGLE_TIMER), None, {}


def _cfg_network_aware():
    from ..core import topology
    from ..core.types import SchedPolicy

    cfg = _small(sched_policy=SchedPolicy.NETWORK_AWARE, max_jobs=32,
                 tasks_per_job=2, max_children=2, max_flows=64,
                 local_q=32, has_network=True, comm_model=0)
    return cfg, topology.star(8, link_cap=1.0e8), {"chains": True}


def _cfg_provisioned():
    from ..core.types import SchedPolicy

    return _small(sched_policy=SchedPolicy.PROVISIONED), None, {}


def _cfg_wasp():
    from ..core.types import SchedPolicy, SleepPolicy

    return _small(sched_policy=SchedPolicy.WASP_POOLS,
                  sleep_policy=SleepPolicy.WASP), None, {}


def _cfg_thermal_aware():
    from ..core.types import SchedPolicy

    return _small(sched_policy=SchedPolicy.THERMAL_AWARE,
                  thermal=_thermal()), None, {}


def _cfg_carbon_aware():
    from ..core.types import SchedPolicy

    tcfg = _thermal(defer_threshold=350.0, carbon_period=600.0,
                    carbon_swing=0.5)
    return (_small(sched_policy=SchedPolicy.CARBON_AWARE, thermal=tcfg),
            None, {"defer_slack": 300.0})


def _cfg_thermal_tracking():
    from ..core.types import SchedPolicy

    return _small(sched_policy=SchedPolicy.LOAD_BALANCE,
                  thermal=_thermal()), None, {}


def _cfg_thermal_throttling():
    from ..core.types import SchedPolicy

    tcfg = _thermal(t_throttle=50.0, t_release=45.0, throttle_freq=0.5,
                    throttle_power_scale=0.6)
    return _small(sched_policy=SchedPolicy.LOAD_BALANCE,
                  thermal=tcfg), None, {}


def _cfg_trace_on():
    from ..core.types import SchedPolicy, TraceConfig

    return _small(sched_policy=SchedPolicy.LOAD_BALANCE,
                  trace=TraceConfig(enabled=True)), None, {}


def _cfg_f64(builder):
    import jax.numpy as jnp

    def build():
        cfg, topo, wkw = builder()
        return dataclasses.replace(cfg, time_dtype=jnp.float64), topo, wkw

    return build


_ENGINE_CONFIGS = {
    "policy_round_robin": _cfg_round_robin,
    "policy_load_balance": _cfg_load_balance,
    "policy_network_aware": _cfg_network_aware,
    "policy_provisioned": _cfg_provisioned,
    "policy_wasp": _cfg_wasp,
    "policy_thermal_aware": _cfg_thermal_aware,
    "policy_carbon_aware": _cfg_carbon_aware,
    "thermal_tracking": _cfg_thermal_tracking,
    "thermal_throttling": _cfg_thermal_throttling,
    "trace_on": _cfg_trace_on,
}

_F64_CONFIGS = {
    "f64_load_balance": _cfg_f64(_cfg_load_balance),
    "f64_thermal_throttling": _cfg_f64(_cfg_thermal_throttling),
}


def _build_workload_state(cfg, topo, wkw):
    if wkw.get("chains"):
        from ..core import engine
        from ..core import jobs as jobs_mod
        from ..core import workload
        from ..core.jobs import dag_chain

        rng = np.random.default_rng(2)
        arr = workload.poisson_arrivals(25.0, 16, seed=2)
        specs = [dag_chain(rng.uniform(0.01, 0.04, size=2),
                           edge_bytes=float(rng.uniform(4e6, 8e6)))
                 for _ in range(16)]
        jt = jobs_mod.build_jobs(cfg, np.asarray(arr), specs)
        return engine.init_state(cfg, jt, topo)
    return _built_state(cfg, topo=topo, **wkw)


def _engine_case(name, builder) -> AuditCase:
    import jax

    from ..core import engine

    cfg, topo, wkw = builder()
    state, tc = _build_workload_state(cfg, topo, wkw)
    jx = jax.make_jaxpr(engine.step_closure(cfg, tc))(state)
    return AuditCase(
        name=name, closed_jaxpr=jx, state_template=state,
        time_dtype=cfg.time_dtype, thermal_on=cfg.thermal.enabled,
        trace_on=cfg.trace.enabled, kind="engine")


def _montecarlo_case() -> AuditCase:
    import jax

    from ..core import engine, montecarlo, workload
    from ..core.jobs import dag_single

    cfg = _small(max_events=5_000)
    R = 4
    arrs = np.stack([workload.poisson_arrivals(40.0, 12, seed=s)
                     for s in range(R)])
    specs = [dag_single(0.02) for _ in range(12)]
    state_b, tc = montecarlo.batched_state(cfg, arrs, specs)
    jx = jax.make_jaxpr(jax.vmap(engine.step_closure(cfg, tc)))(state_b)
    return AuditCase(
        name="montecarlo_vmap", closed_jaxpr=jx, state_template=state_b,
        time_dtype=cfg.time_dtype, thermal_on=False, trace_on=False,
        kind="vmap")


def _sharded_case(n_devices: int) -> AuditCase:
    from ..core import shard_sim
    from ..core.types import PartitionConfig, TraceConfig

    cfg = _small(
        n_servers=16, max_jobs=32, max_events=1_000,
        thermal=_thermal(), trace=TraceConfig(enabled=True),
        partition=PartitionConfig(n_shards=n_devices))
    state, tc = _built_state(cfg, n_jobs=5)
    mesh = shard_sim.make_mesh(n_devices)
    jx = shard_sim.sharded_step_jaxpr(state, cfg, tc, mesh)
    return AuditCase(
        name=f"sharded_d{n_devices}", closed_jaxpr=jx,
        state_template=state, time_dtype=cfg.time_dtype, thermal_on=True,
        trace_on=True,
        n_sharded=shard_sim.n_sharded_leaves(state, cfg, mesh),
        kind="sharded")


def case_names(n_devices_available: int = 1) -> list:
    """All case names in build order (f32 first, f64 twins last — the
    CLI enables x64 between the two groups)."""
    names = list(_ENGINE_CONFIGS) + ["montecarlo_vmap", "sharded_d1"]
    if n_devices_available >= 8:
        names.append("sharded_d8")
    names += list(_F64_CONFIGS)
    return names


def needs_x64(name: str) -> bool:
    return name in _F64_CONFIGS


def build_case(name: str) -> AuditCase:
    if name in _ENGINE_CONFIGS:
        return _engine_case(name, _ENGINE_CONFIGS[name])
    if name in _F64_CONFIGS:
        return _engine_case(name, _F64_CONFIGS[name])
    if name == "montecarlo_vmap":
        return _montecarlo_case()
    if name.startswith("sharded_d"):
        return _sharded_case(int(name[len("sharded_d"):]))
    raise KeyError(f"unknown audit case '{name}'")


def state_footprint_cases() -> dict:
    """Configs for the HBM-budget table, including the 65536-server farm
    (sized via eval_shape — nothing is materialised)."""
    from ..core.types import ThermalConfig, TraceConfig

    return {
        "farm_8": _small(),
        "farm_1024": _small(n_servers=1024, max_jobs=4096),
        "farm_65536": _small(
            n_servers=65536, n_cores=2, max_jobs=65536,
            thermal=ThermalConfig(enabled=True, rack_size=32),
            trace=TraceConfig(enabled=True)),
    }


def footprint_of(cfg) -> dict:
    """State-footprint via eval_shape over an init closure (no arrays)."""
    from ..core import engine
    from ..core import jobs as jobs_mod
    from ..core.jobs import dag_single
    from . import costmodel

    def init():
        jt = jobs_mod.build_jobs(cfg, np.zeros(1), [dag_single(0.01)])
        state, _ = engine.init_state(cfg, jt)
        return state

    return costmodel.state_footprint(init)

"""Static per-equation cost model: bytes touched, FLOPs, arithmetic
intensity, and the state-footprint (HBM budget) table.

This is a *jaxpr-level* estimate, not a compiled-module measurement: loop
bodies are counted once (static structure, same convention as
``roofline.analysis.raw_stats``), fusion is ignored, and bytes are the sum
of input+output aval sizes per equation.  That makes the numbers an upper
bound on memory traffic and a structural fingerprint — good for "did this
PR double the bytes the cheap core touches", not for wall-clock prediction
(the benchmarks guard that).

``state_footprint`` sizes the full ``SimState`` pytree via
``jax.eval_shape`` without materialising it, so the 65536-server farm's HBM
budget is a printed table rather than a guess.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from .jaxpr_audit import iter_eqns

# same hardware model as roofline/analysis.py (TPU v5e)
from ..roofline.analysis import HBM_BW, PEAK_FLOPS  # noqa: F401

HBM_PER_CHIP = 16e9  # bytes, v5e


def _aval_bytes(aval) -> int:
    if not hasattr(aval, "dtype") or not hasattr(aval, "shape"):
        return 0
    n = 1
    for d in aval.shape:
        try:
            n *= int(d)
        except TypeError:  # symbolic dim
            return 0
    return n * np.dtype(aval.dtype).itemsize


def _out_size(eqn) -> int:
    return sum(
        int(np.prod(v.aval.shape)) if hasattr(v.aval, "shape") else 0
        for v in eqn.outvars
    )


_ELEMENTWISE_FLOP_WEIGHT = {
    "exp": 8,
    "log": 8,
    "sin": 8,
    "cos": 8,
    "tanh": 8,
    "erf": 8,
    "rsqrt": 4,
    "sqrt": 4,
    "div": 4,
    "pow": 8,
    "integer_pow": 2,
}

_REDUCTIONS = frozenset(
    {
        "reduce_sum",
        "reduce_max",
        "reduce_min",
        "reduce_prod",
        "reduce_and",
        "reduce_or",
        "argmax",
        "argmin",
        "cumsum",
        "cummax",
        "cummin",
        "cumlogsumexp",
    }
)

_ZERO_FLOP = frozenset(
    {
        "broadcast_in_dim",
        "reshape",
        "transpose",
        "squeeze",
        "slice",
        "concatenate",
        "convert_element_type",
        "copy",
        "rev",
        "iota",
        "gather",
        "scatter",
        "dynamic_slice",
        "dynamic_update_slice",
        "pad",
        "bitcast_convert_type",
        "stop_gradient",
        "select_n",
    }
)


def eqn_cost(eqn) -> tuple:
    """(bytes, flops) estimate for one equation (sub-jaxprs excluded —
    the walker visits their eqns separately)."""
    name = eqn.primitive.name
    has_sub = any(
        isinstance(p, (jax.core.ClosedJaxpr, jax.core.Jaxpr))
        or (
            isinstance(p, (tuple, list))
            and any(isinstance(q, (jax.core.ClosedJaxpr, jax.core.Jaxpr)) for q in p)
        )
        for p in eqn.params.values()
    )
    if has_sub:
        return 0, 0  # charged to the inner eqns
    bytes_ = sum(
        _aval_bytes(v.aval) for v in eqn.invars if not isinstance(v, jax.core.Literal)
    ) + sum(_aval_bytes(v.aval) for v in eqn.outvars)
    if name in _ZERO_FLOP:
        return bytes_, 0
    if name == "dot_general":
        dims = eqn.params["dimension_numbers"][0][0]
        lhs = eqn.invars[0].aval
        contracted = 1
        for d in dims:
            contracted *= int(lhs.shape[d])
        return bytes_, 2 * _out_size(eqn) * contracted
    if name in _REDUCTIONS:
        insz = sum(
            int(np.prod(v.aval.shape))
            for v in eqn.invars
            if hasattr(v.aval, "shape") and not isinstance(v, jax.core.Literal)
        )
        return bytes_, insz
    if name == "sort":
        insz = max(
            (
                int(np.prod(v.aval.shape))
                for v in eqn.invars
                if hasattr(v.aval, "shape")
            ),
            default=0,
        )
        return bytes_, insz * max(int(np.log2(max(insz, 2))), 1)
    weight = _ELEMENTWISE_FLOP_WEIGHT.get(name, 1)
    return bytes_, weight * _out_size(eqn)


@dataclasses.dataclass
class CostReport:
    """Rolled-up static cost of one traced program."""

    total_bytes: int
    total_flops: int
    by_region: dict  # {region: {"bytes": int, "flops": int, "eqns": int}}

    @property
    def arithmetic_intensity(self) -> float:
        return self.total_flops / max(self.total_bytes, 1)

    def to_json(self) -> dict:
        return {
            "bytes": self.total_bytes,
            "flops": self.total_flops,
            "arithmetic_intensity": round(self.arithmetic_intensity, 4),
            "by_region": self.by_region,
        }


def cost_of(closed_jaxpr) -> CostReport:
    jaxpr = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    by_region: dict = {}
    total_b = total_f = 0
    for eqn, reg in iter_eqns(jaxpr):
        b, f = eqn_cost(eqn)
        key = "/".join(reg)
        slot = by_region.setdefault(key, {"bytes": 0, "flops": 0, "eqns": 0})
        slot["bytes"] += b
        slot["flops"] += f
        slot["eqns"] += 1
        total_b += b
        total_f += f
    return CostReport(
        total_bytes=total_b,
        total_flops=total_f,
        by_region=dict(sorted(by_region.items())),
    )


# ==========================================================================
# state footprint / HBM budget
# ==========================================================================


def state_footprint(state_fn, *args) -> dict:
    """Size the pytree returned by ``state_fn(*args)`` via ``eval_shape``
    (nothing is materialised).  Returns ``{"total_bytes", "by_field"}``
    with ``by_field`` grouped on the first path component."""
    shapes = jax.eval_shape(state_fn, *args)
    leaves, _ = jax.tree_util.tree_flatten_with_path(shapes)
    by_field: dict = {}
    total = 0
    for path, leaf in leaves:
        b = _aval_bytes(leaf)
        key = jax.tree_util.keystr(path[:1]) or "<root>"
        by_field[key] = by_field.get(key, 0) + b
        total += b
    return {"total_bytes": total, "by_field": dict(sorted(by_field.items()))}


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024 or unit == "GiB":
            return f"{n:8.2f} {unit}"
        n /= 1024
    return f"{n:.2f} GiB"


def footprint_table(footprints: dict, hbm_per_chip: float = HBM_PER_CHIP) -> str:
    """Render ``{label: footprint_dict}`` as the HBM-budget table."""
    lines = [
        f"{'config':<28} {'state bytes':>14} {'% of HBM/chip':>14}",
        "-" * 58,
    ]
    for label, fp in footprints.items():
        total = fp["total_bytes"]
        lines.append(
            f"{label:<28} {_fmt_bytes(total):>14} {100 * total / hbm_per_chip:13.2f}%"
        )
    return "\n".join(lines)


def field_table(fp: dict) -> str:
    lines = [f"{'field':<24} {'bytes':>14}", "-" * 40]
    for field, b in sorted(fp["by_field"].items(), key=lambda kv: -kv[1]):
        lines.append(f"{field:<24} {_fmt_bytes(b):>14}")
    lines.append("-" * 40)
    lines.append(f"{'total':<24} {_fmt_bytes(fp['total_bytes']):>14}")
    return "\n".join(lines)

"""Fused telemetry accumulation Pallas TPU kernel.

One VMEM pass per engine step fuses the two latency-histogram scatter-adds
(job + task granularity) with the windowed time-series bucketing
(core/telemetry.py).  Scatter-add is hostile to the TPU's vector unit, so
each block of latencies is binned via a one-hot compare against the bin
iota and reduced at VPU width; the histograms and the window matrix stay
resident in VMEM across the sequential grid (revisited output blocks),
so HBM sees exactly one read and one write of each accumulator.

Oracle: ref.telemetry_accum_reference; swept in tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from . import ref
from .compat import CompilerParams


def _kernel(widx_ref, wvals_ref, jv_ref, jw_ref, tv_ref, tw_ref,
            jh_in_ref, th_in_ref, win_in_ref,
            jh_ref, th_ref, win_ref, *, lo, hi, n_bins):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        jh_ref[...] = jh_in_ref[...]
        th_ref[...] = th_in_ref[...]
        rows = jax.lax.broadcasted_iota(jnp.int32, win_in_ref.shape, 0)
        win_ref[...] = win_in_ref[...] + jnp.where(
            rows == widx_ref[0], wvals_ref[...][None, :], 0.0)

    def contrib(vals, wts):
        # ref.log_bin keeps kernel and jnp oracle bit-identical
        bins = ref.log_bin(vals, lo, hi, n_bins)
        cols = jax.lax.broadcasted_iota(
            jnp.int32, (vals.shape[0], n_bins), 1)
        onehot = (bins[:, None] == cols).astype(jnp.float32)
        return (onehot * wts[:, None]).sum(axis=0)

    jh_ref[...] += contrib(jv_ref[...], jw_ref[...])
    th_ref[...] += contrib(tv_ref[...], tw_ref[...])


def telemetry_accum(job_vals, job_wts, task_vals, task_wts,
                    job_hist, task_hist, win, widx, wvals,
                    lo, hi, *, block=1024, interpret=False):
    """Fused telemetry update.  job_vals/job_wts (J,) f32; task_vals/
    task_wts (M,) f32; job_hist/task_hist (B,) f32; win (W, K) f32;
    widx () int32 window index; wvals (K,) f32 window increments;
    lo/hi python floats — the log-spaced bin range.

    Returns (job_hist, task_hist, win) with this step's contributions
    accumulated; semantics match ref.telemetry_accum_reference.
    """
    B = job_hist.shape[0]
    lo, hi = float(lo), float(hi)

    def pad_stream(vals, wts, n_blocks):
        n = vals.shape[0]
        pad = n_blocks * block - n
        if pad:
            vals = jnp.pad(vals, (0, pad), constant_values=lo)
            wts = jnp.pad(wts, (0, pad))    # zero weight: no contribution
        return vals.astype(jnp.float32), wts.astype(jnp.float32)

    n_blocks = max(pl.cdiv(job_vals.shape[0], block),
                   pl.cdiv(task_vals.shape[0], block))
    jv, jw = pad_stream(job_vals, job_wts, n_blocks)
    tv, tw = pad_stream(task_vals, task_wts, n_blocks)
    W, K = win.shape

    kernel = functools.partial(_kernel, lo=lo, hi=hi, n_bins=B)
    widx1 = jnp.asarray(widx, jnp.int32).reshape(1)

    jh, th, w = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),            # widx
            pl.BlockSpec((K,), lambda i: (0,)),            # wvals
            pl.BlockSpec((block,), lambda i: (i,)),        # job vals
            pl.BlockSpec((block,), lambda i: (i,)),        # job wts
            pl.BlockSpec((block,), lambda i: (i,)),        # task vals
            pl.BlockSpec((block,), lambda i: (i,)),        # task wts
            pl.BlockSpec((B,), lambda i: (0,)),            # job hist in
            pl.BlockSpec((B,), lambda i: (0,)),            # task hist in
            pl.BlockSpec((W, K), lambda i: (0, 0)),        # win in
        ],
        out_specs=[
            pl.BlockSpec((B,), lambda i: (0,)),
            pl.BlockSpec((B,), lambda i: (0,)),
            pl.BlockSpec((W, K), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B,), jnp.float32),
            jax.ShapeDtypeStruct((B,), jnp.float32),
            jax.ShapeDtypeStruct((W, K), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(widx1, wvals.astype(jnp.float32), jv, jw, tv, tw,
      job_hist, task_hist, win)
    return jh, th, w

"""Jitted public wrappers around the Pallas kernels.

On this CPU container the kernels execute under ``interpret=True`` (Pallas
interpreter runs the kernel body in Python for correctness); on a real TPU
set ``interpret=False`` (default resolved from the backend) to get the
Mosaic-compiled kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import dcsim_step as _dc
from . import flash_attention as _fa
from . import ssm_scan as _ssm


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    block_q=128, block_k=128, interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, block_q=block_q,
                               block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_d", "chunk_t",
                                             "interpret"))
def ssm_scan(dt, Bm, Cm, x, A, *, block_d=256, chunk_t=16, interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    return _ssm.ssm_scan(dt, Bm, Cm, x, A, block_d=block_d, chunk_t=chunk_t,
                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=("p_core_active", "p_core_idle",
                                             "block_n", "interpret"))
def dcsim_advance(core_busy, srv_state, energy, busy_seconds, t, t_next,
                  state_power, srv_wake_at=None, srv_idle_since=None,
                  srv_tau=None, *, p_core_active=13.0, p_core_idle=2.0,
                  block_n=256, interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    return _dc.dcsim_advance(core_busy, srv_state, energy, busy_seconds,
                             t, t_next, state_power,
                             p_core_active, p_core_idle,
                             srv_wake_at, srv_idle_since, srv_tau,
                             block_n=block_n, interpret=interpret)

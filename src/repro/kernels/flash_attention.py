"""Flash attention Pallas TPU kernel (prefill / train hot spot).

Classic streaming-softmax formulation: the grid is (batch, q_heads,
q_blocks, kv_blocks) with the kv dimension marked "arbitrary" so each
(b, h, qb) program accumulates over kv blocks in VMEM scratch — running max
m, running sum l, and the (block_q, head_dim) f32 accumulator — and writes
the normalized output at the last kv step.  GQA is handled by the K/V
index_map (kv head = h // group); causal and sliding-window masks and the
gemma2 attention softcap are applied in-kernel.

Block sizes default to (block_q, block_k) = (128, 128): MXU-aligned on the
(8,128)/(128,128) register tiling, and the VMEM working set
q(128×hd) + k/v(128×hd) + acc(128×hd f32) stays well under 16 MB for
hd ≤ 256.

Correctness oracle: ``ref.mha_reference`` (pure jnp, the same math as
models/layers.attend); validated under interpret=True in
tests/test_kernels.py across shape/dtype/window/softcap sweeps.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from .compat import CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale, causal, window, softcap, block_q, block_k, kv_len):
    qb = pl.program_id(2)
    kb = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                  # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap

    q_pos = qb * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                  # (bq,)
    m_cur = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(kb == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    block_q=128, block_k=128, interpret=False):
    """q (B, H, Sq, hd); k/v (B, KV, Skv, hd); H % KV == 0.
    Returns (B, H, Sq, hd) in q.dtype."""
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    nq = -(-Sq // block_q)
    nk = -(-Skv // block_k)
    pad_q = nq * block_q - Sq
    pad_k = nk * block_k - Skv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, kv_len=Skv)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, qb, kb: (b, h, qb, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, qb, kb: (b, h // G, kb, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, qb, kb: (b, h // G, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, qb, kb: (b, h, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * block_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]

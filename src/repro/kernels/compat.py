"""Pallas TPU API drift shims.

``pltpu.TPUCompilerParams`` was renamed ``pltpu.CompilerParams`` in newer
jax releases; resolve whichever exists so the kernels run on both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

"""Fused data-center-simulator advance Pallas TPU kernel.

The engine's hot loop (core/engine.sim_step) streams the whole farm state
from HBM several times per event: once for the min-reduction, once for
energy accrual, once for the completion update.  This kernel fuses the
"advance farm to t_next" into a single VMEM pass over server blocks:

  per server block (block_n, C):
    busy count -> piecewise power -> energy += P·dt, busy_seconds += busy·dt
    completions (busy_until <= t_next) freed to INF, mask emitted
    next-event candidate: min over the block of surviving busy_until,
    pending wake completions, and idle delay-timer expiries — the farm's
    contribution to the NEXT next_event_time, so the following iteration's
    min-reduction needs no extra pass over the farm arrays

It is the TPU analogue of the paper's event-queue pop + clock advance —
O(state) streaming with everything fused at VPU width, instead of a heap's
pointer chasing (DESIGN.md §3.4).

Oracle: ref.dcsim_advance_reference; swept in tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from .compat import CompilerParams

INF = 1.0e30


def _kernel(t_ref, tn_ref, busy_ref, state_ref, energy_ref, bsec_ref,
            wake_ref, isince_ref, tau_ref, thr_ref, ptab_ref,
            new_busy_ref, done_ref, new_energy_ref, new_bsec_ref, next_ref,
            *, p_core_active, p_core_idle, n_cores, throttle_power_scale):
    dt = (tn_ref[0] - t_ref[0]).astype(jnp.float32)
    cb = busy_ref[...]                                    # (bn, C)
    st = state_ref[...]                                   # (bn,)
    busy = (cb < INF).astype(jnp.float32).sum(axis=1)     # (bn,)
    awake = st <= 1
    # thermally throttled servers draw scaled active-core power
    # (linear-DVFS approximation, mirrors power.server_power)
    p_act = jnp.where(thr_ref[...] != 0,
                      jnp.float32(p_core_active * throttle_power_scale),
                      jnp.float32(p_core_active))
    p_awake = ptab_ref[0] + busy * p_act \
        + (n_cores - busy) * p_core_idle
    p_state = ptab_ref[jnp.clip(st, 0, ptab_ref.shape[0] - 1)]
    p = jnp.where(awake, p_awake, p_state)
    new_energy_ref[...] = energy_ref[...] + p * dt
    new_bsec_ref[...] = bsec_ref[...] + busy * dt
    done = cb <= tn_ref[0]
    done_ref[...] = done.astype(jnp.int8)
    new_busy = jnp.where(done, INF, cb)
    new_busy_ref[...] = new_busy
    # farm candidates for the next event: surviving completions, pending
    # wakeups, and delay-timer expiries of IDLE (state==1) servers
    timer = jnp.where(st == 1, isince_ref[...] + tau_ref[...], INF)
    cand = jnp.minimum(new_busy.min(axis=1),
                       jnp.minimum(wake_ref[...], timer))
    next_ref[0] = cand.min()


def dcsim_advance(core_busy, srv_state, energy, busy_seconds, t, t_next,
                  state_power, p_core_active, p_core_idle,
                  srv_wake_at=None, srv_idle_since=None, srv_tau=None,
                  throttled=None, *, throttle_power_scale=1.0,
                  block_n=256, interpret=False):
    """Fused farm advance.  core_busy (N, C) f32; srv_state (N,) int32;
    energy/busy_seconds/srv_wake_at/srv_idle_since/srv_tau (N,) f32;
    t/t_next scalars; state_power (SrvState.NUM,) f32 table (index 0 =
    base power of an awake server); throttled (N,) bool/int —
    thermally-throttled servers accrue active-core power scaled by
    ``throttle_power_scale`` (the PR 3 linear-DVFS coupling).

    Returns (new_core_busy, done_mask (N, C) bool, energy, busy_seconds,
    next_candidate) where next_candidate is the farm's min next-event time
    after the advance (INF when nothing is pending).
    """
    N, C = core_busy.shape
    if srv_wake_at is None:
        srv_wake_at = jnp.full((N,), INF, jnp.float32)
    if srv_idle_since is None:
        srv_idle_since = jnp.zeros((N,), jnp.float32)
    if srv_tau is None:
        srv_tau = jnp.full((N,), INF, jnp.float32)
    if throttled is None:
        throttled = jnp.zeros((N,), jnp.int32)
    throttled = throttled.astype(jnp.int32)
    block_n = min(block_n, N)
    pad = (-N) % block_n
    if pad:
        core_busy = jnp.pad(core_busy, ((0, pad), (0, 0)),
                            constant_values=INF)
        srv_state = jnp.pad(srv_state, (0, pad), constant_values=4)  # OFF
        energy = jnp.pad(energy, (0, pad))
        busy_seconds = jnp.pad(busy_seconds, (0, pad))
        srv_wake_at = jnp.pad(srv_wake_at, (0, pad), constant_values=INF)
        srv_idle_since = jnp.pad(srv_idle_since, (0, pad))
        srv_tau = jnp.pad(srv_tau, (0, pad), constant_values=INF)
        throttled = jnp.pad(throttled, (0, pad))
    Np = N + pad
    grid = (Np // block_n,)

    kernel = functools.partial(_kernel, p_core_active=p_core_active,
                               p_core_idle=p_core_idle, n_cores=C,
                               throttle_power_scale=throttle_power_scale)
    t1 = jnp.asarray(t, jnp.float32).reshape(1)
    t2 = jnp.asarray(t_next, jnp.float32).reshape(1)

    nb, dm, en, bs, nc = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),                    # t
            pl.BlockSpec((1,), lambda i: (0,)),                    # t_next
            pl.BlockSpec((block_n, C), lambda i: (i, 0)),          # busy
            pl.BlockSpec((block_n,), lambda i: (i,)),              # state
            pl.BlockSpec((block_n,), lambda i: (i,)),              # energy
            pl.BlockSpec((block_n,), lambda i: (i,)),              # bsec
            pl.BlockSpec((block_n,), lambda i: (i,)),              # wake_at
            pl.BlockSpec((block_n,), lambda i: (i,)),              # idle_since
            pl.BlockSpec((block_n,), lambda i: (i,)),              # tau
            pl.BlockSpec((block_n,), lambda i: (i,)),              # throttled
            pl.BlockSpec((state_power.shape[0],), lambda i: (0,)),  # table
        ],
        out_specs=[
            pl.BlockSpec((block_n, C), lambda i: (i, 0)),
            pl.BlockSpec((block_n, C), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),                    # next cand
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np, C), core_busy.dtype),
            jax.ShapeDtypeStruct((Np, C), jnp.int8),
            jax.ShapeDtypeStruct((Np,), jnp.float32),
            jax.ShapeDtypeStruct((Np,), jnp.float32),
            jax.ShapeDtypeStruct((Np // block_n,), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(t1, t2, core_busy, srv_state, energy, busy_seconds,
      srv_wake_at, srv_idle_since, srv_tau, throttled, state_power)
    return (nb[:N], dm[:N].astype(bool), en[:N], bs[:N], nc.min())

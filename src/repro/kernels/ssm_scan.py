"""Chunked selective-SSM scan Pallas TPU kernel (hymba's Mamba mixer).

The recurrence h[t] = exp(dt·A)⊙h[t-1] + (dt·x)[t]⊗B[t] is elementwise over
the (Dss, N) state — VPU work, not MXU — so the kernel's job is purely a
memory-hierarchy one: tile Dss into VMEM-resident channel blocks, keep the
running state h in VMEM scratch across sequential time chunks (grid
dimension marked "arbitrary"), and stream dt/B/C/x through.  One HBM pass
instead of S tiny scan iterations; the time chunk is unrolled inside the
kernel body over registers.

Grid: (B, Dss/block_d, S/chunk_t) — batch and channel blocks parallel, time
chunks sequential.  State block (block_d, N) f32 lives in scratch; with
block_d=512, N=16 that is 32 KB — negligible, the VMEM budget goes to the
streamed (chunk_t, block_d) inputs.

Oracle: ref.ssm_scan_reference (the engine's lax.scan).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from .compat import CompilerParams


def _kernel(dt_ref, b_ref, c_ref, x_ref, a_ref, y_ref, h_ref, *, chunk_t):
    tb = pl.program_id(2)

    @pl.when(tb == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...].astype(jnp.float32)                    # (bd, N)
    h = h_ref[...]                                        # (bd, N)
    ys = []
    for i in range(chunk_t):                              # unrolled in VREGs
        dt_t = dt_ref[0, i].astype(jnp.float32)           # (bd,)
        x_t = x_ref[0, i].astype(jnp.float32)             # (bd,)
        b_t = b_ref[0, i].astype(jnp.float32)             # (N,)
        c_t = c_ref[0, i].astype(jnp.float32)             # (N,)
        da = jnp.exp(dt_t[:, None] * a)                   # (bd, N)
        h = da * h + (dt_t * x_t)[:, None] * b_t[None, :]
        ys.append(jnp.sum(h * c_t[None, :], axis=1))      # (bd,)
    h_ref[...] = h
    y_ref[0] = jnp.stack(ys).astype(y_ref.dtype)          # (chunk_t, bd)


def ssm_scan(dt, Bm, Cm, x, A, *, block_d=256, chunk_t=16, interpret=False):
    """dt/x (B, S, Dss); Bm/Cm (B, S, N); A (Dss, N).
    Returns y (B, S, Dss) = C·h with h the selective-SSM state."""
    B, S, Dss = x.shape
    N = Bm.shape[-1]
    block_d = min(block_d, Dss)
    chunk_t = min(chunk_t, S)
    assert Dss % block_d == 0, (Dss, block_d)
    assert S % chunk_t == 0, (S, chunk_t)
    nd = Dss // block_d
    nt = S // chunk_t

    kernel = functools.partial(_kernel, chunk_t=chunk_t)
    out = pl.pallas_call(
        kernel,
        grid=(B, nd, nt),
        in_specs=[
            pl.BlockSpec((1, chunk_t, block_d),
                         lambda b, d, t: (b, t, d)),       # dt
            pl.BlockSpec((1, chunk_t, N), lambda b, d, t: (b, t, 0)),  # B
            pl.BlockSpec((1, chunk_t, N), lambda b, d, t: (b, t, 0)),  # C
            pl.BlockSpec((1, chunk_t, block_d),
                         lambda b, d, t: (b, t, d)),       # x
            pl.BlockSpec((block_d, N), lambda b, d, t: (d, 0)),        # A
        ],
        out_specs=pl.BlockSpec((1, chunk_t, block_d),
                               lambda b, d, t: (b, t, d)),
        out_shape=jax.ShapeDtypeStruct((B, S, Dss), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(dt, Bm, Cm, x, A)
    return out

"""Pallas TPU kernels for the perf-critical hot spots, each with a jitted
wrapper (ops.py) and a pure-jnp oracle (ref.py):

  flash_attention  prefill/train attention (causal, sliding-window,
                   softcap, GQA) — streaming softmax, VMEM-resident scores
  ssm_scan         selective-SSM recurrence (hymba) — state in VMEM
                   scratch across sequential time chunks
  dcsim_step       the simulator's fused farm-advance (min + energy +
                   completion) — the TPU analogue of the event-queue pop
  telemetry_bin    fused telemetry accumulation (latency-histogram binning
                   + time-series window bucketing in one VMEM pass)
"""
from . import ops, ref, telemetry_bin  # noqa: F401

"""Pure-jnp oracles for every Pallas kernel (the correctness references
used by tests/test_kernels.py)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def mha_reference(q, k, v, *, causal=True, window=0, softcap=0.0):
    """q (B, H, Sq, hd); k/v (B, KV, Skv, hd).  Dense softmax attention."""
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, Sq, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, kf) / math.sqrt(hd)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, vf)
    return o.reshape(B, H, Sq, hd).astype(q.dtype)


def ssm_scan_reference(dt, Bm, Cm, x, A):
    """Selective-SSM recurrence (the lax.scan in models/ssm.py).

    dt/x (B, S, Dss); Bm/Cm (B, S, N); A (Dss, N) negative reals.
    Returns (y (B, S, Dss), h_final (B, Dss, N)); f32 state."""
    B, S, Dss = x.shape
    N = Bm.shape[-1]

    def step(h, inp):
        dt_t, B_t, C_t, x_t = inp
        da = jnp.exp(dt_t[..., None] * A[None])
        h = da * h + (dt_t * x_t)[..., None] * B_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    h0 = jnp.zeros((B, Dss, N), jnp.float32)
    xs = (dt.transpose(1, 0, 2).astype(jnp.float32),
          Bm.transpose(1, 0, 2).astype(jnp.float32),
          Cm.transpose(1, 0, 2).astype(jnp.float32),
          x.transpose(1, 0, 2).astype(jnp.float32))
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2).astype(x.dtype), h


def log_bin(vals, lo: float, hi: float, n_bins: int):
    """Log-spaced histogram bin index for each value: values below ``lo``
    clamp into bin 0, values >= ``hi`` into bin n_bins-1."""
    scale = n_bins / math.log(hi / lo)
    raw = jnp.log(jnp.maximum(vals, lo) / lo) * scale
    return jnp.clip(raw.astype(jnp.int32), 0, n_bins - 1)


def telemetry_accum_reference(job_vals, job_wts, task_vals, task_wts,
                              job_hist, task_hist, win, widx, wvals,
                              lo, hi):
    """One fused telemetry update (the oracle for telemetry_bin.py):

      job_hist  += histogram(job_vals, weights=job_wts)   (log-spaced bins)
      task_hist += histogram(task_vals, weights=task_wts)
      win[widx] += wvals                                  (window bucketing)

    Returns (job_hist, task_hist, win)."""
    B = job_hist.shape[0]
    jh = job_hist.at[log_bin(job_vals, lo, hi, B)].add(job_wts)
    th = task_hist.at[log_bin(task_vals, lo, hi, B)].add(task_wts)
    w = win.at[widx].add(wvals)
    return jh, th, w


def dcsim_advance_reference(core_busy, srv_state, energy, busy_seconds,
                            t, t_next, state_power, p_core_active,
                            p_core_idle, srv_wake_at=None,
                            srv_idle_since=None, srv_tau=None,
                            throttled=None, throttle_power_scale=1.0,
                            inf=1.0e30):
    """One fused engine advance (the hot loop of core/engine.sim_step):

      dt      = t_next - t
      power_i = table[state_i] + busy_i·p_act + idle_i·p_idle  (awake only;
                p_act scales by throttle_power_scale on throttled servers)
      energy += power·dt ; busy_seconds += busy_i·dt
      completions: core slots with busy_until <= t_next -> freed (inf)
      next candidate = min(surviving busy_until, wake completions,
                           idle delay-timer expiries)   (farm's share of
                           the next next_event_time reduction)

    Returns (new_core_busy, done_mask, energy, busy_seconds, next_cand)."""
    N, C = core_busy.shape
    if srv_wake_at is None:
        srv_wake_at = jnp.full((N,), inf, jnp.float32)
    if srv_idle_since is None:
        srv_idle_since = jnp.zeros((N,), jnp.float32)
    if srv_tau is None:
        srv_tau = jnp.full((N,), inf, jnp.float32)
    if throttled is None:
        throttled = jnp.zeros((N,), jnp.int32)
    dt = (t_next - t).astype(jnp.float32)
    busy = (core_busy < inf).sum(axis=1).astype(jnp.float32)
    awake = srv_state <= 1                       # ACTIVE=0 / IDLE=1
    p_act = jnp.where(throttled.astype(jnp.int32) != 0,
                      jnp.float32(p_core_active * throttle_power_scale),
                      jnp.float32(p_core_active))
    p_awake = state_power[0] + busy * p_act \
        + (C - busy) * p_core_idle
    p = jnp.where(awake, p_awake, state_power[jnp.clip(srv_state, 0, 5)])
    energy = energy + p * dt
    busy_seconds = busy_seconds + busy * dt
    done = core_busy <= t_next
    new_busy = jnp.where(done, inf, core_busy)
    timer = jnp.where(srv_state == 1, srv_idle_since + srv_tau, inf)
    next_cand = jnp.minimum(new_busy.min(),
                            jnp.minimum(srv_wake_at.min(), timer.min()))
    return new_busy, done, energy, busy_seconds, next_cand
